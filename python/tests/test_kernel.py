"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and value regimes (including the degenerate
states relaxed consistency produces: zero rows, negative counts, zero
denominators); fixed cases pin the exact edge semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import log_dot_pallas, phi_dense_pallas
from compile.kernels.ref import log_dot_ref, phi_dense_ref
from compile import model

jax.config.update("jax_platform_name", "cpu")


def rand(rng, shape, lo=0.0, hi=1.0, dtype=np.float32):
    return (rng.uniform(lo, hi, size=shape)).astype(dtype)


# ---------------------------------------------------------------- log_dot

@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=8),
    k=st.sampled_from([1, 7, 64, 128, 200, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_log_dot_matches_ref(blocks, k, seed):
    rng = np.random.default_rng(seed)
    b = 8 * blocks
    theta = rand(rng, (b, k))
    phi = rand(rng, (b, k))
    got = np.asarray(log_dot_pallas(jnp.asarray(theta), jnp.asarray(phi)))
    want = np.asarray(log_dot_ref(jnp.asarray(theta), jnp.asarray(phi)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_log_dot_known_values():
    theta = jnp.full((8, 4), 0.25, dtype=jnp.float32)
    phi = jnp.full((8, 4), 0.5, dtype=jnp.float32)
    out = np.asarray(log_dot_pallas(theta, phi))
    np.testing.assert_allclose(out, np.log(0.5), rtol=1e-6)


def test_log_dot_zero_rows_clamp():
    theta = jnp.zeros((8, 16), dtype=jnp.float32)
    phi = jnp.zeros((8, 16), dtype=jnp.float32)
    out = np.asarray(log_dot_pallas(theta, phi))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, np.log(1e-30), rtol=1e-5)


def test_log_dot_accepts_f64_inputs():
    rng = np.random.default_rng(0)
    theta = rand(rng, (8, 32), dtype=np.float64)
    phi = rand(rng, (8, 32), dtype=np.float64)
    got = np.asarray(log_dot_pallas(jnp.asarray(theta), jnp.asarray(phi)))
    want = np.asarray(log_dot_ref(jnp.asarray(theta), jnp.asarray(phi)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_log_dot_rejects_unaligned_batch():
    with pytest.raises(AssertionError):
        log_dot_pallas(jnp.zeros((7, 8)), jnp.zeros((7, 8)))


# -------------------------------------------------------------- phi_dense

@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    k=st.sampled_from([1, 5, 64, 128, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_phi_dense_matches_ref(blocks, k, seed):
    rng = np.random.default_rng(seed)
    b = 8 * blocks
    counts = rand(rng, (b, k), lo=-3.0, hi=50.0)  # include negatives
    denom = rand(rng, (k,), lo=0.0, hi=100.0)  # include ~zero denominators
    beta = float(rng.uniform(0.001, 1.0))
    got = np.asarray(phi_dense_pallas(jnp.asarray(counts), jnp.asarray(denom), beta))
    want = np.asarray(phi_dense_ref(jnp.asarray(counts), jnp.asarray(denom), beta))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_phi_dense_known_values():
    counts = jnp.asarray(np.arange(8 * 4, dtype=np.float32).reshape(8, 4))
    denom = jnp.full((4,), 10.0, dtype=jnp.float32)
    out = np.asarray(phi_dense_pallas(counts, denom, 0.5))
    want = (np.arange(32, dtype=np.float32).reshape(8, 4) + 0.5) / 10.0
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_phi_dense_clamps_negative_counts():
    counts = jnp.full((8, 2), -5.0, dtype=jnp.float32)
    denom = jnp.ones((2,), dtype=jnp.float32)
    out = np.asarray(phi_dense_pallas(counts, denom, 0.25))
    np.testing.assert_allclose(out, 0.25, rtol=1e-6)


# ------------------------------------------------------------------- L2

def test_model_graphs_pallas_vs_jnp_agree():
    rng = np.random.default_rng(7)
    theta = jnp.asarray(rand(rng, (16, 64)))
    phi = jnp.asarray(rand(rng, (16, 64)))
    (a,) = model.eval_log_dot(theta, phi, use_pallas=True)
    (b,) = model.eval_log_dot(theta, phi, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    counts = jnp.asarray(rand(rng, (8, 64), hi=30.0))
    denom = jnp.asarray(rand(rng, (64,), lo=1.0, hi=40.0))
    (pa,) = model.dense_phi(counts, denom, 0.1, use_pallas=True)
    (pb,) = model.dense_phi(counts, denom, 0.1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(pa), np.asarray(pb), rtol=1e-5)


def test_dense_proposal_sums():
    rng = np.random.default_rng(9)
    counts = jnp.asarray(rand(rng, (8, 32), hi=20.0))
    denom = jnp.asarray(rand(rng, (32,), lo=1.0, hi=30.0))
    alpha = jnp.asarray(rand(rng, (32,), lo=0.01, hi=0.5))
    q, qsum = model.dense_proposal(counts, denom, alpha, 0.05)
    np.testing.assert_allclose(
        np.asarray(qsum), np.asarray(q).sum(axis=1), rtol=1e-5
    )
    assert np.all(np.asarray(q) >= 0)


# ------------------------------------------------------------------- AOT

def test_aot_lowering_produces_hlo_text(tmp_path):
    from compile import aot

    text = aot.to_hlo_text(aot.lower_log_dot(16, 32, use_pallas=True))
    assert "HloModule" in text
    assert "ENTRY" in text
    text2 = aot.to_hlo_text(aot.lower_phi_dense(8, 32, use_pallas=True))
    assert "HloModule" in text2


def test_aot_main_writes_manifest(tmp_path, monkeypatch):
    import sys
    from compile import aot

    out = tmp_path / "artifacts"
    monkeypatch.setattr(
        sys,
        "argv",
        ["aot", "--out-dir", str(out), "--k", "32", "--log-dot-batch", "16", "--phi-batch", "8"],
    )
    aot.main()
    import json

    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["log_dot"]["k"] == 32
    assert (out / "log_dot.hlo.txt").exists()
    assert (out / "phi_dense.hlo.txt").exists()
