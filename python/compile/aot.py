"""AOT compiler: lower the Layer-2 graphs to HLO text + manifest.

Run once at build time (`make artifacts`); the rust runtime then loads
`artifacts/*.hlo.txt` through the PJRT C API and python never runs again.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids
(see /opt/xla-example/README.md and aot_recipe).

Usage: python -m compile.aot --out-dir ../artifacts [--k 512] [--batch 256]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static shapes the artifacts are specialized to. K is padded by the rust
# caller; 512 covers every configuration the benches use (larger K falls
# back to the bit-identical rust path).
DEFAULT_K = 512
LOG_DOT_BATCH = 256
PHI_BATCH = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_log_dot(batch, k, use_pallas):
    spec = jax.ShapeDtypeStruct((batch, k), jnp.float32)
    fn = lambda t, p: model.eval_log_dot(t, p, use_pallas=use_pallas)  # noqa: E731
    return jax.jit(fn).lower(spec, spec)


def lower_phi_dense(batch, k, use_pallas):
    counts = jax.ShapeDtypeStruct((batch, k), jnp.float32)
    denom = jax.ShapeDtypeStruct((k,), jnp.float32)
    beta = jax.ShapeDtypeStruct((), jnp.float32)
    fn = lambda c, d, b: model.dense_phi(c, d, b, use_pallas=use_pallas)  # noqa: E731
    return jax.jit(fn).lower(counts, denom, beta)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--k", type=int, default=DEFAULT_K)
    ap.add_argument("--log-dot-batch", type=int, default=LOG_DOT_BATCH)
    ap.add_argument("--phi-batch", type=int, default=PHI_BATCH)
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference instead of the Pallas kernels",
    )
    args = ap.parse_args()

    use_pallas = not args.no_pallas
    flavor = "pallas" if use_pallas else "jnp"
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}

    text = to_hlo_text(lower_log_dot(args.log_dot_batch, args.k, use_pallas))
    path = os.path.join(args.out_dir, "log_dot.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["log_dot"] = {
        "file": "log_dot.hlo.txt",
        "batch": args.log_dot_batch,
        "k": args.k,
        "flavor": flavor,
    }
    print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    text = to_hlo_text(lower_phi_dense(args.phi_batch, args.k, use_pallas))
    path = os.path.join(args.out_dir, "phi_dense.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["phi_dense"] = {
        "file": "phi_dense.hlo.txt",
        "batch": args.phi_batch,
        "k": args.k,
        "flavor": flavor,
    }
    print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}", file=sys.stderr)


if __name__ == "__main__":
    main()
