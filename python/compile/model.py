"""Layer 2: the JAX compute graphs the rust runtime executes.

Each function here is a jit-able graph calling the Layer-1 Pallas
kernels; `aot.py` lowers them once to HLO text. The graphs are small on
purpose — the paper's contribution is the coordination layer, so L2 is
the *dense* math of the system: the perplexity scoring pass and the
phi/dense-proposal normalization (the stale distribution the alias
sampler snapshots).
"""

import jax.numpy as jnp

from .kernels import log_dot_pallas, phi_dense_pallas
from .kernels.ref import log_dot_ref, phi_dense_ref


def eval_log_dot(theta, phi, *, use_pallas=True):
    """Perplexity scoring pass: out[b] = log p(w_b | d_b).

    The graph returns a 1-tuple so the rust side can unwrap uniformly
    (`to_tuple1`, see /opt/xla-example/load_hlo.rs).
    """
    if use_pallas:
        return (log_dot_pallas(theta, phi),)
    return (log_dot_ref(theta, phi),)


def dense_phi(counts, denom, beta, *, use_pallas=True):
    """phi[b,t] = (counts[b,t]+beta)/denom[t] over a row batch."""
    if use_pallas:
        return (phi_dense_pallas(counts, denom, beta),)
    return (phi_dense_ref(counts, denom, beta),)


def dense_proposal(counts, denom, alpha, beta, *, use_pallas=True):
    """The alias sampler's stale dense weights q_w(t) = alpha_t * phi_tw
    plus their row sums (eq. 4's dense term and its normalizer).

    alpha: [K] per-topic document smoothing.
    Returns (q [B,K], qsum [B]).
    """
    (phi,) = dense_phi(counts, denom, beta, use_pallas=use_pallas)
    q = phi * alpha[None, :].astype(jnp.float32)
    return q, jnp.sum(q, axis=1)
