"""Pallas kernel: the perplexity estimator's scoring pass.

For a batch of test tokens the rust coordinator gathers the fold-in
mixture theta[b, :] and the model row phi[b, :] (phi[b, t] = p(w_b | t));
the kernel computes

    out[b] = log( sum_t theta[b, t] * phi[b, t] )

which is `log p(w_b | d)` in the paper's estimator (Section 6).

TPU mapping (DESIGN.md "Hardware-Adaptation"): tokens tile the sublane
axis (block of 8), topics live on the 128-wide lane axis and are reduced
in-register; the multiply-reduce feeds the MXU-adjacent VPU with both
operands streamed HBM->VMEM once. `interpret=True` everywhere in this
environment: the CPU PJRT plugin cannot execute Mosaic custom-calls, so
the kernel lowers to plain HLO with identical numerics (the gotcha list
in /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sublane-aligned token block (8 is the f32 sublane count on TPU).
BLOCK_B = 8


def _log_dot_kernel(theta_ref, phi_ref, out_ref):
    """One (BLOCK_B, K) tile: elementwise product, lane reduce, log."""
    t = theta_ref[...]
    p = phi_ref[...]
    acc = jnp.sum(t * p, axis=1)
    # Clamp to a tiny positive value: unseen words can have all-zero
    # statistics (the paper evaluates them with zero stats, not by
    # skipping), and log(0) would poison the batch.
    acc = jnp.maximum(acc, jnp.float32(1e-30))
    out_ref[...] = jnp.log(acc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def log_dot_pallas(theta, phi, interpret=True):
    """out[b] = log(sum_t theta[b,t] * phi[b,t]); shapes [B, K] -> [B]."""
    b, k = theta.shape
    assert phi.shape == (b, k)
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _log_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(theta.astype(jnp.float32), phi.astype(jnp.float32))
