"""Pallas kernel: dense word-given-topic probabilities.

phi[b, t] = (counts[b, t] + beta) / denom[t]

with denom[t] = n_t + beta_bar precomputed by the caller. This is the
dense factor of eq. (4) — the quantity the alias sampler snapshots into
its stale per-word proposal tables and the evaluator uses for phi rows.

TPU mapping: word rows tile the sublane axis, topics the lane axis;
`denom` is O(K) and stays resident in VMEM across the whole grid (the
BlockSpec pins it to block (K,) at every grid point), so each tile costs
one HBM read of the counts block and no re-fetches — the BlockSpec
expresses what a CUDA port would do with a shared-memory broadcast.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 8


def _phi_dense_kernel(counts_ref, denom_ref, beta_ref, out_ref):
    c = counts_ref[...]
    d = denom_ref[...]
    beta = beta_ref[0]
    # Guard: relaxed consistency can transiently produce negative counts
    # or zero denominators; clamp like the rust hot path does.
    c = jnp.maximum(c, 0.0)
    d = jnp.maximum(d, jnp.float32(1e-9))
    out_ref[...] = (c + beta) / d[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def phi_dense_pallas(counts, denom, beta, interpret=True):
    """phi[b,t] = (max(counts,0)+beta)/denom[t]; [B,K],[K],scalar -> [B,K]."""
    b, k = counts.shape
    assert denom.shape == (k,)
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    beta_arr = jnp.asarray(beta, dtype=jnp.float32).reshape((1,))
    return pl.pallas_call(
        _phi_dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(counts.astype(jnp.float32), denom.astype(jnp.float32), beta_arr)
