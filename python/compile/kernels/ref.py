"""Pure-jnp oracles for the Pallas kernels.

Every kernel has a reference here; pytest asserts allclose across shapes
and dtypes (hypothesis sweeps). The rust fallback evaluator implements
the same math, so this file is the single source of truth for numerics.
"""

import jax.numpy as jnp


def log_dot_ref(theta, phi):
    """out[b] = log(max(sum_t theta[b,t]*phi[b,t], 1e-30))."""
    theta = theta.astype(jnp.float32)
    phi = phi.astype(jnp.float32)
    acc = jnp.sum(theta * phi, axis=1)
    return jnp.log(jnp.maximum(acc, jnp.float32(1e-30)))


def phi_dense_ref(counts, denom, beta):
    """phi[b,t] = (max(counts,0)+beta)/max(denom,1e-9)."""
    counts = jnp.maximum(counts.astype(jnp.float32), 0.0)
    denom = jnp.maximum(denom.astype(jnp.float32), jnp.float32(1e-9))
    beta = jnp.float32(beta)
    return (counts + beta) / denom[None, :]
