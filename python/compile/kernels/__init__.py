# Layer 1: Pallas kernels for the dense math of the alias sampler and the
# perplexity estimator. Build-time only — lowered to HLO by ../aot.py and
# never imported at runtime.
from .log_dot import log_dot_pallas
from .phi_dense import phi_dense_pallas

__all__ = ["log_dot_pallas", "phi_dense_pallas"]
