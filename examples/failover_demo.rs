//! Failure handling demo (§5.4): a training run in which a client is
//! hard-killed and a server slot is lost mid-run. The scheduler's
//! failover respawns the client from its barrier-free snapshot, the
//! server manager freezes the system, rebinds the slot to a fresh node
//! restored from *its* snapshot, and training converges anyway.
//!
//! ```sh
//! cargo run --release --example failover_demo
//! ```

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn main() {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 20;
    cfg.corpus.n_docs = 1_200;
    cfg.corpus.vocab_size = 2_000;
    cfg.corpus.n_topics = 20;
    cfg.corpus.doc_len_mean = 40.0;
    cfg.cluster.clients = 4;
    cfg.iterations = 12;
    cfg.eval_every = 3;
    cfg.test_docs = 80;
    // Slow the workers slightly so the injected failures land mid-run.
    cfg.cluster.worker_slowdown = Duration::from_micros(300);
    // Barrier-free snapshots every 100 ms (paper: "every N minutes").
    cfg.cluster.snapshot_every = Some(Duration::from_millis(100));
    // The failure plan: kill client 2 at iteration 3, server slot 0 at 6.
    cfg.failures.kill_clients = vec![(3, 2)];
    cfg.failures.kill_servers = vec![(6, 0)];

    println!("failover demo: killing client 2 @ iter 3 and server slot 0 @ iter 6\n");
    let report = Trainer::new(cfg).run().expect("training failed");
    report.print_table();

    println!("\nreassignments (client failovers): {}", report.reassignments);
    assert!(
        report.reassignments >= 1,
        "expected at least one client failover"
    );
    println!(
        "final perplexity {:.1} — training survived both failures.",
        report.final_perplexity()
    );
}
