//! Perf driver (§Perf): AliasLDA K=1600 hot loop, best-of-N reporting
//! (the shared host is noisy; per-rep best is the stable statistic).
use hplvm::corpus::generator::CorpusConfig;
use hplvm::sampler::alias_lda::AliasLda;
use hplvm::sampler::DocSampler;
use hplvm::util::rng::Rng;
fn main() {
    let (c, _) = CorpusConfig { n_docs: 1500, vocab_size: 5000, n_topics: 30, doc_len_mean: 50.0, seed: 99, ..Default::default() }.generate();
    let tokens: usize = c.docs.iter().map(|d| d.len()).sum();
    let mut rng = Rng::new(1);
    let mut s = AliasLda::new(c.docs, 5000, 1600, 0.1, 0.01, &mut rng);
    for _ in 0..2 { for d in 0..s.docs.len() { s.sample_doc(d, &mut rng); } }
    let mut best = 0.0f64;
    for _ in 0..8 {
        let t0 = std::time::Instant::now();
        for d in 0..s.docs.len() { s.sample_doc(d, &mut rng); }
        let rate = tokens as f64 / t0.elapsed().as_secs_f64() / 1e6;
        if rate > best { best = rate; }
    }
    println!("K=1600 best-of-8: {best:.2}M tokens/s");
}
