//! Topic discovery: train on a corpus with a known ground truth, then
//! inspect what the model recovered — top words per topic, topic shares,
//! and the document-side sparsity the alias sampler exploits.
//!
//! ```sh
//! cargo run --release --example topic_discovery
//! ```

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::model::ModelSampler;
use hplvm::corpus::vocab::Vocabulary;
use hplvm::eval::topics::{top_words, topic_shares};
use hplvm::util::rng::Rng;

fn main() {
    // Single-machine training for direct access to the learned counts.
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 12;
    cfg.corpus.n_docs = 1_500;
    cfg.corpus.vocab_size = 3_000;
    cfg.corpus.n_topics = 12;
    cfg.corpus.doc_len_mean = 60.0;

    let (corpus, _) = cfg.corpus.generate();
    let vocab = Vocabulary::new(cfg.corpus.vocab_size, cfg.corpus.zipf_s);
    let mut rng = Rng::new(7);
    let mut sampler = ModelSampler::build(&cfg, corpus.docs.clone(), cfg.corpus.vocab_size, None, &mut rng);

    println!("training {} sweeps on {} tokens ...", 30, corpus.total_tokens());
    for sweep in 0..30 {
        for d in 0..corpus.docs.len() {
            sampler.sample_doc(d, &mut rng);
        }
        if sweep % 10 == 9 {
            println!(
                "  sweep {:>2}: topics/word {:.2}, MH acceptance {:.2}",
                sweep + 1,
                sampler.topics_per_word(),
                sampler.acceptance_rate()
            );
        }
    }

    println!("\ntop words per topic (synthetic ids; rank 0 = most frequent type):");
    let tops = top_words(sampler.primary(), 8);
    for (t, words) in tops.iter().enumerate() {
        if words.is_empty() {
            continue;
        }
        let line: Vec<String> = words
            .iter()
            .map(|&(w, c)| format!("{}({})", vocab.surface(w), c))
            .collect();
        println!("  topic {t:>2}: {}", line.join(" "));
    }

    let shares = topic_shares(sampler.primary());
    println!("\ntopic shares (sorted): {:?}", &shares[..shares.len().min(12)]
        .iter()
        .map(|s| format!("{:.3}", s))
        .collect::<Vec<_>>());
    println!(
        "ground truth had {} topics; effective topics (share > 1%): {}",
        cfg.corpus.n_topics,
        shares.iter().filter(|&&s| s > 0.01).count()
    );
}
