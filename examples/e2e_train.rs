//! END-TO-END DRIVER (recorded in EXPERIMENTS.md): the full three-layer
//! stack on a real workload.
//!
//! * L3: 8-client / 3-server simulated cluster, eventual consistency,
//!   communication filters, distributed projection, failure injection ON.
//! * L2+L1: test perplexity scored through the AOT-compiled PJRT
//!   artifacts (`make artifacts` first) — python never runs here.
//! * Workload: 10M-parameter LDA (vocab 20k × K 500) on a ~1M-token
//!   synthetic corpus, 40 full Gibbs sweeps, loss (perplexity +
//!   log-likelihood) curve logged every sweep.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train
//! ```

use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn main() {
    let t0 = std::time::Instant::now();
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 500;
    cfg.corpus.n_docs = 12_000;
    cfg.corpus.vocab_size = 20_000;
    cfg.corpus.n_topics = 100;
    cfg.corpus.doc_len_mean = 50.0;
    cfg.cluster.clients = 4; // this container exposes a single core

    cfg.cluster.net.base_latency = Duration::from_micros(150);
    cfg.cluster.net.jitter = Duration::from_micros(300);
    cfg.cluster.net.drop_prob = 0.005;
    cfg.cluster.snapshot_every = Some(Duration::from_secs(5));
    cfg.projection = ProjectionMode::Distributed;
    cfg.iterations = 40;
    cfg.eval_every = 5;
    cfg.test_docs = 200;
    cfg.failures.kill_clients = vec![(15, 3)]; // mid-run preemption
    cfg.use_pjrt_eval = true; // L1/L2 artifacts on the eval path

    let params = cfg.corpus.vocab_size * cfg.params.topics;
    println!(
        "e2e: {} | {:.1}M parameters (V={} × K={}) | {} docs | {} clients/{} servers | PJRT eval",
        cfg.model.name(),
        params as f64 / 1e6,
        cfg.corpus.vocab_size,
        cfg.params.topics,
        cfg.corpus.n_docs,
        cfg.cluster.clients,
        cfg.cluster.n_servers(),
    );

    let report = Trainer::new(cfg).run().expect("training failed");
    report.print_table();

    // Loss curve summary for EXPERIMENTS.md.
    println!("\nperplexity curve (eval every 5 sweeps):");
    for r in &report.per_iteration {
        if r.perplexity.count() > 0 {
            println!(
                "  sweep {:>3}: perplexity {:>9.1} ±{:>7.1}  loglik {:>8.4}  (n={})",
                r.iteration,
                r.perplexity.mean(),
                r.perplexity.std(),
                r.log_lik.mean(),
                r.datapoints
            );
        }
    }
    println!(
        "\ntotal {:.1}M tokens in {:.1}s wall | sampler throughput {:.2}M tokens/s | reassignments {}",
        report.total_tokens as f64 / 1e6,
        t0.elapsed().as_secs_f64(),
        report.tokens_per_sec / 1e6,
        report.reassignments
    );
    let path = "e2e_report.json";
    std::fs::write(path, report.to_json().to_string()).expect("write report");
    println!("report JSON: {path}");
}
