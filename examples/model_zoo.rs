//! Model zoo: the four samplers of the paper on one corpus, one table —
//! YahooLDA (sparse baseline), AliasLDA, AliasPDP, AliasHDP. Shows the
//! generality claim: one parameter-server system, four latent variable
//! models, the alias machinery shared by the last three.
//!
//! ```sh
//! cargo run --release --example model_zoo
//! ```

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;

fn main() {
    let models = [
        ModelKind::YahooLda,
        ModelKind::AliasLda,
        ModelKind::AliasPdp,
        ModelKind::AliasHdp,
    ];
    let mut rows = Vec::new();
    for model in models {
        let mut cfg = TrainConfig::default();
        cfg.model = model;
        cfg.params.topics = if model == ModelKind::AliasHdp { 60 } else { 30 };
        cfg.corpus.n_docs = 1_200;
        cfg.corpus.vocab_size = 2_500;
        cfg.corpus.n_topics = 20;
        cfg.corpus.doc_len_mean = 40.0;
        if model == ModelKind::AliasPdp {
            cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
        }
        cfg.cluster.clients = 4;
        cfg.iterations = 10;
        cfg.eval_every = 5;
        cfg.test_docs = 80;
        println!("running {} ...", model.name());
        let report = Trainer::new(cfg).run().expect("train");
        rows.push(vec![
            model.name().to_string(),
            format!("{:.1}", report.final_perplexity()),
            format!("{:.4}", report.final_log_lik()),
            format!("{:.3}", report.steady_state_iter_secs()),
            format!("{:.2}M", report.tokens_per_sec / 1e6),
            report.corrections.to_string(),
        ]);
    }
    println!();
    bench::table(
        &[
            "model",
            "perplexity",
            "loglik/token",
            "iter time(s)",
            "tokens/s",
            "corrections",
        ],
        &rows,
    );
    println!("\nNote: PDP runs on a power-law (PYP-generated) corpus — its perplexity is");
    println!("not directly comparable to the LDA rows; corrections > 0 only for the");
    println!("constrained models (PDP/HDP), exactly as §5.5 predicts.");
}
