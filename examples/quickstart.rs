//! Quickstart: train AliasLDA on a synthetic corpus over a simulated
//! 4-client / 2-server parameter-server cluster and print the paper-style
//! per-iteration table.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;

fn main() {
    let mut cfg = TrainConfig::small_lda();
    cfg.iterations = 15;
    cfg.eval_every = 5;

    println!(
        "quickstart: {} | {} docs, vocab {}, K={} | {} clients / {} servers",
        cfg.model.name(),
        cfg.corpus.n_docs,
        cfg.corpus.vocab_size,
        cfg.params.topics,
        cfg.cluster.clients,
        cfg.cluster.n_servers(),
    );

    let report = Trainer::new(cfg).run().expect("training failed");
    report.print_table();

    println!(
        "\nfinal test perplexity: {:.1} (lower is better; vocab-size {} would be chance)",
        report.final_perplexity(),
        2_000
    );
}
