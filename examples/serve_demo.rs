//! Train → checkpoint → serve → **train more (same session)** →
//! **hot-reload** → **scale out**, end to end: one [`TrainSession`]
//! trains a small LDA model, checkpoints the cluster for the serve
//! handoff, keeps training while queries flow, checkpoints again, and
//! the service swaps the newer generation in live (queries in flight,
//! nothing dropped) — then the same snapshots serve through a 2-replica
//! [`ReplicaSet`] (`serve --replicas 2`): the vocabulary
//! consistent-hashed over two model slices, each with its own alias
//! cache, answers bit-identical to the single model.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```
//!
//! [`TrainSession`]: hplvm::coordinator::TrainSession
//! [`ReplicaSet`]: hplvm::serve::ReplicaSet

use hplvm::config::TrainConfig;
use hplvm::coordinator::TrainSession;
use hplvm::corpus::SyntheticSource;
use hplvm::serve::{InferConfig, InferenceService, ReplicaSet, ServeConfig, ServingHandle};

fn main() {
    let snapdir = std::env::temp_dir().join(format!("hplvm_serve_demo_{}", std::process::id()));
    std::fs::remove_dir_all(&snapdir).ok();

    // 1. One long-lived session; generation 1 = a cluster checkpoint
    // after 12 iterations. The checkpoint is simultaneously a serve
    // input and a resume target.
    let mut cfg = TrainConfig::small_lda();
    cfg.iterations = 24;
    println!(
        "[session] training {} | {} docs, vocab {}, K={}",
        cfg.model.name(),
        cfg.corpus.n_docs,
        cfg.corpus.vocab_size,
        cfg.params.topics,
    );
    let source = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg.clone(), &source).expect("session start");
    let seg = session.run_to(12).expect("segment 1");
    println!(
        "[gen 1] iterations {}..{}: perplexity {:.1} (run {:#018x})",
        seg.start_iteration,
        seg.end_iteration,
        seg.report.final_perplexity(),
        session.run_id(),
    );
    session.checkpoint(&snapdir).expect("checkpoint");

    // 2. Load generation 1 — no training config needed: the v3 snapshot
    // header carries the family, K, α, β, ring geometry, and (for
    // PDP/HDP) the table-side hyperparameters.
    let handle = ServingHandle::load_dir(&snapdir).expect("snapshot load failed");
    {
        let model = handle.model();
        println!(
            "serving {} (family {}) | K={} vocab={} | {} frozen tokens | generation {}",
            model.meta().model,
            model.kind().family_name(),
            model.k(),
            model.vocab(),
            model.total_tokens(),
            handle.generation(),
        );
    }

    // 3. Serve held-out documents (regenerate the corpus; the tail docs
    // were never trained on).
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let svc = InferenceService::spawn(handle.clone(), ServeConfig::default());
    for (i, doc) in test.docs.iter().take(3).enumerate() {
        let res = svc.infer(doc.tokens.clone()).expect("service closed");
        let top: Vec<String> = res
            .top_topics(3)
            .into_iter()
            .map(|(t, w)| format!("{t}:{w:.3}"))
            .collect();
        println!(
            "gen {} | doc {i:>2} ({:>3} tokens): top topics {}",
            res.generation,
            doc.tokens.len(),
            top.join("  ")
        );
    }

    // 4. Train further *in the same session* — no retrain from scratch:
    // the cluster is still hot, and the next checkpoint carries the same
    // run id, so the watcher/reloader sees a continuation, not a
    // stranger. The service keeps answering against generation 1.
    let seg = session.run_to(24).expect("segment 2");
    println!(
        "[gen 2] iterations {}..{}: perplexity {:.1}",
        seg.start_iteration,
        seg.end_iteration,
        seg.report.final_perplexity(),
    );
    session.checkpoint(&snapdir).expect("checkpoint 2");
    let _ = session.finish().expect("finish");

    // 5. Live reload: queue a burst of queries, swap the generation while
    // they drain, and account for every single one.
    let in_flight: Vec<_> = test
        .docs
        .iter()
        .take(40)
        .map(|d| svc.submit(d.tokens.clone()))
        .collect();
    let swapped = handle.reload(&snapdir).expect("reload failed");
    println!("hot-reloaded → generation {swapped} (queue untouched)");
    let mut by_gen = std::collections::BTreeMap::<u64, usize>::new();
    for rx in in_flight {
        let res = rx.recv().expect("request dropped across reload");
        *by_gen.entry(res.generation).or_default() += 1;
    }
    for (generation, n) in &by_gen {
        println!("  {n:>3} in-flight queries answered by generation {generation}");
    }

    // 6. Every query from here on is served by the new generation.
    let res = svc
        .infer(test.docs[0].tokens.clone())
        .expect("service closed");
    assert_eq!(res.generation, swapped, "post-swap query on old generation");
    println!(
        "post-swap query: generation {} | top topic {:?}",
        res.generation,
        res.top_topics(1)
    );
    let stats = svc.stats();
    println!(
        "served {} queries in {} micro-batches; final generation {}",
        stats.served,
        stats.batches,
        handle.generation()
    );
    svc.shutdown();

    // 7. Scale out: the same snapshots behind a 2-replica set
    // (`hplvm serve --replicas 2`). The vocabulary is consistent-hashed
    // over the replicas — each holds only its words' rows plus the
    // global normalizers — and routed answers are bit-identical to the
    // single model's at the same seed.
    let set = ReplicaSet::load_dir(&snapdir, 2).expect("replica-set load failed");
    {
        let vocab = set.current().models()[0].vocab();
        for (r, owned) in set.router().spread(vocab).iter().enumerate() {
            println!("replica {r}: owns {owned} of {vocab} words");
        }
    }
    let doc = &test.docs[0].tokens;
    let cfg = InferConfig::default();
    let single = hplvm::serve::infer_doc(
        &handle.model(),
        doc,
        &cfg,
        &mut hplvm::util::rng::Rng::new(1234),
    );
    let routed = set.infer(doc, &cfg, &mut hplvm::util::rng::Rng::new(1234));
    assert!(
        single
            .theta
            .iter()
            .zip(routed.theta.iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "routed posterior must be bit-identical to the single-replica posterior"
    );
    println!(
        "routed query served by replicas {:?} — θ bit-identical to 1-replica ✓",
        routed.served_by
    );
    // Set-wide reload: the generation bumps only once *every* replica
    // has installed the new slice (and pre-warmed its alias cache from
    // the outgoing resident set).
    let g = set.reload(&snapdir).expect("set reload failed");
    println!("set-wide hot reload → generation {g} (all replicas committed)");

    std::fs::remove_dir_all(&snapdir).ok();
}
