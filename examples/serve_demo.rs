//! Train → snapshot → serve, end to end: train a small LDA model on the
//! simulated cluster, persist the server snapshots, load them into the
//! inference service, and answer topic-mixture queries for held-out
//! documents.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;
use hplvm::serve::{InferenceService, ServeConfig, ServingModel};
use std::sync::Arc;

fn main() {
    let snapdir = std::env::temp_dir().join(format!("hplvm_serve_demo_{}", std::process::id()));

    // 1. Train with snapshots persisted (the serve handoff).
    let mut cfg = TrainConfig::small_lda();
    cfg.iterations = 20;
    cfg.cluster.snapshot_dir = Some(snapdir.clone());
    println!(
        "training {} | {} docs, vocab {}, K={} → snapshots in {}",
        cfg.model.name(),
        cfg.corpus.n_docs,
        cfg.corpus.vocab_size,
        cfg.params.topics,
        snapdir.display()
    );
    let report = Trainer::new(cfg.clone()).run().expect("training failed");
    println!(
        "trained: final perplexity {:.1} ({} tokens)",
        report.final_perplexity(),
        report.total_tokens
    );

    // 2. Load the frozen model — no training config needed: the v2
    // snapshot header carries model, K, α, β and the ring geometry.
    let model = Arc::new(ServingModel::load_dir(&snapdir).expect("snapshot load failed"));
    println!(
        "serving model: {} | K={} vocab={} | {} frozen tokens",
        model.meta().model,
        model.k(),
        model.vocab(),
        model.total_tokens()
    );

    // 3. Serve held-out documents (regenerate the corpus; the tail docs
    // were never trained on).
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let svc = InferenceService::spawn(model.clone(), ServeConfig::default());
    let t0 = std::time::Instant::now();
    for (i, doc) in test.docs.iter().take(5).enumerate() {
        let res = svc.infer(doc.tokens.clone()).expect("service closed");
        let top: Vec<String> = res
            .top_topics(3)
            .into_iter()
            .map(|(t, w)| format!("{t}:{w:.3}"))
            .collect();
        println!(
            "doc {i:>2} ({:>3} tokens): top topics {}",
            doc.tokens.len(),
            top.join("  ")
        );
    }
    let n = test.docs.len();
    for doc in &test.docs {
        svc.infer(doc.tokens.clone()).expect("service closed");
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "served {} queries in {:.2}s ({:.0} q/s, {} micro-batches); cache: {:?}",
        stats.served,
        secs,
        (n + 5) as f64 / secs,
        stats.batches,
        model.cache_stats()
    );
    svc.shutdown();
    std::fs::remove_dir_all(&snapdir).ok();
}
