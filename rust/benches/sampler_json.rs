//! Deterministic sampler-throughput + wire-cost bench. Prints the usual
//! table AND writes `BENCH_sampler.json` at the repository root so the
//! repo carries a machine-readable perf trajectory across PRs:
//!
//! * tokens/sec for each of the four samplers (small fixed config,
//!   seeded corpus, warm sweeps — same recipe every run), and
//! * wire bytes per end-of-iteration sync at K=256 as `SimNet` accounts
//!   them, next to the dense-era cost of the identical sync.
//!
//! Regenerate with `cargo bench --bench sampler_json`.

use hplvm::bench;
use hplvm::corpus::generator::{CorpusConfig, GenerativeModel};
use hplvm::ps::msg::Payload;
use hplvm::ps::network::{NetConfig, SimNet};
use hplvm::sampler::alias_lda::AliasLda;
use hplvm::sampler::counts::CountMatrix;
use hplvm::sampler::hdp::AliasHdp;
use hplvm::sampler::pdp::AliasPdp;
use hplvm::sampler::sparse_lda::SparseLda;
use hplvm::sampler::DocSampler;
use hplvm::util::json::Json;
use hplvm::util::rng::Rng;

const N_DOCS: usize = 300;
const VOCAB: usize = 800;
const K: usize = 64;
const DOC_LEN: f64 = 40.0;

fn sweep<S: DocSampler>(s: &mut S, n_docs: usize, rng: &mut Rng) {
    for d in 0..n_docs {
        s.sample_doc(d, rng);
    }
}

fn bench_model<S: DocSampler>(
    name: &str,
    s: &mut S,
    n_docs: usize,
    tokens: u64,
    rng: &mut Rng,
) -> bench::BenchResult {
    bench::time_units(name, 2, 3, tokens as f64, || {
        // The borrow dance: time_units takes FnMut, rng lives outside.
        sweep(s, n_docs, rng);
    })
}

/// One K-panel case: drive a *raw* [`CountMatrix`] (not a full sampler —
/// alias/proposal buffers at K=100k would be hundreds of MB and the
/// panel would measure those, not the rows) with seeded synthetic
/// tokens shaped like a converged model: skewed word frequencies, each
/// word drawing from a small per-word topic menu, so rows stay sparse
/// relative to K. Returns `(table_row, json_entry)`.
fn memory_panel_case(k: usize) -> (Vec<String>, hplvm::util::json::Json) {
    const PANEL_VOCAB: usize = 2_000;
    const PANEL_TOKENS: usize = 400_000;
    const TOPIC_MENU: usize = 32;
    let mut m = CountMatrix::new(PANEL_VOCAB, k);
    let mut rng = Rng::new(0xC0FFEE ^ k as u64);
    let start = std::time::Instant::now();
    for _ in 0..PANEL_TOKENS {
        // min of two uniforms ≈ frequency skew toward low word ids.
        let w = rng.below(PANEL_VOCAB).min(rng.below(PANEL_VOCAB));
        let base = w.wrapping_mul(2_654_435_761) % k;
        let t = (base + rng.below(TOPIC_MENU)) % k;
        m.inc(w as u32, t, 1);
    }
    let secs = start.elapsed().as_secs_f64();
    let inc_tokens_per_sec = PANEL_TOKENS as f64 / secs.max(1e-9);

    let touched = m.iter_rows().count();
    let resident = m.resident_row_bytes();
    let dense = touched * 4 * k;
    let ratio = dense as f64 / (resident.max(1)) as f64;
    let rows = m.drain_deltas();
    let wire: u64 = rows.iter().map(|(_, r)| r.wire_bytes()).sum();

    let row = vec![
        k.to_string(),
        touched.to_string(),
        resident.to_string(),
        dense.to_string(),
        format!("{ratio:.1}x"),
        format!("{inc_tokens_per_sec:.0}"),
        wire.to_string(),
    ];
    let json = Json::obj(vec![
        ("k", Json::Num(k as f64)),
        ("touched_words", Json::Num(touched as f64)),
        ("resident_bytes", Json::Num(resident as f64)),
        ("dense_bytes", Json::Num(dense as f64)),
        ("dense_over_resident", Json::Num(ratio)),
        ("inc_tokens_per_sec", Json::Num(inc_tokens_per_sec)),
        ("drain_wire_bytes", Json::Num(wire as f64)),
    ]);
    (row, json)
}

fn main() {
    println!("# Sampler throughput + sparse-wire cost (BENCH_sampler.json)");

    let (lda_corpus, _) = CorpusConfig {
        n_docs: N_DOCS,
        vocab_size: VOCAB,
        n_topics: 16,
        doc_len_mean: DOC_LEN,
        seed: 7,
        ..Default::default()
    }
    .generate();
    let (pyp_corpus, _) = CorpusConfig {
        n_docs: N_DOCS,
        vocab_size: VOCAB,
        n_topics: 16,
        doc_len_mean: DOC_LEN,
        model: GenerativeModel::Pyp,
        seed: 7,
        ..Default::default()
    }
    .generate();
    let lda_tokens: u64 = lda_corpus.docs.iter().map(|d| d.tokens.len() as u64).sum();
    let pyp_tokens: u64 = pyp_corpus.docs.iter().map(|d| d.tokens.len() as u64).sum();

    bench::section(&format!(
        "tokens/sec — {N_DOCS} docs, V={VOCAB}, K={K}, warm sweeps"
    ));
    let mut rng = Rng::new(1);
    let mut alias = AliasLda::new(lda_corpus.docs.clone(), VOCAB, K, 0.1, 0.01, &mut rng);
    let r_alias = bench_model("AliasLDA", &mut alias, N_DOCS, lda_tokens, &mut rng);
    println!("{}", r_alias.row());

    let mut rng = Rng::new(2);
    let mut yahoo = SparseLda::new(lda_corpus.docs.clone(), VOCAB, K, 0.1, 0.01, &mut rng);
    let r_yahoo = bench_model("SparseLDA", &mut yahoo, N_DOCS, lda_tokens, &mut rng);
    println!("{}", r_yahoo.row());

    let mut rng = Rng::new(3);
    let mut pdp = AliasPdp::new(pyp_corpus.docs, VOCAB, K, 0.1, 0.1, 10.0, 0.5, &mut rng);
    let r_pdp = bench_model("AliasPDP", &mut pdp, N_DOCS, pyp_tokens, &mut rng);
    println!("{}", r_pdp.row());

    let mut rng = Rng::new(4);
    let mut hdp = AliasHdp::new(lda_corpus.docs, VOCAB, K * 2, 1.0, 1.0, 0.01, &mut rng);
    let r_hdp = bench_model("AliasHDP", &mut hdp, N_DOCS, lda_tokens, &mut rng);
    println!("{}", r_hdp.row());

    // Wire bytes per end-of-iteration sync at K=256 (the acceptance tier):
    // one warm sweep's drained deltas through SimNet's byte accounting,
    // vs the dense-era encoding of the very same rows.
    bench::section("wire bytes per end-of-iteration sync (K=256)");
    let wire_k = 256usize;
    let (c, _) = CorpusConfig {
        n_docs: 120,
        vocab_size: 500,
        n_topics: 16,
        doc_len_mean: 30.0,
        seed: 11,
        ..Default::default()
    }
    .generate();
    let mut rng = Rng::new(42);
    let mut s = AliasLda::new(c.docs, 500, wire_k, 0.1, 0.01, &mut rng);
    let _ = s.nwt.drain_deltas(); // discard the init burst
    sweep(&mut s, 120, &mut rng);
    let rows = s.nwt.drain_deltas();
    let n_rows = rows.len() as u64;
    let dense_bytes = 16 + n_rows * (4 + 5 + 4 * wire_k as u64);
    let net = SimNet::new(2, NetConfig::default());
    net.send(0, 1, Payload::Push { matrix: 0, rows });
    let (_, _, _, sparse_bytes) = net.stats();
    let reduction = dense_bytes as f64 / sparse_bytes.max(1) as f64;
    bench::table(
        &["rows", "sparse bytes", "dense-era bytes", "reduction"],
        &[vec![
            n_rows.to_string(),
            sparse_bytes.to_string(),
            dense_bytes.to_string(),
            format!("{reduction:.1}x"),
        ]],
    );

    // Hybrid-row memory + throughput panel at K ∈ {1k, 10k, 100k}: the
    // acceptance tier for the fully-sparse model memory is ≥10× smaller
    // resident bytes than dense at K=10k.
    bench::section("hybrid-row memory panel — raw CountMatrix, 400k incs");
    let mut panel_rows = Vec::new();
    let mut panel_json = Vec::new();
    for k in [1_000usize, 10_000, 100_000] {
        let (row, json) = memory_panel_case(k);
        panel_rows.push(row);
        panel_json.push(json);
    }
    bench::table(
        &[
            "K",
            "touched words",
            "resident bytes",
            "dense bytes",
            "dense/resident",
            "inc tokens/sec",
            "drain wire bytes",
        ],
        &panel_rows,
    );

    // Machine-readable trajectory at the repository root.
    let json = Json::obj(vec![
        ("bench", Json::Str("sampler_json".into())),
        (
            "regenerate",
            Json::Str("cargo bench --bench sampler_json".into()),
        ),
        (
            "config",
            Json::obj(vec![
                ("n_docs", Json::Num(N_DOCS as f64)),
                ("vocab", Json::Num(VOCAB as f64)),
                ("k", Json::Num(K as f64)),
                ("doc_len_mean", Json::Num(DOC_LEN)),
            ]),
        ),
        (
            "tokens_per_sec",
            Json::obj(vec![
                ("AliasLDA", Json::Num(r_alias.throughput())),
                ("SparseLDA", Json::Num(r_yahoo.throughput())),
                ("AliasPDP", Json::Num(r_pdp.throughput())),
                ("AliasHDP", Json::Num(r_hdp.throughput())),
            ]),
        ),
        (
            "wire_sync",
            Json::obj(vec![
                ("k", Json::Num(wire_k as f64)),
                ("rows", Json::Num(n_rows as f64)),
                ("sparse_bytes", Json::Num(sparse_bytes as f64)),
                ("dense_era_bytes", Json::Num(dense_bytes as f64)),
                ("reduction", Json::Num(reduction)),
            ]),
        ),
        ("memory_panel", Json::Arr(panel_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sampler.json");
    match std::fs::write(path, format!("{json}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
