//! Throughput table (§6.3 "many machines ... above one million tokens per
//! second" + §6.1's central scaling claim): raw single-thread sampling
//! rate per model, the AliasLDA-vs-SparseLDA sweep over topic counts
//! (alias stays flat, sparse grows with topics-per-word), and the
//! multi-thread stash pool rate.

use hplvm::bench;
use hplvm::corpus::generator::{CorpusConfig, GenerativeModel};
use hplvm::sampler::alias::AliasTable;
use hplvm::sampler::alias_lda::AliasLda;
use hplvm::sampler::hdp::AliasHdp;
use hplvm::sampler::pdp::AliasPdp;
use hplvm::sampler::sparse_lda::SparseLda;
use hplvm::sampler::DocSampler;
use hplvm::util::rng::Rng;

fn corpus(vocab: usize, n_docs: usize, truth: usize, pyp: bool) -> Vec<hplvm::corpus::doc::Document> {
    let (c, _) = CorpusConfig {
        n_docs,
        vocab_size: vocab,
        n_topics: truth,
        doc_len_mean: 50.0,
        model: if pyp { GenerativeModel::Pyp } else { GenerativeModel::Lda },
        seed: 99,
        ..Default::default()
    }
    .generate();
    c.docs
}

fn main() {
    println!("# Throughput — tokens/second/client (paper: ~1M/client at 2000 topics)");
    let vocab = 5_000;
    let docs = corpus(vocab, 1_500, 30, false);
    let tokens: usize = docs.iter().map(|d| d.len()).sum();

    bench::section("K-sweep: per-token cost vs topic count (the paper's central claim)");
    let mut rows = Vec::new();
    for k in [100usize, 400, 1600] {
        let mut rng = Rng::new(1);
        let mut alias = AliasLda::new(docs.clone(), vocab, k, 0.1, 0.01, &mut rng);
        // Warm into the dense regime so topics-per-word is realistic.
        for d in 0..alias.docs.len() {
            alias.sample_doc(d, &mut rng);
        }
        let r_alias = bench::time_units(&format!("AliasLDA K={k}"), 1, 3, tokens as f64, || {
            for d in 0..alias.docs.len() {
                alias.sample_doc(d, &mut rng);
            }
        });
        let mut rng = Rng::new(1);
        let mut sparse = SparseLda::new(docs.clone(), vocab, k, 0.1, 0.01, &mut rng);
        for d in 0..sparse.docs.len() {
            sparse.sample_doc(d, &mut rng);
        }
        let r_sparse = bench::time_units(&format!("SparseLDA K={k}"), 1, 3, tokens as f64, || {
            for d in 0..sparse.docs.len() {
                sparse.sample_doc(d, &mut rng);
            }
        });
        let tpw = sparse.nwt.avg_topics_per_word();
        rows.push(vec![
            k.to_string(),
            format!("{:.2}", tpw),
            format!("{:.2}M", r_alias.throughput() / 1e6),
            format!("{:.2}M", r_sparse.throughput() / 1e6),
            format!("{:.2}x", r_alias.throughput() / r_sparse.throughput().max(1.0)),
        ]);
    }
    bench::table(
        &["K", "topics/word", "AliasLDA tok/s", "SparseLDA tok/s", "speedup"],
        &rows,
    );

    bench::section("all four models at K=200 (single thread)");
    let k = 200;
    let mut rows = Vec::new();
    {
        let mut rng = Rng::new(2);
        let mut s = AliasLda::new(docs.clone(), vocab, k, 0.1, 0.01, &mut rng);
        let r = bench::time_units("AliasLDA", 1, 3, tokens as f64, || {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        });
        rows.push(vec!["AliasLDA".into(), format!("{:.2}M", r.throughput() / 1e6)]);
    }
    {
        let mut rng = Rng::new(2);
        let mut s = SparseLda::new(docs.clone(), vocab, k, 0.1, 0.01, &mut rng);
        let r = bench::time_units("YahooLDA", 1, 3, tokens as f64, || {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        });
        rows.push(vec!["YahooLDA".into(), format!("{:.2}M", r.throughput() / 1e6)]);
    }
    {
        let pyp_docs = corpus(vocab, 800, 30, true);
        let pyp_tokens: usize = pyp_docs.iter().map(|d| d.len()).sum();
        let mut rng = Rng::new(2);
        let mut s = AliasPdp::new(pyp_docs, vocab, k, 0.1, 0.1, 10.0, 0.5, &mut rng);
        let r = bench::time_units("AliasPDP", 1, 2, pyp_tokens as f64, || {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        });
        rows.push(vec!["AliasPDP".into(), format!("{:.2}M", r.throughput() / 1e6)]);
    }
    {
        let mut rng = Rng::new(2);
        let mut s = AliasHdp::new(docs.clone(), vocab, k, 1.0, 1.0, 0.01, &mut rng);
        let r = bench::time_units("AliasHDP", 1, 2, tokens as f64, || {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        });
        rows.push(vec!["AliasHDP".into(), format!("{:.2}M", r.throughput() / 1e6)]);
    }
    bench::table(&["model", "tokens/s"], &rows);

    bench::section("multi-thread stash pool (§5.1): draws/s across sampling threads");
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let pool = std::sync::Arc::new(hplvm::sampler::stash::AliasPool::spawn(
            256,
            1024,
            move |w| {
                let mut rng = Rng::new(w as u64);
                (0..200).map(|_| rng.f64() + 0.01).collect()
            },
            5,
        ));
        let draws_per_thread = 400_000usize;
        let r = bench::time_units(
            &format!("{threads} threads"),
            0,
            3,
            (draws_per_thread * threads) as f64,
            || {
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        let pool = pool.clone();
                        std::thread::spawn(move || {
                            let mut acc = 0u64;
                            for i in 0..draws_per_thread {
                                acc += pool.pop(((i * 7 + t) % 256) as u32).0 as u64;
                            }
                            acc
                        })
                    })
                    .collect();
                for h in handles {
                    let _ = h.join();
                }
            },
        );
        rows.push(vec![
            threads.to_string(),
            format!("{:.1}M", r.throughput() / 1e6),
        ]);
    }
    bench::table(&["sampling threads", "draws/s"], &rows);

    bench::section("alias-table primitive (O(l) build, O(1) draw)");
    let mut rng = Rng::new(7);
    let weights: Vec<f64> = (0..2000).map(|_| rng.f64() + 1e-3).collect();
    let table = AliasTable::build(&weights);
    let r_build = bench::time_units("build l=2000", 2, 20, 2000.0, || {
        std::hint::black_box(AliasTable::build(&weights));
    });
    let r_draw = bench::time_units("draw", 1, 5, 1_000_000.0, || {
        let mut acc = 0usize;
        for _ in 0..1_000_000 {
            acc += table.sample(&mut rng);
        }
        std::hint::black_box(acc);
    });
    println!("{}", r_build.row());
    println!("{}", r_draw.row());
    println!("\nExpected shape (paper): alias throughput FLAT in K; sparse degrades as");
    println!("topics-per-word rises; absolute per-client rate near the 1M tok/s mark.");
}
