//! Communication-filter ablation (§5.3): the paper's magnitude-priority
//! + uniform-sampling filter vs sending everything. The filter trades
//! network bytes against staleness; the measurement is bytes-on-the-wire
//! and perplexity at matched iterations.

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use hplvm::ps::filter::Filter;
use std::time::Duration;

fn cfg(filter: Filter) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 100;
    cfg.corpus.n_docs = 1_600;
    cfg.corpus.vocab_size = 4_000;
    cfg.corpus.n_topics = 25;
    cfg.corpus.doc_len_mean = 40.0;
    cfg.cluster.clients = 8;
    cfg.cluster.filter = filter;
    cfg.cluster.net.base_latency = Duration::from_micros(100);
    cfg.cluster.net.jitter = Duration::from_micros(200);
    cfg.iterations = 10;
    cfg.eval_every = 5;
    cfg.test_docs = 60;
    cfg
}

fn main() {
    println!("# Table — communication filters (§5.3 ablation)");
    let variants = [
        ("send everything", Filter::default()),
        ("magnitude 50% + uniform 10%", Filter::magnitude_priority()),
        (
            "magnitude 25% + uniform 5%",
            Filter {
                magnitude_fraction: 0.25,
                uniform_prob: 0.05,
                cell_level: false,
            },
        ),
    ];
    let mut rows = Vec::new();
    for (label, filter) in variants {
        let report = Trainer::new(cfg(filter)).run().expect("train");
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", report.net.3 as f64 / (1024.0 * 1024.0)),
            report.net.0.to_string(),
            format!("{:.1}", report.final_perplexity()),
            format!("{:.3}", report.steady_state_iter_secs()),
        ]);
    }
    bench::table(
        &["filter", "MiB on wire", "messages", "perplexity", "iter(s)"],
        &rows,
    );
    println!("\nExpected shape (§5.3): the filter cuts wire volume materially while the");
    println!("uniform-sampling rescue keeps perplexity within noise of send-everything");
    println!("at matched iterations (retained rows are re-queued, not lost).");
}
