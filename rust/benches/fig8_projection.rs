//! Figure 8: HDP with vs without projection — the ablation showing why
//! §5.5 exists. Without corrections the shared table/count statistics
//! drift out of the model's polytope under relaxed consistency and the
//! perplexity estimate degrades/diverges; with Algorithm 2 it converges.
//! An aggressive transport (drops + latency) makes the conflicts frequent
//! like a 200-client production run.

use hplvm::bench;
use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn cfg(model: ModelKind, projection: ProjectionMode) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model;
    cfg.params.topics = 80;
    cfg.corpus.n_docs = 1_600;
    cfg.corpus.vocab_size = 3_000;
    cfg.corpus.n_topics = 20;
    cfg.corpus.doc_len_mean = 40.0;
    if model == ModelKind::AliasPdp {
        cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
    }
    cfg.cluster.clients = 8;
    // Hostile consistency regime: real drops and latency.
    cfg.cluster.net.base_latency = Duration::from_micros(300);
    cfg.cluster.net.jitter = Duration::from_micros(700);
    cfg.cluster.net.drop_prob = 0.08;
    cfg.projection = projection;
    cfg.iterations = 12;
    cfg.eval_every = 3;
    cfg.test_docs = 60;
    cfg
}

fn run_panel(model: ModelKind) {
    println!("\n## {} — 8 clients, with vs without projection", model.name());
    let mut curves = Vec::new();
    for (label, mode) in [
        ("with projection (Alg 2)", ProjectionMode::Distributed),
        ("WITHOUT projection", ProjectionMode::Off),
    ] {
        let report = Trainer::new(cfg(model, mode)).run().expect("train");
        let curve: Vec<(u64, f64, f64)> = report
            .per_iteration
            .iter()
            .filter(|r| r.perplexity.count() > 0)
            .map(|r| (r.iteration, r.perplexity.mean(), r.perplexity.std()))
            .collect();
        println!(
            "\n-- {label}: corrections={} final={:.1} --",
            report.corrections,
            report.final_perplexity()
        );
        curves.push((label, curve, report.final_perplexity()));
    }
    bench::section("perplexity curves");
    let max_len = curves.iter().map(|(_, c, _)| c.len()).max().unwrap_or(0);
    let mut rows = Vec::new();
    for i in 0..max_len {
        let mut row = vec![curves[0].1.get(i).map(|c| c.0.to_string()).unwrap_or_default()];
        for (_, curve, _) in &curves {
            row.push(
                curve
                    .get(i)
                    .map(|c| format!("{:.1} ±{:.1}", c.1, c.2))
                    .unwrap_or_default(),
            );
        }
        rows.push(row);
    }
    bench::table(&["iter", "with projection", "without projection"], &rows);
    let with = curves[0].2;
    let without = curves[1].2;
    println!(
        "\nfinal: with={with:.1} without={without:.1} (ratio {:.2}x)",
        without / with
    );
}

fn main() {
    println!("# Figure 8 — with vs without projection (paper: HDP @ 200 clients)");
    // The paper's panel is HDP; we also run PDP, whose word-level
    // (s_tw ≤ m_tw) polytope is hit by *every* conflicting update and
    // shows the mechanism's work most clearly.
    run_panel(ModelKind::AliasHdp);
    run_panel(ModelKind::AliasPdp);
    println!("\nExpected shape (paper Fig 8): the no-projection run converges slower and/or");
    println!("diverges; the projected run is strictly better at matched iterations. In this");
    println!("repro the HDP document-side tables are repaired locally by construction, so");
    println!("the separation is strongest on PDP's shared word-level polytope.");
}
