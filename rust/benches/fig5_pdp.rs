//! Figure 5: PDP (Pitman-Yor topic model) on 200 clients — scaled to 8.
//!
//! Power-law (PYP-generated) corpus; the converging perplexity curve
//! demonstrates the system handles the constrained two-matrix sufficient
//! statistics (m_tw, s_tw); the paper notes "without corrections, we
//! observed diverging values" — the correction mechanism here is
//! Algorithm 2 (distributed projection), the paper's reported choice.

use hplvm::bench;
use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use hplvm::corpus::generator::GenerativeModel;
use std::time::Duration;

fn main() {
    println!("# Figure 5 — AliasPDP on 8 clients (paper: 200)");
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasPdp;
    cfg.params.topics = 100;
    cfg.params.pdp_discount = 0.1;
    cfg.params.pdp_concentration = 10.0;
    cfg.corpus.model = GenerativeModel::Pyp;
    cfg.corpus.n_docs = 2_000;
    cfg.corpus.vocab_size = 4_000;
    cfg.corpus.n_topics = 25;
    cfg.corpus.doc_len_mean = 40.0;
    cfg.cluster.clients = 8;
    cfg.cluster.net.base_latency = Duration::from_micros(100);
    cfg.cluster.net.jitter = Duration::from_micros(200);
    cfg.cluster.net.drop_prob = 0.01;
    cfg.projection = ProjectionMode::Distributed;
    cfg.iterations = 12;
    cfg.eval_every = 4;
    cfg.test_docs = 60;

    let report = Trainer::new(cfg).run().expect("train");
    bench::section("per-iteration panels (perplexity / topics-per-word / time / datapoints)");
    let mut rows = Vec::new();
    for r in &report.per_iteration {
        rows.push(vec![
            r.iteration.to_string(),
            if r.perplexity.count() > 0 {
                format!("{:.1} ±{:.1}", r.perplexity.mean(), r.perplexity.std())
            } else {
                "-".into()
            },
            format!("{:.2}", r.topics_per_word.mean()),
            format!("{:.3} ±{:.3}", r.time.mean(), r.time.std()),
            r.datapoints.to_string(),
        ]);
    }
    bench::table(&["iter", "perplexity", "topics/word", "time(s)", "n"], &rows);
    println!(
        "\nfinal perplexity {:.1} | corrections {} | throughput {:.0} tokens/s",
        report.final_perplexity(),
        report.corrections,
        report.tokens_per_sec
    );
    println!("Expected shape (paper Fig 5): perplexity decreases and stabilizes; the");
    println!("correction count is non-zero (relaxed consistency does create conflicts).");
}
