//! Deterministic end-to-end *training* bench. Prints a summary table AND
//! writes `BENCH_train.json` at the repository root so the repo carries a
//! machine-readable training-perf trajectory across PRs, next to
//! `BENCH_sampler.json`:
//!
//! * whole-cluster tokens/sec, wall seconds, and final perplexity for a
//!   fixed seeded LDA and PDP config through `Trainer::run`, and
//! * the session lifecycle costs: checkpoint seconds (acknowledged
//!   cluster snapshot) and resume seconds (fresh topology from disk),
//!   plus the incremental-checkpoint byte panel: segment bytes written
//!   by the first (full base) checkpoint vs. by an immediate second
//!   one (carried by hardlink — the v4 store's O(rows changed) claim
//!   in numbers).
//!
//! Regenerate with `cargo bench --bench train_json`.

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::session::TrainSession;
use hplvm::coordinator::trainer::Trainer;
use hplvm::corpus::source::SyntheticSource;
use hplvm::util::json::Json;
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

/// Segment files in a checkpoint dir: name → byte length. Carried
/// segments keep their names across checkpoints, so bytes under names
/// *not* present in the previous checkpoint are the bytes this
/// checkpoint actually wrote.
fn seg_files(dir: &Path) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if hplvm::ps::snapshot::is_segment_name(&name) {
                if let Ok(md) = entry.metadata() {
                    out.insert(name, md.len());
                }
            }
        }
    }
    out
}

fn cfg(model: ModelKind) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model;
    cfg.params.topics = 16;
    cfg.corpus.n_docs = 400;
    cfg.corpus.vocab_size = 1_000;
    cfg.corpus.n_topics = 16;
    cfg.corpus.doc_len_mean = 30.0;
    cfg.cluster.clients = 3;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(50);
    cfg.iterations = 10;
    cfg.eval_every = 5;
    cfg.test_docs = 50;
    cfg.seed = 7;
    cfg.corpus.seed = 7;
    if model == ModelKind::AliasPdp {
        cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
    }
    cfg
}

fn main() {
    println!("# End-to-end training trajectory (BENCH_train.json)");

    let mut panels: Vec<(&str, f64, f64, f64)> = Vec::new();
    for model in [ModelKind::AliasLda, ModelKind::AliasPdp] {
        let report = Trainer::new(cfg(model)).run().expect("train");
        panels.push((
            model.name(),
            report.tokens_per_sec,
            report.wall_secs,
            report.final_perplexity(),
        ));
    }
    bench::section("whole-cluster training (3 clients, 10 iterations)");
    bench::table(
        &["model", "tokens/s", "wall s", "perplexity"],
        &panels
            .iter()
            .map(|(m, tps, wall, perp)| {
                vec![
                    m.to_string(),
                    format!("{tps:.0}"),
                    format!("{wall:.2}"),
                    format!("{perp:.1}"),
                ]
            })
            .collect::<Vec<_>>(),
    );

    // Session lifecycle: segment → checkpoint → resume → segment.
    let ckpt = std::env::temp_dir().join(format!("hplvm_bench_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt).ok();
    let c = cfg(ModelKind::AliasLda);
    let src = SyntheticSource::new(c.corpus.clone());
    let mut session = TrainSession::start(c, &src).expect("start");
    session.run_to(5).expect("segment 1");
    let t = Instant::now();
    session.checkpoint(&ckpt).expect("checkpoint");
    let checkpoint_secs = t.elapsed().as_secs_f64();
    // Incremental-checkpoint byte panel: an immediate second checkpoint
    // carries every segment forward and should write ≈0 new bytes.
    let ckpt2 = std::env::temp_dir().join(format!("hplvm_bench_ckpt2_{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt2).ok();
    let first = seg_files(&ckpt);
    let first_bytes: u64 = first.values().sum();
    session.checkpoint(&ckpt2).expect("second checkpoint");
    let second_bytes: u64 = seg_files(&ckpt2)
        .iter()
        .filter(|(name, _)| !first.contains_key(*name))
        .map(|(_, len)| len)
        .sum();
    std::fs::remove_dir_all(&ckpt2).ok();
    let _ = session.finish().expect("finish");
    let t = Instant::now();
    let mut resumed = TrainSession::resume(&ckpt).expect("resume");
    let resume_secs = t.elapsed().as_secs_f64();
    resumed.run_to(10).expect("segment 2");
    let resumed_perp = resumed.finish().expect("finish").final_perplexity();
    std::fs::remove_dir_all(&ckpt).ok();
    bench::section("session lifecycle");
    bench::table(
        &[
            "checkpoint s",
            "resume s",
            "resumed perplexity",
            "ckpt1 seg bytes",
            "ckpt2 new bytes",
        ],
        &[vec![
            format!("{checkpoint_secs:.3}"),
            format!("{resume_secs:.3}"),
            format!("{resumed_perp:.1}"),
            format!("{first_bytes}"),
            format!("{second_bytes}"),
        ]],
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("train_json".into())),
        (
            "regenerate",
            Json::Str("cargo bench --bench train_json".into()),
        ),
        (
            "config",
            Json::obj(vec![
                ("n_docs", Json::Num(400.0)),
                ("vocab", Json::Num(1_000.0)),
                ("k", Json::Num(16.0)),
                ("clients", Json::Num(3.0)),
                ("iterations", Json::Num(10.0)),
            ]),
        ),
        (
            "models",
            Json::Arr(
                panels
                    .iter()
                    .map(|(m, tps, wall, perp)| {
                        Json::obj(vec![
                            ("model", Json::Str((*m).into())),
                            ("tokens_per_sec", Json::Num(*tps)),
                            ("wall_secs", Json::Num(*wall)),
                            ("final_perplexity", Json::Num(*perp)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "session",
            Json::obj(vec![
                ("checkpoint_secs", Json::Num(checkpoint_secs)),
                ("resume_secs", Json::Num(resume_secs)),
                ("resumed_final_perplexity", Json::Num(resumed_perp)),
                ("checkpoint_segment_bytes_first", Json::Num(first_bytes as f64)),
                ("checkpoint_segment_bytes_second", Json::Num(second_bytes as f64)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train.json");
    match std::fs::write(path, format!("{json}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
