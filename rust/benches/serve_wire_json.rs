//! Wire-serving throughput bench. Spins up the framed-protocol reactor
//! server ([`hplvm::net::WireServer`]) over a synthetic in-memory model
//! on loopback TCP, drives it with the load generator at 1, 8, and 64
//! concurrent connections (closed loop), prints a summary table, AND
//! writes `BENCH_serve_wire.json` at the repository root so the repo
//! carries a machine-readable wire-serving trajectory across PRs, next
//! to `BENCH_train.json` and `BENCH_sampler.json`.
//!
//! Regenerate with `cargo bench --bench serve_wire_json`.

use hplvm::bench;
use hplvm::net::{loadgen, ListenAddr, LoadgenConfig, ModelInfo, WireConfig, WireServer};
use hplvm::ps::snapshot::{SnapshotMeta, Store};
use hplvm::serve::{ServingHandle, ServingModel};
use hplvm::util::json::Json;

const VOCAB: u32 = 5_000;
const K: u32 = 64;
const REACTORS: usize = 2;
const DOC_LEN: f64 = 24.0;
const TOTAL_REQUESTS: usize = 2_048;

/// Synthetic frozen statistics: every word observed, mass concentrated
/// on a couple of topics per word so the alias tables are non-trivial.
fn synthetic_model() -> ServingModel {
    let mut store = Store::new();
    for w in 0..VOCAB {
        let mut row = vec![0i32; K as usize];
        row[(w % K) as usize] = 40 + (w % 13) as i32;
        row[((w / 7) % K) as usize] += 15;
        store.insert((0, w), row.into());
    }
    let meta = SnapshotMeta {
        model: "AliasLDA".to_string(),
        k: K,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: VOCAB,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0,
        tables: None,
    };
    ServingModel::from_stores(meta, vec![store], 64 << 20).expect("synthetic model")
}

struct Panel {
    connections: usize,
    requests_per_conn: usize,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    max_ms: f64,
    server_p50_ms: f64,
    server_p99_ms: f64,
    errors: u64,
}

fn main() {
    println!("# Wire-serving throughput (BENCH_serve_wire.json)");

    let handle = ServingHandle::from_model(synthetic_model());
    let info = ModelInfo {
        family: handle.model().kind().family_name().to_string(),
        k: K,
        vocab: VOCAB,
    };
    let server = WireServer::start(
        handle.clone(),
        info,
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig {
            reactors: REACTORS,
            ..WireConfig::default()
        },
    )
    .expect("wire server");
    let addr = server.local_addr().to_string();

    let mut panels = Vec::new();
    for connections in [1usize, 8, 64] {
        let requests = (TOTAL_REQUESTS / connections).max(16);
        // One warm-up pass populates the alias cache so every panel
        // measures the steady state, not the first panel's cold builds.
        let lg = LoadgenConfig {
            connections,
            requests,
            window: 4,
            vocab: VOCAB as usize,
            doc_len: DOC_LEN,
            seed: 42 + connections as u64,
            ..LoadgenConfig::default()
        };
        if connections == 1 {
            loadgen::run(&addr, &lg).expect("warm-up");
        }
        let report = loadgen::run(&addr, &lg).expect("loadgen");
        assert_eq!(
            report.answered as usize,
            connections * requests,
            "bench run dropped requests ({} errors, {} timed out)",
            report.errors,
            report.timed_out,
        );
        panels.push(Panel {
            connections,
            requests_per_conn: requests,
            qps: report.qps,
            p50_ms: report.p50_ms,
            p99_ms: report.p99_ms,
            max_ms: report.max_ms,
            server_p50_ms: report.server_p50_ms,
            server_p99_ms: report.server_p99_ms,
            errors: report.errors,
        });
    }
    server.shutdown();

    bench::section(&format!(
        "wire serving, {REACTORS} reactors, V={VOCAB} K={K}, closed loop (window 4)"
    ));
    bench::table(
        &["conns", "reqs/conn", "qps", "p50 ms", "p99 ms", "max ms"],
        &panels
            .iter()
            .map(|p| {
                vec![
                    p.connections.to_string(),
                    p.requests_per_conn.to_string(),
                    format!("{:.0}", p.qps),
                    format!("{:.3}", p.p50_ms),
                    format!("{:.3}", p.p99_ms),
                    format!("{:.3}", p.max_ms),
                ]
            })
            .collect::<Vec<_>>(),
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("serve_wire_json".into())),
        (
            "regenerate",
            Json::Str("cargo bench --bench serve_wire_json".into()),
        ),
        (
            "config",
            Json::obj(vec![
                ("vocab", Json::Num(VOCAB as f64)),
                ("k", Json::Num(K as f64)),
                ("reactors", Json::Num(REACTORS as f64)),
                ("doc_len_mean", Json::Num(DOC_LEN)),
                ("window", Json::Num(4.0)),
            ]),
        ),
        (
            "panels",
            Json::Arr(
                panels
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("connections", Json::Num(p.connections as f64)),
                            (
                                "requests_per_conn",
                                Json::Num(p.requests_per_conn as f64),
                            ),
                            ("qps", Json::Num(p.qps)),
                            ("p50_ms", Json::Num(p.p50_ms)),
                            ("p99_ms", Json::Num(p.p99_ms)),
                            ("max_ms", Json::Num(p.max_ms)),
                            ("server_p50_ms", Json::Num(p.server_p50_ms)),
                            ("server_p99_ms", Json::Num(p.server_p99_ms)),
                            ("errors", Json::Num(p.errors as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_wire.json");
    match std::fs::write(path, format!("{json}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
