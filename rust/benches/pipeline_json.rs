//! Deterministic streaming-pipeline bench. Prints a summary table AND
//! writes `BENCH_pipeline.json` at the repository root so the repo
//! carries a machine-readable train-while-serve trajectory across PRs,
//! next to `BENCH_train.json`:
//!
//! * **ingest rate** — documents/second through the full loop (stream →
//!   live session → sweeps → checkpoints), overall and per batch;
//! * **freshness lag** — p50/p99 of the per-batch ingested-minus-
//!   servable document gap, plus the peak and the final (must-be-zero)
//!   value;
//! * **reload cadence** — serving reloads performed, seconds between
//!   them, and the distinct generations the query load observed, with
//!   the zero-drop query counters alongside.
//!
//! Regenerate with `cargo bench --bench pipeline_json`.

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::corpus::generator::CorpusConfig;
use hplvm::corpus::source::write_docword;
use hplvm::corpus::stream::StreamingSource;
use hplvm::pipeline::{Pipeline, PipelineConfig};
use hplvm::util::json::Json;
use std::time::Duration;

const N_DOCS: usize = 600;
const VOCAB: usize = 500;
const CHUNK_DOCS: usize = 80;

fn train_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 12;
    cfg.cluster.clients = 2;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(50);
    cfg.iterations = 8;
    cfg.eval_every = 4;
    cfg.test_docs = 20;
    cfg.seed = 11;
    cfg.cluster.net.seed = 11 ^ 0x7EA7;
    cfg
}

fn main() {
    println!("# Streaming train-while-serve pipeline (BENCH_pipeline.json)");

    // One seeded corpus, streamed from disk in bounded chunks.
    let mut gen = CorpusConfig::default();
    gen.n_docs = N_DOCS;
    gen.vocab_size = VOCAB;
    gen.n_topics = 12;
    gen.doc_len_mean = 16.0;
    gen.seed = 11;
    let (corpus, _vocab) = gen.generate();
    let dir = std::env::temp_dir().join(format!("hplvm_bench_pipeline_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("bench tmpdir");
    let docword = dir.join("docword.bench.txt");
    write_docword(&docword, &corpus).expect("write docword");

    let mut cfg = PipelineConfig::new(train_cfg(), dir.join("ckpt"));
    cfg.checkpoint_every_batches = 2;
    cfg.replicas = 2;
    cfg.query_interval = Duration::from_millis(1);
    cfg.warmup_sweeps = 4;

    let mut stream = StreamingSource::open(&docword, CHUNK_DOCS).expect("open stream");
    let report = Pipeline::run(cfg, &mut stream).expect("pipeline run");
    std::fs::remove_dir_all(&dir).ok();

    let lags: Vec<f64> = report.samples.iter().map(|s| s.freshness_lag as f64).collect();
    let rates: Vec<f64> = report
        .samples
        .iter()
        .filter(|s| s.ingest_docs_per_sec > 0.0)
        .map(|s| s.ingest_docs_per_sec)
        .collect();
    let lag_p50 = bench::percentile(&lags, 50.0);
    let lag_p99 = bench::percentile(&lags, 99.0);
    let reload_cadence_secs = report.wall_secs / report.reloads.max(1) as f64;

    bench::section("streaming ingest + online train-while-serve");
    bench::table(
        &[
            "docs", "batches", "ingest docs/s", "lag p50", "lag p99", "reloads",
            "cadence s", "gens", "queries", "perplexity",
        ],
        &[vec![
            format!("{}", report.docs_streamed),
            format!("{}", report.batches),
            format!("{:.0}", report.ingest_docs_per_sec()),
            format!("{lag_p50:.0}"),
            format!("{lag_p99:.0}"),
            format!("{}", report.reloads),
            format!("{reload_cadence_secs:.2}"),
            format!("{}", report.generations_observed.len()),
            format!("{}/{}", report.queries_answered, report.queries_sent),
            format!("{:.1}", report.final_perplexity),
        ]],
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("pipeline_json".into())),
        (
            "regenerate",
            Json::Str("cargo bench --bench pipeline_json".into()),
        ),
        (
            "config",
            Json::obj(vec![
                ("n_docs", Json::Num(N_DOCS as f64)),
                ("vocab", Json::Num(VOCAB as f64)),
                ("chunk_docs", Json::Num(CHUNK_DOCS as f64)),
                ("k", Json::Num(12.0)),
                ("clients", Json::Num(2.0)),
                ("checkpoint_every_batches", Json::Num(2.0)),
            ]),
        ),
        (
            "ingest",
            Json::obj(vec![
                ("docs_per_sec", Json::Num(report.ingest_docs_per_sec())),
                ("batch_docs_per_sec_p50", Json::Num(bench::percentile(&rates, 50.0))),
                ("docs_streamed", Json::Num(report.docs_streamed as f64)),
                ("peak_chunk_docs", Json::Num(report.peak_chunk_docs as f64)),
                ("wall_secs", Json::Num(report.wall_secs)),
            ]),
        ),
        (
            "freshness_lag_docs",
            Json::obj(vec![
                ("p50", Json::Num(lag_p50)),
                ("p99", Json::Num(lag_p99)),
                ("peak", Json::Num(report.peak_lag() as f64)),
                ("final", Json::Num(report.final_lag() as f64)),
            ]),
        ),
        (
            "serving",
            Json::obj(vec![
                ("reloads", Json::Num(report.reloads as f64)),
                ("reload_cadence_secs", Json::Num(reload_cadence_secs)),
                (
                    "generations_observed",
                    Json::Num(report.generations_observed.len() as f64),
                ),
                ("queries_sent", Json::Num(report.queries_sent as f64)),
                ("queries_answered", Json::Num(report.queries_answered as f64)),
            ]),
        ),
        ("final_perplexity", Json::Num(report.final_perplexity)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
    match std::fs::write(path, format!("{json}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
