//! Serving throughput: queries/second and latency percentiles of the
//! snapshot-backed inference service.
//!
//! Four panels:
//! * pool-shape sweep on an LDA snapshot (workers × micro-batch),
//! * warm vs budget-starved alias cache (the §3.1 amortization argument
//!   on the serving path),
//! * **replica scale-out** — the same service loop over a
//!   [`ReplicaSet`] of 1/2/4 vocabulary slices: the consistent-hash
//!   router scatters each query's words, every replica serves from its
//!   own alias cache, and answers stay bit-identical to 1-replica,
//! * **family sweep** — the same service loop against LDA, PDP, and HDP
//!   snapshots, now that the [`ServingFamily`] abstraction serves all
//!   three: PDP pays the Pitman-Yor predictive (two matrices) per table
//!   build, HDP pays the root-stick prior weighting.
//!
//! [`ServingFamily`]: hplvm::serve::ServingFamily
//! [`ReplicaSet`]: hplvm::serve::ReplicaSet

use hplvm::bench;
use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;
use hplvm::serve::{
    run_queries, synth_queries, InferenceService, QueryBackend, ReplicaSet, ServeConfig,
    ServingHandle,
};
use std::sync::Arc;

/// Run `queries` through a fresh service over any backend; returns
/// (qps, p50 ms, p99 ms, realized batch size).
fn drive(
    backend: Arc<dyn QueryBackend>,
    queries: &[Vec<u32>],
    workers: usize,
    max_batch: usize,
) -> (f64, f64, f64, f64) {
    let svc = InferenceService::spawn(
        backend,
        ServeConfig {
            workers,
            max_batch,
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let latencies = run_queries(&svc, queries, 256);
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    svc.shutdown();
    (
        latencies.len() as f64 / wall.max(1e-9),
        bench::percentile(&latencies, 50.0) * 1e3,
        bench::percentile(&latencies, 99.0) * 1e3,
        stats.served as f64 / stats.batches.max(1) as f64,
    )
}

/// Train `cfg` into a fresh snapshot dir and load it behind a handle.
fn trained_handle(cfg: &TrainConfig, tag: &str) -> (Arc<ServingHandle>, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "hplvm_serve_bench_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = cfg.clone();
    cfg.cluster.snapshot_dir = Some(dir.clone());
    let t0 = std::time::Instant::now();
    let report = Trainer::new(cfg.clone()).run().expect("training failed");
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load failed");
    println!(
        "trained {} in {:.1}s (final perplexity {:.1}); loaded generation {}",
        cfg.model.name(),
        t0.elapsed().as_secs_f64(),
        report.final_perplexity(),
        handle.generation(),
    );
    (handle, dir)
}

fn main() {
    println!("# Serving throughput — snapshot-backed topic inference");

    bench::section("snapshot production (20-iteration small_lda)");
    let mut lda_cfg = TrainConfig::small_lda();
    lda_cfg.iterations = 20;
    let (lda, lda_dir) = trained_handle(&lda_cfg, "lda");
    {
        let model = lda.model();
        println!(
            "loaded: K={} vocab={} frozen tokens={}",
            model.k(),
            model.vocab(),
            model.total_tokens()
        );
    }

    let queries = synth_queries(lda.model().vocab(), 4_000, 32.0, 7);

    bench::section("pool shape sweep (queries/s, latency in ms)");
    let mut rows = Vec::new();
    // Prime the alias cache so the shapes compete on pool mechanics, not
    // first-touch table builds.
    drive(lda.clone(), &queries[..500.min(queries.len())], 2, 32);
    for &(workers, batch) in &[(1usize, 1usize), (1, 32), (2, 32), (4, 32), (4, 128)] {
        let (qps, p50, p99, realized) = drive(lda.clone(), &queries, workers, batch);
        rows.push(vec![
            workers.to_string(),
            batch.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{realized:.1}"),
        ]);
    }
    bench::table(
        &["workers", "max batch", "queries/s", "p50 ms", "p99 ms", "avg batch"],
        &rows,
    );
    let cache = lda.model().cache_stats();
    println!(
        "alias cache after sweep: {} resident, {} hits / {} misses / {} evictions",
        cache.resident, cache.hits, cache.misses, cache.evictions
    );

    bench::section("alias-cache amortization (64 MiB budget vs starved)");
    let starved = ServingHandle::load_dir_with_budget(&lda_dir, 1).expect("snapshot load failed");
    let mut rows = Vec::new();
    for (name, h) in [("warm 64 MiB", &lda), ("starved (~1 table/shard)", &starved)] {
        let (qps, p50, p99, _) = drive(h.clone(), &queries[..1_000.min(queries.len())], 2, 32);
        rows.push(vec![
            name.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    bench::table(&["cache", "queries/s", "p50 ms", "p99 ms"], &rows);

    bench::section("replica scale-out (consistent-hash router, per-replica alias caches)");
    let vocab = lda.model().vocab();
    let mut rows = Vec::new();
    for &replicas in &[1usize, 2, 4] {
        let set = ReplicaSet::load_dir(&lda_dir, replicas).expect("replica-set load failed");
        let spread = set.router().spread(vocab);
        // Warm each replica's cache, then measure the routed loop.
        drive(set.clone(), &queries[..500.min(queries.len())], 4, 32);
        let (qps, p50, p99, _) = drive(set.clone(), &queries, 4, 32);
        rows.push(vec![
            replicas.to_string(),
            format!("{spread:?}"),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    bench::table(
        &["replicas", "words/replica", "queries/s", "p50 ms", "p99 ms"],
        &rows,
    );
    std::fs::remove_dir_all(&lda_dir).ok();

    bench::section("family sweep (same service loop, per-family φ)");
    // Smaller runs: the panel compares serving cost, not training quality.
    let mut pdp_cfg = TrainConfig::small_pdp();
    pdp_cfg.corpus.n_docs = 400;
    pdp_cfg.iterations = 10;
    let mut hdp_cfg = TrainConfig::small_hdp();
    hdp_cfg.corpus.n_docs = 400;
    hdp_cfg.iterations = 10;
    let mut lda_small = TrainConfig::small_lda();
    lda_small.corpus.n_docs = 400;
    lda_small.iterations = 10;
    let mut rows = Vec::new();
    for (tag, cfg) in [
        ("lda_fam", lda_small),
        ("pdp_fam", pdp_cfg),
        ("hdp_fam", hdp_cfg),
    ] {
        let (handle, dir) = trained_handle(&cfg, tag);
        let queries = synth_queries(handle.model().vocab(), 2_000, 32.0, 7);
        // Warm pass primes each family's alias cache, then measure.
        drive(handle.clone(), &queries[..400.min(queries.len())], 2, 32);
        let (qps, p50, p99, _) = drive(handle.clone(), &queries, 2, 32);
        rows.push(vec![
            handle.model().meta().model.clone(),
            format!("{}", handle.model().k()),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
        std::fs::remove_dir_all(&dir).ok();
    }
    bench::table(&["family", "K", "queries/s", "p50 ms", "p99 ms"], &rows);

    println!(
        "\nExpected shape: batching lifts queries/s at equal worker count; the\n\
         starved cache pays an O(K) table rebuild per (word, query) and falls\n\
         behind; replicas split the resident-table footprint ~evenly and keep\n\
         per-replica caches contention-free (in one process the scatter adds\n\
         a small constant, on real machines it is what caps vocab × K);\n\
         PDP/HDP serve within the same order of magnitude as LDA — the\n\
         family only changes how a cached table is *built*, not how it is\n\
         consumed."
    );
}
