//! Serving throughput: queries/second and latency percentiles of the
//! snapshot-backed inference service, against a snapshot produced by a
//! 20-iteration `small_lda` training run.
//!
//! Sweeps the worker-pool and micro-batch shape, and contrasts a warm
//! alias cache with a budget-starved one (every query rebuilds tables) —
//! the serving-side analogue of the paper's amortization argument (§3.1).

use hplvm::bench;
use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;
use hplvm::serve::{run_queries, synth_queries, InferenceService, ServeConfig, ServingModel};
use std::sync::Arc;

/// Run `queries` through a fresh service; returns (qps, p50 ms, p99 ms,
/// realized batch size).
fn drive(
    model: &Arc<ServingModel>,
    queries: &[Vec<u32>],
    workers: usize,
    max_batch: usize,
) -> (f64, f64, f64, f64) {
    let svc = InferenceService::spawn(
        model.clone(),
        ServeConfig {
            workers,
            max_batch,
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let latencies = run_queries(&svc, queries, 256);
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    svc.shutdown();
    (
        latencies.len() as f64 / wall.max(1e-9),
        bench::percentile(&latencies, 50.0) * 1e3,
        bench::percentile(&latencies, 99.0) * 1e3,
        stats.served as f64 / stats.batches.max(1) as f64,
    )
}

fn main() {
    println!("# Serving throughput — snapshot-backed topic inference");

    bench::section("snapshot production (20-iteration small_lda)");
    let snapdir = std::env::temp_dir().join(format!("hplvm_serve_bench_{}", std::process::id()));
    let mut cfg = TrainConfig::small_lda();
    cfg.iterations = 20;
    cfg.cluster.snapshot_dir = Some(snapdir.clone());
    let t0 = std::time::Instant::now();
    let report = Trainer::new(cfg.clone()).run().expect("training failed");
    println!(
        "trained {} in {:.1}s (final perplexity {:.1}); snapshots in {}",
        cfg.model.name(),
        t0.elapsed().as_secs_f64(),
        report.final_perplexity(),
        snapdir.display()
    );
    let model =
        Arc::new(ServingModel::load_dir(&snapdir).expect("snapshot load failed"));
    println!(
        "loaded: K={} vocab={} frozen tokens={}",
        model.k(),
        model.vocab(),
        model.total_tokens()
    );

    let queries = synth_queries(model.vocab(), 4_000, 32.0, 7);

    bench::section("pool shape sweep (queries/s, latency in ms)");
    let mut rows = Vec::new();
    // Prime the alias cache so the shapes compete on pool mechanics, not
    // first-touch table builds.
    drive(&model, &queries[..500.min(queries.len())], 2, 32);
    for &(workers, batch) in &[(1usize, 1usize), (1, 32), (2, 32), (4, 32), (4, 128)] {
        let (qps, p50, p99, realized) = drive(&model, &queries, workers, batch);
        rows.push(vec![
            workers.to_string(),
            batch.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{realized:.1}"),
        ]);
    }
    bench::table(
        &["workers", "max batch", "queries/s", "p50 ms", "p99 ms", "avg batch"],
        &rows,
    );
    let cache = model.cache_stats();
    println!(
        "alias cache after sweep: {} resident, {} hits / {} misses / {} evictions",
        cache.resident, cache.hits, cache.misses, cache.evictions
    );

    bench::section("alias-cache amortization (64 MiB budget vs starved)");
    let starved = Arc::new(
        ServingModel::load_dir_with_budget(&snapdir, 1).expect("snapshot load failed"),
    );
    let mut rows = Vec::new();
    for (name, m) in [("warm 64 MiB", &model), ("starved (~1 table/shard)", &starved)] {
        let (qps, p50, p99, _) = drive(m, &queries[..1_000.min(queries.len())], 2, 32);
        rows.push(vec![
            name.to_string(),
            format!("{qps:.0}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    bench::table(&["cache", "queries/s", "p50 ms", "p99 ms"], &rows);

    println!(
        "\nExpected shape: batching lifts queries/s at equal worker count; the\n\
         starved cache pays an O(K) table rebuild per (word, query) and falls\n\
         behind — the §3.1 amortization argument, now on the serving path."
    );
    std::fs::remove_dir_all(&snapdir).ok();
}
