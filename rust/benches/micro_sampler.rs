//! Microbenchmarks of the §3 machinery: alias draw vs linear categorical
//! scan (the O(1) vs O(k) claim), MH acceptance rate vs proposal
//! staleness (why a handful of MH steps suffice), and Stirling table
//! build cost (the PDP arithmetic is precomputable).

use hplvm::bench;
use hplvm::sampler::alias::AliasTable;
use hplvm::sampler::mh::mh_chain;
use hplvm::sampler::stirling::StirlingTable;
use hplvm::util::rng::Rng;

fn main() {
    println!("# Microbenches — Metropolis-Hastings-Walker machinery (§3)");

    bench::section("draw cost: alias O(1) vs linear-scan O(k)");
    let mut rows = Vec::new();
    for k in [64usize, 256, 1024, 4096] {
        let mut rng = Rng::new(1);
        let weights: Vec<f64> = (0..k).map(|_| rng.f64() + 1e-3).collect();
        let table = AliasTable::build(&weights);
        let n = 1_000_000usize;
        let r_alias = bench::time_units(&format!("alias k={k}"), 1, 5, n as f64, || {
            let mut acc = 0usize;
            for _ in 0..n {
                acc += table.sample(&mut rng);
            }
            std::hint::black_box(acc);
        });
        let n_lin = 100_000usize;
        let r_linear = bench::time_units(&format!("linear k={k}"), 1, 3, n_lin as f64, || {
            let mut acc = 0usize;
            for _ in 0..n_lin {
                acc += rng.categorical(&weights);
            }
            std::hint::black_box(acc);
        });
        rows.push(vec![
            k.to_string(),
            format!("{:.1}M/s", r_alias.throughput() / 1e6),
            format!("{:.2}M/s", r_linear.throughput() / 1e6),
            format!("{:.1}x", r_alias.throughput() / r_linear.throughput().max(1.0)),
        ]);
    }
    bench::table(&["k", "alias draws", "linear draws", "speedup"], &rows);

    bench::section("MH acceptance vs staleness (drifted proposal, 2-step chain)");
    let mut rows = Vec::new();
    let k = 512;
    for drift in [0.0f64, 0.1, 0.5, 1.0, 2.0] {
        let mut rng = Rng::new(3);
        let p: Vec<f64> = (0..k).map(|_| rng.f64() + 0.01).collect();
        // q = p perturbed multiplicatively by exp(drift * normal).
        let q: Vec<f64> = p
            .iter()
            .map(|&x| x * (drift * rng.normal()).exp())
            .collect();
        let table = AliasTable::build(&q);
        let mut accepted = 0usize;
        let trials = 50_000;
        let mut state = None;
        for _ in 0..trials {
            let (s, acc) = mh_chain(
                state,
                2,
                |r| {
                    let j = table.sample(r);
                    (j, q[j])
                },
                |i| q[i],
                |i| p[i],
                &mut rng,
            );
            state = Some(s);
            accepted += acc;
        }
        rows.push(vec![
            format!("{drift:.1}"),
            format!("{:.1}%", 100.0 * accepted as f64 / (trials * 2) as f64),
        ]);
    }
    bench::table(&["staleness (log-drift σ)", "acceptance"], &rows);

    bench::section("generalized Stirling table build (log-space)");
    for n in [256usize, 1024, 4096] {
        let r = bench::time_fn(&format!("build N={n}, a=0.1"), 1, 5, || {
            std::hint::black_box(StirlingTable::new(0.1, n));
        });
        println!("{}", r.row());
    }
    println!("\nExpected shape: alias draw rate independent of k (linear scan degrades");
    println!("~1/k); acceptance stays high until the proposal is badly stale — the");
    println!("rebuild-every-K schedule keeps drift in the top rows of this table.");
}
