//! Figure 6: the largest LDA run — 5B documents / 60k cores in the paper,
//! scaled to the largest corpus this harness runs (32 clients). The
//! reported metric is document log-likelihood over iterations with its
//! cross-client variance; "small variation across the mean likelihood
//! implies proper synchronization across clients".

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn main() {
    println!("# Figure 6 — large-scale LDA (16 clients; paper: 6000 clients / 5B docs)");
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 200;
    cfg.corpus.n_docs = 8_000;
    cfg.corpus.vocab_size = 6_000;
    cfg.corpus.n_topics = 50;
    cfg.corpus.doc_len_mean = 30.0;
    cfg.cluster.clients = 16;
    cfg.cluster.net.base_latency = Duration::from_micros(100);
    cfg.cluster.net.jitter = Duration::from_micros(300);
    cfg.cluster.net.drop_prob = 0.01;
    cfg.iterations = 10;
    cfg.eval_every = 10; // log-likelihood is the per-iteration metric here
    cfg.test_docs = 50;

    let report = Trainer::new(cfg).run().expect("train");
    bench::section("document log-likelihood per iteration (mean ± std across 16 clients)");
    let mut rows = Vec::new();
    for r in &report.per_iteration {
        rows.push(vec![
            r.iteration.to_string(),
            format!("{:.4}", r.log_lik.mean()),
            format!("{:.4}", r.log_lik.std()),
            format!("{:.4}", r.log_lik.min()),
            format!("{:.4}", r.log_lik.max()),
            r.datapoints.to_string(),
        ]);
    }
    bench::table(&["iter", "loglik", "std", "min", "max", "n"], &rows);
    let first = report.per_iteration.first().map(|r| r.log_lik.mean()).unwrap_or(0.0);
    let last = report.final_log_lik();
    let last_std = report
        .per_iteration
        .iter()
        .rev()
        .find(|r| r.log_lik.count() > 1)
        .map(|r| r.log_lik.std())
        .unwrap_or(f64::NAN);
    println!(
        "\nloglik {first:.4} → {last:.4} | final cross-client std {last_std:.4} | {} tokens total | {:.0} tokens/s",
        report.total_tokens, report.tokens_per_sec
    );
    println!("Expected shape (paper Fig 6): monotone improvement with *small* cross-client");
    println!("variance — the eventual-consistency sync keeps replicas aligned.");
}
