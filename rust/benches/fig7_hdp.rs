//! Figure 7: HDP on 200 and 500 clients — scaled to 8 and 16. Same four
//! panels as Fig 4/5; the paper highlights convergence "with very small
//! standard deviation" and per-client throughput above a million tokens
//! per second (see tab_throughput for the raw sampler rate).

use hplvm::bench;
use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn cfg(clients: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasHdp;
    cfg.params.topics = 100; // truncation K_max
    cfg.params.hdp_b0 = 1.0;
    cfg.params.hdp_b1 = 1.0;
    cfg.corpus.n_docs = 250 * clients;
    cfg.corpus.vocab_size = 4_000;
    cfg.corpus.n_topics = 25;
    cfg.corpus.doc_len_mean = 40.0;
    cfg.cluster.clients = clients;
    cfg.cluster.net.base_latency = Duration::from_micros(100);
    cfg.cluster.net.jitter = Duration::from_micros(200);
    cfg.cluster.net.drop_prob = 0.01;
    cfg.projection = ProjectionMode::Distributed;
    cfg.iterations = 12;
    cfg.eval_every = 4;
    cfg.test_docs = 60;
    cfg
}

fn main() {
    println!("# Figure 7 — AliasHDP on 8 and 16 clients (paper: 200 and 500)");
    for clients in [8usize, 16] {
        bench::section(&format!("{clients} clients (paper: {})", clients * 25));
        let report = Trainer::new(cfg(clients)).run().expect("train");
        let mut rows = Vec::new();
        for r in &report.per_iteration {
            rows.push(vec![
                r.iteration.to_string(),
                if r.perplexity.count() > 0 {
                    format!("{:.1} ±{:.1}", r.perplexity.mean(), r.perplexity.std())
                } else {
                    "-".into()
                },
                format!("{:.2}", r.topics_per_word.mean()),
                format!("{:.3} ±{:.3}", r.time.mean(), r.time.std()),
                r.datapoints.to_string(),
            ]);
        }
        bench::table(&["iter", "perplexity", "topics/word", "time(s)", "n"], &rows);
        println!(
            "final perplexity {:.1} | corrections {} | {:.0} tokens/s",
            report.final_perplexity(),
            report.corrections,
            report.tokens_per_sec
        );
    }
    println!("\nExpected shape (paper Fig 7): stable decreasing perplexity at both scales");
    println!("with small std; the larger scale converges at a similar rate per iteration.");
}
