//! Figure 4: AliasLDA vs YahooLDA at three client scales.
//!
//! Paper: 200/500/1000 clients, 2000 topics, ~50M tokens/shard. Scaled:
//! 4/8/16 clients, 200 topics, ~10⁴ tokens/shard — the panels and the
//! comparison shape are the paper's: per-iteration perplexity, average
//! topics per word, running time, and the number of data points per
//! iteration (clients thin out under the 90% rule). Expected shape:
//! AliasLDA ≤ YahooLDA in time and perplexity at equal iterations, with
//! smaller error bars.

use hplvm::bench;
use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn cfg(model: ModelKind, clients: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model;
    cfg.params.topics = 200;
    cfg.corpus.n_docs = 300 * clients;
    cfg.corpus.vocab_size = 4_000;
    cfg.corpus.n_topics = 40;
    cfg.corpus.doc_len_mean = 40.0;
    cfg.corpus.seed = 4242;
    cfg.cluster.clients = clients;
    cfg.cluster.net.base_latency = Duration::from_micros(100);
    cfg.cluster.net.jitter = Duration::from_micros(200);
    cfg.cluster.net.drop_prob = 0.01; // shared-cluster flakiness
    cfg.iterations = 12;
    cfg.eval_every = 4;
    cfg.test_docs = 60;
    cfg.seed = 4242;
    cfg
}

fn main() {
    println!("# Figure 4 — AliasLDA vs YahooLDA (scaled: clients x25 smaller)");
    for clients in [4usize, 8, 16] {
        bench::section(&format!("{clients} clients (paper: {})", clients * 50));
        for model in [ModelKind::AliasLda, ModelKind::YahooLda] {
            let report = Trainer::new(cfg(model, clients)).run().expect("train");
            println!("\n-- {} --", model.name());
            let mut rows = Vec::new();
            for r in &report.per_iteration {
                rows.push(vec![
                    r.iteration.to_string(),
                    format!("{:.3}", r.time.mean()),
                    format!("{:.3}", r.time.std()),
                    format!("{:.3}", r.time.min()),
                    if r.perplexity.count() > 0 {
                        format!("{:.1}", r.perplexity.mean())
                    } else {
                        "-".into()
                    },
                    if r.perplexity.count() > 0 {
                        format!("{:.1}", r.perplexity.std())
                    } else {
                        "-".into()
                    },
                    format!("{:.2}", r.topics_per_word.mean()),
                    r.datapoints.to_string(),
                ]);
            }
            bench::table(
                &[
                    "iter",
                    "time(s)",
                    "t.std",
                    "t.min",
                    "perplexity",
                    "p.std",
                    "topics/word",
                    "datapoints",
                ],
                &rows,
            );
            println!(
                "steady-state iter time {:.3}s | final perplexity {:.1} | {:.0} tokens/s | reassignments {}",
                report.steady_state_iter_secs(),
                report.final_perplexity(),
                report.tokens_per_sec,
                report.reassignments
            );
        }
    }
    println!("\nExpected shape (paper): AliasLDA beats YahooLDA on running time and");
    println!("perplexity-at-iteration at every scale, with smaller error bars; the");
    println!("gap grows with topics-per-word (see tab_throughput for the sweep).");
}
