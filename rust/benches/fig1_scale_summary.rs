//! Figure 1: the published-systems scatter (parameters vs cores,
//! supervised vs unsupervised), printed as a table with this
//! reproduction's own live measurement appended for context.

use hplvm::bench;
use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;

fn main() {
    println!("# Figure 1 — largest published ML experiments (parameters vs cores)");
    let mut rows: Vec<Vec<String>> = bench::fig1_survey()
        .into_iter()
        .map(|(name, params, cores, kind)| {
            vec![
                name.to_string(),
                format!("{params:.0e}"),
                format!("{cores:.0e}"),
                kind.to_string(),
            ]
        })
        .collect();

    // Live row: run this repo's LDA and report its actual parameter and
    // "core" (worker thread) counts.
    let mut cfg = TrainConfig::small_lda();
    cfg.iterations = 5;
    cfg.eval_every = 5;
    let clients = cfg.cluster.clients;
    let params = (cfg.corpus.vocab_size * cfg.params.topics) as f64;
    let report = Trainer::new(cfg).run().expect("train");
    rows.push(vec![
        "THIS REPRO (live, simulated cluster)".into(),
        format!("{params:.0e}"),
        format!("{:.0e}", clients as f64),
        format!("unsupervised, {:.0} tok/s", report.tokens_per_sec),
    ]);

    bench::table(&["system", "#parameters", "#cores", "kind"], &rows);
    println!("\nThe paper's own point (4e12 params on 6e4 cores) dominates the survey —");
    println!("the simulated repro preserves the *architecture*, not the datacenter.");
}
