//! Offline stub of the `xla` PJRT bindings.
//!
//! The runtime bridge (`hplvm::runtime`) is written against the real
//! `xla` crate's API. This environment has no XLA/PJRT shared library (and
//! no crates.io access), so this stub provides the same signatures with a
//! [`PjRtClient::cpu`] that returns an "unavailable" error. Every caller
//! already treats PJRT as optional — `Engine::load` failures degrade to
//! the pure-rust evaluation path and the PJRT test suite skips — so the
//! whole system builds and runs offline. Swap in the real crate with a
//! `[patch]` section to get hardware execution back.

use std::fmt;

/// Stub error type (mirrors `xla::Error` well enough for `?`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: hplvm was built against the offline `xla` stub \
         (no XLA/PJRT shared library in this environment)"
            .to_string(),
    ))
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails, so no other method
/// is ever reached at runtime; they exist to satisfy the call sites.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Platform name (never reached; the constructor fails).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (never reached).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a proto (never reached).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments (never reached).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer as a literal (never reached).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal (host-side only; carries no data in the
    /// stub because nothing can ever execute against it).
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape (never reached at runtime).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    /// Unwrap a 1-tuple result (never reached).
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    /// Copy out as a typed vector (never reached).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not produce a client"),
        };
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
