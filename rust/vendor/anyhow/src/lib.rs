//! Minimal offline drop-in for the subset of the `anyhow` API this
//! workspace uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` /
//! `ensure!` macros. The build environment has no crates.io access, so
//! this ~100-line vendored crate stands in for the real one; swap it out
//! with a `[patch]` entry when building online.

use std::fmt;

/// A dynamic error: a message plus an optional source it was converted
/// from. Deliberately does **not** implement `std::error::Error`, exactly
/// like the real `anyhow::Error`, so the blanket `From` below is coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// The root cause's message, if this error wraps one.
    pub fn source_message(&self) -> Option<String> {
        self.source.as_ref().map(|s| s.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the chain, like the real anyhow.
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            if let Some(s) = &self.source {
                let cause = s.to_string();
                if cause != self.msg {
                    write!(f, ": {cause}")?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(s) = &self.source {
            let cause = s.to_string();
            if cause != self.msg {
                write!(f, "\n\nCaused by:\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>` — like `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/3720")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("thing {} broke", 7);
        assert_eq!(e.to_string(), "thing 7 broke");
        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(guarded(1).is_ok());
        assert_eq!(
            guarded(-2).unwrap_err().to_string(),
            "x must be positive, got -2"
        );
    }

    #[test]
    fn alternate_display_appends_cause() {
        let e = io_fail().unwrap_err();
        // Wrapped errors echo their cause; message == cause here, so the
        // alternate form must not duplicate it.
        assert_eq!(format!("{e}"), format!("{e:#}"));
    }
}
