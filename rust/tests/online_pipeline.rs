//! End-to-end streaming train-while-serve pipeline: a corpus several
//! times larger than the chunk buffer flows through [`Pipeline::run`]
//! while a background query load hits the serving tier continuously.
//!
//! The acceptance claims, in one run:
//!
//! * **bounded memory** — the driver never holds more than `chunk_docs`
//!   documents of the stream at once (`peak_chunk_docs`);
//! * **live reloads** — the `ReplicaSet` serves ≥ 3 distinct model
//!   generations mid-stream and drops zero queries across reloads;
//! * **quality** — post-stream held-out perplexity beats chance
//!   decisively and lands in the same regime as an equivalent offline
//!   run over the same docword file (statistical, like
//!   `session_resume.rs`: seeded RNGs, but thread interleaving perturbs
//!   trajectories under eventual consistency);
//! * **freshness** — the ingest-to-servable lag is finite throughout,
//!   shrinks after the final catch-up checkpoint, and ends at zero.

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::session::TrainSession;
use hplvm::corpus::generator::CorpusConfig;
use hplvm::corpus::source::{write_docword, FileSource};
use hplvm::corpus::stream::{CorpusStream, StreamingSource};
use hplvm::pipeline::{OnlinePolicy, Pipeline, PipelineConfig};
use std::path::PathBuf;
use std::time::Duration;

const CHUNK_DOCS: usize = 60;
const N_DOCS: usize = 400;
const VOCAB: usize = 300;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hplvm_pipeline_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn train_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 8;
    cfg.cluster.clients = 2;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(100);
    cfg.iterations = 8;
    cfg.eval_every = 2;
    cfg.test_docs = 12;
    cfg.seed = seed;
    cfg.cluster.net.seed = seed ^ 0x7EA7;
    cfg
}

/// Write one seeded synthetic corpus to a docword file both the
/// streaming and offline runs read.
fn write_corpus(tag: &str) -> PathBuf {
    let mut gen = CorpusConfig::default();
    gen.n_docs = N_DOCS;
    gen.vocab_size = VOCAB;
    gen.n_topics = 8;
    gen.doc_len_mean = 12.0;
    gen.seed = 77;
    let (corpus, _vocab) = gen.generate();
    assert_eq!(corpus.docs.len(), N_DOCS);
    let dir = tmpdir(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("docword.stream.txt");
    write_docword(&path, &corpus).unwrap();
    path
}

#[test]
fn streamed_corpus_trains_and_serves_online() {
    let path = write_corpus("e2e");
    let ckpt = tmpdir("e2e_ckpt");

    let mut cfg = PipelineConfig::new(train_cfg(4242), ckpt);
    cfg.policy = OnlinePolicy::default();
    cfg.checkpoint_every_batches = 2;
    cfg.replicas = 2;
    cfg.query_interval = Duration::from_millis(1);
    cfg.warmup_sweeps = 4;

    let policy = cfg.policy.clone();
    let warmup = cfg.warmup_sweeps;
    let mut stream = StreamingSource::open(&path, CHUNK_DOCS).unwrap();
    assert_eq!(stream.vocab_size(), VOCAB);
    let report = Pipeline::run(cfg, &mut stream).unwrap();
    println!("{}", report.render());

    // (a) Bounded streaming memory: the corpus is ~7× the chunk buffer,
    // yet the driver never held more than one chunk.
    assert_eq!(report.docs_streamed, N_DOCS as u64);
    assert!(
        report.peak_chunk_docs <= CHUNK_DOCS,
        "peak resident chunk {} exceeds the {CHUNK_DOCS}-doc bound",
        report.peak_chunk_docs
    );
    let expected_batches = (N_DOCS as u64).div_ceil(CHUNK_DOCS as u64);
    assert_eq!(report.batches, expected_batches);

    // (b) Live serving: ≥ 3 generations answered queries mid-stream and
    // no query was dropped or left unanswered across any reload.
    assert!(report.queries_sent > 0, "query load never fired");
    assert_eq!(
        report.queries_answered, report.queries_sent,
        "reloads dropped queries"
    );
    assert!(
        report.generations_observed.len() >= 3,
        "want ≥ 3 served generations, saw {:?}",
        report.generations_observed
    );
    assert!(
        report.reloads >= 3,
        "want ≥ 3 serving reloads, got {}",
        report.reloads
    );
    for w in report.generations_observed.windows(2) {
        assert!(w[0] < w[1], "generations must ascend: {w:?}");
    }

    // (c) Quality: beats chance decisively, same regime as offline.
    let chance = VOCAB as f64;
    assert!(report.final_perplexity.is_finite());
    assert!(
        report.final_perplexity < 0.9 * chance,
        "online perplexity {:.1} does not beat chance {chance:.1}",
        report.final_perplexity
    );
    let total_sweeps: u64 =
        warmup + (2..=expected_batches).map(|t| policy.sweeps_for(t)).sum::<u64>();
    let src = FileSource::new(&path);
    let mut offline = TrainSession::start(train_cfg(4242), &src).unwrap();
    offline.run_to(total_sweeps).unwrap();
    let p_offline = offline.finish().unwrap().final_perplexity();
    assert!(p_offline.is_finite() && p_offline > 0.0);
    assert!(
        report.final_perplexity < 3.0 * p_offline,
        "online {:.1} left the offline regime ({p_offline:.1})",
        report.final_perplexity
    );

    // (d) Freshness: lag spikes while batches queue between checkpoints,
    // then the catch-up checkpoint drains it to zero.
    assert!(report.peak_lag() > 0, "stream never produced a lag");
    assert!(report.peak_lag() <= N_DOCS as u64);
    assert_eq!(report.final_lag(), 0, "catch-up checkpoint must drain the lag");
    let last = report.samples.last().unwrap();
    assert!(last.freshness_lag < report.peak_lag());
    assert_eq!(last.docs_ingested, N_DOCS as u64);
    assert_eq!(last.docs_servable, N_DOCS as u64);
    // Every sample stays within the documents actually streamed.
    for s in &report.samples {
        assert!(s.freshness_lag <= s.docs_ingested);
        assert!(s.docs_ingested <= N_DOCS as u64);
    }
}

#[test]
fn bootstrap_chunk_must_cover_the_heldout_split() {
    let path = write_corpus("boot");
    let ckpt = tmpdir("boot_ckpt");
    let mut cfg = PipelineConfig::new(train_cfg(7), ckpt);
    cfg.train.test_docs = 30;
    // A 10-doc bootstrap chunk cannot carry a 30-doc held-out split.
    let mut stream = StreamingSource::open(&path, 10).unwrap();
    let err = format!("{:#}", Pipeline::run(cfg, &mut stream).unwrap_err());
    assert!(err.contains("bootstrap chunk"), "{err}");
    assert!(err.contains("held-out"), "{err}");
}
