//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! check bit-level agreement with the pure-rust evaluation path.
//!
//! Requires `make artifacts` (the tests skip, loudly, when the artifacts
//! are absent — `make test` always builds them first).

use hplvm::runtime::{DenseEval, Engine, EvalService};
use std::path::Path;

fn artifacts_dir() -> &'static Path {
    Path::new("artifacts")
}

fn engine() -> Option<Engine> {
    match Engine::load(artifacts_dir()) {
        Ok(Some(e)) => Some(e),
        _ => {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn log_dot_matches_rust_math() {
    let Some(engine) = engine() else { return };
    let k = 64;
    let rows = 5;
    let mut rng = hplvm::util::rng::Rng::new(42);
    let theta: Vec<f32> = (0..rows * k).map(|_| rng.f64() as f32).collect();
    let phi: Vec<f32> = (0..rows * k).map(|_| rng.f64() as f32).collect();
    let got = engine.log_dot(&theta, &phi, rows, k).unwrap();
    assert_eq!(got.len(), rows);
    for r in 0..rows {
        let dot: f64 = (0..k)
            .map(|t| theta[r * k + t] as f64 * phi[r * k + t] as f64)
            .sum();
        assert!(
            (got[r] as f64 - dot.ln()).abs() < 1e-4,
            "row {r}: pjrt {} vs rust {}",
            got[r],
            dot.ln()
        );
    }
}

#[test]
fn log_dot_full_batch_and_padding() {
    let Some(engine) = engine() else { return };
    let meta_batch = engine.manifest().entries["log_dot"].batch;
    let k = 8;
    // Exactly the artifact batch.
    let theta = vec![0.125f32; meta_batch * k];
    let phi = vec![0.5f32; meta_batch * k];
    let got = engine.log_dot(&theta, &phi, meta_batch, k).unwrap();
    assert_eq!(got.len(), meta_batch);
    for &v in &got {
        assert!((v - 0.5f32.ln()).abs() < 1e-5);
    }
    // Over-batch must error cleanly.
    let too_big = vec![0.1f32; (meta_batch + 1) * k];
    assert!(engine
        .log_dot(&too_big, &too_big, meta_batch + 1, k)
        .is_err());
}

#[test]
fn log_dot_zero_rows_are_clamped_finite() {
    let Some(engine) = engine() else { return };
    let k = 16;
    let theta = vec![0.0f32; k];
    let phi = vec![0.0f32; k];
    let got = engine.log_dot(&theta, &phi, 1, k).unwrap();
    assert!(got[0].is_finite(), "zero row must clamp, got {}", got[0]);
}

#[test]
fn phi_dense_matches_rust_math() {
    let Some(engine) = engine() else { return };
    let k = 32;
    let rows = 4;
    let counts: Vec<f32> = (0..rows * k).map(|i| (i % 13) as f32 - 2.0).collect();
    let denom: Vec<f32> = (0..k).map(|t| 10.0 + t as f32).collect();
    let beta = 0.05f32;
    let got = engine.phi_dense(&counts, &denom, beta, rows, k).unwrap();
    assert_eq!(got.len(), rows * k);
    for r in 0..rows {
        for t in 0..k {
            let c = counts[r * k + t].max(0.0);
            let want = (c + beta) / denom[t];
            let g = got[r * k + t];
            assert!((g - want).abs() < 1e-5, "cell ({r},{t}): {g} vs {want}");
        }
    }
}

#[test]
fn eval_service_roundtrip_from_other_threads() {
    let svc = match EvalService::spawn(artifacts_dir()) {
        Ok(Some(s)) => std::sync::Arc::new(s),
        _ => {
            eprintln!("SKIP: no artifacts");
            return;
        }
    };
    assert!(svc.supports_log_dot(8));
    let mut handles = Vec::new();
    for th in 0..4u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let k = 8;
            let theta = vec![1.0f32 / k as f32; k];
            let phi = vec![(th as f32 + 1.0) * 0.1; k];
            let out = svc.log_dot(&theta, &phi, 1, k).unwrap();
            let want = ((th as f32 + 1.0) * 0.1).ln();
            assert!((out[0] - want).abs() < 1e-5, "{} vs {}", out[0], want);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

/// End-to-end: the perplexity evaluator must produce (nearly) identical
/// numbers through PJRT and through pure rust.
#[test]
fn perplexity_pjrt_equals_pure_rust() {
    let svc = match EvalService::spawn(artifacts_dir()) {
        Ok(Some(s)) => s,
        _ => {
            eprintln!("SKIP: no artifacts");
            return;
        }
    };
    let (corpus, _) = hplvm::corpus::generator::CorpusConfig {
        n_docs: 120,
        vocab_size: 400,
        n_topics: 8,
        doc_len_mean: 25.0,
        ..Default::default()
    }
    .generate();
    let (train, test) = corpus.split_test(30);
    let mut rng = hplvm::util::rng::Rng::new(5);
    let mut sampler =
        hplvm::sampler::alias_lda::AliasLda::new(train.docs, 400, 8, 0.1, 0.01, &mut rng);
    for _ in 0..5 {
        for d in 0..sampler.docs.len() {
            hplvm::sampler::DocSampler::sample_doc(&mut sampler, d, &mut rng);
        }
    }
    let pure = hplvm::eval::perplexity::perplexity(&sampler, &test, 3, None);
    let pjrt = hplvm::eval::perplexity::perplexity(&sampler, &test, 3, Some(&svc));
    assert_eq!(pure.tokens, pjrt.tokens);
    let rel = (pure.perplexity - pjrt.perplexity).abs() / pure.perplexity;
    assert!(
        rel < 1e-3,
        "pure {} vs pjrt {} (rel {rel})",
        pure.perplexity,
        pjrt.perplexity
    );
}
