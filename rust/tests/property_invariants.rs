//! Randomized property tests over the core invariants (no proptest crate
//! offline — the generators are seeded loops over the crate's own RNG,
//! which keeps every failure reproducible from the printed seed).

use hplvm::projection::{project_pair, PairRule};
use hplvm::ps::filter::Filter;
use hplvm::ps::snapshot;
use hplvm::sampler::alias::AliasTable;
use hplvm::sampler::counts::{CountMatrix, RowData};
use hplvm::sampler::doc_state::SparseCounts;
use hplvm::sampler::stirling::StirlingTable;
use hplvm::util::json::Json;
use hplvm::util::rng::Rng;
use hplvm::util::stats::RunningStats;
use std::collections::HashMap;

/// Alias tables must reproduce arbitrary weight vectors' distributions.
#[test]
fn prop_alias_table_matches_weights() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(200);
        let mut weights: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
        // Sprinkle zeros.
        for _ in 0..n / 4 {
            let i = rng.below(n);
            weights[i] = 0.0;
        }
        let total: f64 = weights.iter().sum();
        if total == 0.0 {
            continue;
        }
        let table = AliasTable::build(&weights);
        let draws = 60_000;
        let mut counts = vec![0u64; n];
        for _ in 0..draws {
            counts[table.sample(&mut rng)] += 1;
        }
        for i in 0..n {
            let expect = weights[i] / total * draws as f64;
            if weights[i] == 0.0 {
                assert_eq!(counts[i], 0, "seed {seed}: zero weight drawn");
            } else if expect >= 20.0 {
                let dev = (counts[i] as f64 - expect).abs();
                assert!(
                    dev < 6.0 * expect.sqrt() + 1.0,
                    "seed {seed}: outcome {i} count {} expect {expect}",
                    counts[i]
                );
            }
        }
    }
}

/// A rigorous chi-square goodness-of-fit for the alias sampler: 100k
/// draws from one fixed weight vector. With 19 effective degrees of
/// freedom, χ² < 43.8 is the p = 0.001 critical value — a principled
/// bound, unlike eyeballed per-bin deviations.
#[test]
fn prop_alias_chi_square_100k_draws() {
    // Fixed, deliberately lumpy weights over 20 outcomes.
    let weights: Vec<f64> = (0..20)
        .map(|i| match i % 4 {
            0 => 10.0,
            1 => 3.5,
            2 => 0.8,
            _ => 1.0 + i as f64 * 0.25,
        })
        .collect();
    let total: f64 = weights.iter().sum();
    let table = AliasTable::build(&weights);
    let draws = 100_000usize;
    let mut rng = Rng::new(0xA11A5);
    let mut counts = vec![0u64; weights.len()];
    for _ in 0..draws {
        counts[table.sample(&mut rng)] += 1;
    }
    let mut chi2 = 0.0;
    for (i, &w) in weights.iter().enumerate() {
        let expected = w / total * draws as f64;
        assert!(expected >= 5.0, "bin {i} too small for the chi-square test");
        chi2 += (counts[i] as f64 - expected).powi(2) / expected;
    }
    // dof = 20 − 1 = 19; χ²_{0.999,19} = 43.82.
    assert!(
        chi2 < 43.82,
        "alias sampler failed chi-square: χ² = {chi2:.2} over 19 dof (p < 0.001)"
    );
    // And the test must have power: all mass accounted for.
    assert_eq!(counts.iter().sum::<u64>() as usize, draws);
}

/// The communication filter never loses or duplicates a row: for random
/// inputs, `send ∪ retain` is a permutation of the input, and
/// `magnitude_fraction = 1.0` retains nothing.
#[test]
fn prop_filter_select_is_a_partition() {
    let mut rng = Rng::new(0xF117);
    for trial in 0..200u64 {
        let n = rng.below(40);
        let k = 1 + rng.below(6);
        let rows: Vec<(u32, RowData)> = (0..n)
            .map(|w| {
                let row: Vec<i32> = (0..k)
                    .map(|_| rng.below(2001) as i32 - 1000)
                    .collect();
                // Exercise both wire encodings through the filter.
                let row = if w % 2 == 0 {
                    RowData::Dense(row.into_boxed_slice())
                } else {
                    RowData::from_dense_auto(&row)
                };
                (w as u32, row)
            })
            .collect();
        let filter = Filter {
            magnitude_fraction: rng.f64(),
            uniform_prob: rng.f64() * 0.5,
            cell_level: false,
        };
        let mut expected: Vec<(u32, RowData)> = rows.clone();
        let (send, retain) = filter.select(rows, &mut rng);
        // Permutation check on the full (word, row) multiset — no row
        // lost, duplicated, or rewritten.
        let mut got: Vec<(u32, RowData)> =
            send.iter().chain(retain.iter()).cloned().collect();
        got.sort();
        expected.sort();
        assert_eq!(
            got, expected,
            "trial {trial}: send ∪ retain is not a permutation of the input"
        );

        // fraction = 1.0 disables the filter entirely.
        let passthrough = Filter {
            magnitude_fraction: 1.0,
            uniform_prob: 0.0,
            cell_level: false,
        };
        let rows2: Vec<(u32, RowData)> = expected.clone();
        let (send2, retain2) = passthrough.select(rows2, &mut rng);
        assert!(retain2.is_empty(), "fraction 1.0 must retain nothing");
        assert_eq!(send2.len(), expected.len());
    }
}

/// Projection: idempotent, feasible, and never moves a feasible point —
/// over a random i32 grid far beyond the unit-test range.
#[test]
fn prop_projection_feasible_idempotent() {
    let mut rng = Rng::new(99);
    for _ in 0..10_000 {
        let a = rng.below(2001) as i32 - 1000;
        let b = rng.below(2001) as i32 - 1000;
        for rule in [PairRule::TablePolytope, PairRule::NonNegative] {
            let (a1, b1) = project_pair(rule, a, b);
            assert!(rule.holds(a1, b1), "({a},{b}) → ({a1},{b1}) infeasible");
            assert_eq!(project_pair(rule, a1, b1), (a1, b1), "not idempotent");
            if rule.holds(a, b) {
                assert_eq!((a1, b1), (a, b), "moved a feasible point");
            }
        }
    }
}

/// Stirling recurrence S^{N+1}_M = S^N_{M−1} + (N−Ma)S^N_M at random
/// discounts, checked in linear space via ratios.
#[test]
fn prop_stirling_recurrence_random_discounts() {
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let a = rng.f64() * 0.9;
        let mut t = StirlingTable::new(a, 60);
        for _ in 0..200 {
            let n = 1 + rng.below(58);
            let m = 1 + rng.below(n);
            let lhs = t.log(n + 1, m);
            let r1 = t.log(n, m - 1);
            let coeff = n as f64 - m as f64 * a;
            let r2 = if coeff > 0.0 {
                t.log(n, m) + coeff.ln()
            } else {
                f64::NEG_INFINITY
            };
            let rhs = if r1 == f64::NEG_INFINITY {
                r2
            } else if r2 == f64::NEG_INFINITY {
                r1
            } else {
                let hi = r1.max(r2);
                hi + ((r1 - hi).exp() + (r2 - hi).exp()).ln()
            };
            if lhs.is_finite() || rhs.is_finite() {
                assert!(
                    (lhs - rhs).abs() < 1e-8,
                    "a={a} n={n} m={m}: {lhs} vs {rhs}"
                );
            }
        }
    }
}

/// SparseCounts behaves exactly like a HashMap reference model under
/// random inc/dec/set sequences.
#[test]
fn prop_sparse_counts_vs_hashmap_model() {
    let mut rng = Rng::new(31);
    for _ in 0..50 {
        let mut sc = SparseCounts::new();
        let mut model: HashMap<u32, u32> = HashMap::new();
        for _ in 0..500 {
            let t = rng.below(20) as u32;
            match rng.below(3) {
                0 => {
                    sc.inc(t);
                    *model.entry(t).or_insert(0) += 1;
                }
                1 => {
                    if model.get(&t).copied().unwrap_or(0) > 0 {
                        sc.dec(t);
                        let e = model.get_mut(&t).unwrap();
                        *e -= 1;
                        if *e == 0 {
                            model.remove(&t);
                        }
                    }
                }
                _ => {
                    let c = rng.below(5) as u32;
                    sc.set_raw(t, c);
                    if c == 0 {
                        model.remove(&t);
                    } else {
                        model.insert(t, c);
                    }
                }
            }
            // Full-state comparison.
            assert_eq!(sc.nnz(), model.len());
            for (&t, &c) in &model {
                assert_eq!(sc.get(t), c);
            }
            assert_eq!(sc.total(), model.values().map(|&c| c as u64).sum::<u64>());
        }
    }
}

/// The replica merge rule: replica == server + unflushed local deltas,
/// under arbitrary interleavings of inc / drain / pull.
#[test]
fn prop_replica_merge_algebra() {
    let mut rng = Rng::new(17);
    for _ in 0..30 {
        let k = 4;
        let vocab = 10;
        let mut replica = CountMatrix::new(vocab, k);
        // The "server": authoritative rows + what we've pushed.
        let mut server = vec![vec![0i32; k]; vocab];
        // Shadow of the unflushed local deltas.
        let mut pending = vec![vec![0i32; k]; vocab];
        for _ in 0..400 {
            match rng.below(4) {
                // Local Gibbs move.
                0 | 1 => {
                    let w = rng.below(vocab) as u32;
                    let t = rng.below(k);
                    let d = if rng.coin(0.5) { 1 } else { -1 };
                    replica.inc(w, t, d);
                    pending[w as usize][t] += d;
                }
                // Push: drain deltas into the server (rows arrive in
                // whichever wire encoding the density picked; both must
                // mean the same dense deltas).
                2 => {
                    for (w, row) in replica.drain_deltas() {
                        let dense = row.to_dense(k);
                        for t in 0..k {
                            server[w as usize][t] += dense[t];
                            pending[w as usize][t] = 0;
                        }
                    }
                    // NB: drain returns only non-zero rows; zero rows'
                    // pending is already zero.
                    for p in pending.iter_mut() {
                        p.iter_mut().for_each(|x| *x = 0);
                    }
                }
                // Pull a random word: replica := server + pending.
                _ => {
                    let w = rng.below(vocab) as u32;
                    let srow: Vec<i32> = server[w as usize].clone();
                    replica.apply_pull(w, &srow);
                    for t in 0..k {
                        assert_eq!(
                            replica.get(w, t),
                            server[w as usize][t] + pending[w as usize][t],
                            "merge rule violated at ({w},{t})"
                        );
                    }
                }
            }
        }
        // Final: flush everything, pull everything → exact agreement.
        for (w, row) in replica.drain_deltas() {
            let dense = row.to_dense(k);
            for t in 0..k {
                server[w as usize][t] += dense[t];
            }
        }
        for w in 0..vocab as u32 {
            let srow = server[w as usize].clone();
            replica.apply_pull(w, &srow);
        }
        for w in 0..vocab {
            for t in 0..k {
                assert_eq!(replica.get(w as u32, t), server[w][t]);
            }
        }
        // Totals must be consistent after all that.
        let mut totals = vec![0i64; k];
        for w in 0..vocab {
            for t in 0..k {
                totals[t] += server[w][t] as i64;
            }
        }
        assert_eq!(replica.totals(), &totals[..]);
    }
}

/// Sparse/dense wire rows are interchangeable: for random rows, encoding
/// round-trips to the same dense values, the server-side fold and the
/// client-side pull-apply agree with plain dense arithmetic, and the
/// encoder really picks the smaller wire form.
#[test]
fn prop_rowdata_sparse_dense_equivalence() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..300 {
        let k = 1 + rng.below(64);
        let mut dense = vec![0i32; k];
        // Random density from nearly-empty to full.
        let nnz_target = rng.below(k + 1);
        for _ in 0..nnz_target {
            dense[rng.below(k)] = rng.below(41) as i32 - 20;
        }
        let enc = RowData::from_dense_auto(&dense);
        // Encode → decode is the identity.
        assert_eq!(&*enc.to_dense(k), &dense[..]);
        // The encoder picks the cheaper form.
        let nnz = dense.iter().filter(|&&v| v != 0).count();
        match &enc {
            RowData::Sparse(es) => {
                assert_eq!(es.len(), nnz);
                assert!(8 * nnz < 4 * k, "sparse chosen past break-even");
            }
            RowData::Dense(r) => {
                assert_eq!(r.len(), k);
                assert!(8 * nnz >= 4 * k, "dense chosen below break-even");
            }
        }
        // Server fold: either encoding == dense saturating add.
        let base: Vec<i32> = (0..k).map(|_| rng.below(1001) as i32 - 500).collect();
        let mut via_enc = base.clone();
        enc.fold_saturating_into(&mut via_enc);
        let expect: Vec<i32> = base
            .iter()
            .zip(dense.iter())
            .map(|(&b, &d)| b.saturating_add(d))
            .collect();
        assert_eq!(via_enc, expect);
        // Client pull-apply: either encoding lands the same replica state
        // (including unflushed-local-delta preservation and totals).
        let mut a = CountMatrix::new(2, k);
        let mut b = CountMatrix::new(2, k);
        for _ in 0..rng.below(10) {
            let t = rng.below(k);
            let d = if rng.coin(0.5) { 1 } else { -1 };
            a.inc(0, t, d);
            b.inc(0, t, d);
        }
        a.apply_pull(0, &dense);
        b.apply_pull_row(0, &enc);
        for t in 0..k {
            assert_eq!(a.get(0, t), b.get(0, t), "pull mismatch at {t}");
        }
        assert_eq!(a.totals(), b.totals());
    }
}

/// drain → (filter) → requeue → drain is lossless: rows a push cycle
/// retains fold back into the delta log so the next drain carries exactly
/// the aggregate deltas, regardless of sparse/dense storage spills.
#[test]
fn prop_drain_requeue_drain_is_lossless() {
    let mut rng = Rng::new(0xD7A1);
    for trial in 0..60u64 {
        let k = 2 + rng.below(40);
        let vocab = 8;
        let mut m = CountMatrix::new(vocab, k);
        // Shadow of all deltas ever logged (never drained to a server).
        let mut shadow = vec![vec![0i64; k]; vocab];
        for _ in 0..300 {
            let w = rng.below(vocab) as u32;
            let t = rng.below(k);
            let d = if rng.coin(0.5) { 1 } else { -1 };
            m.inc(w, t, d);
            shadow[w as usize][t] += d as i64;
        }
        // Drain, requeue everything (filter retained 100%), inc some
        // more, drain again: the union must equal the shadow.
        let first = m.drain_deltas();
        for (w, row) in first {
            m.requeue_delta(w, row);
        }
        for _ in 0..100 {
            let w = rng.below(vocab) as u32;
            let t = rng.below(k);
            m.inc(w, t, 1);
            shadow[w as usize][t] += 1;
        }
        let mut got = vec![vec![0i64; k]; vocab];
        for (w, row) in m.drain_deltas() {
            let dense = row.to_dense(k);
            for t in 0..k {
                got[w as usize][t] += dense[t] as i64;
            }
        }
        assert_eq!(got, shadow, "trial {trial}: requeue lost deltas");
        assert_eq!(m.pending_rows(), 0);
    }
}

/// JSON parse∘emit is the identity on randomly generated documents.
#[test]
fn prop_json_roundtrip_random() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.coin(0.5)),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let len = rng.below(12);
                Json::Str(
                    (0..len)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(23);
    for _ in 0..300 {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e} in {text}"));
        assert_eq!(v, back, "roundtrip broke for {text}");
    }
}

/// RunningStats merge is associative and order-independent (up to fp
/// noise) for random partitions of random data.
#[test]
fn prop_stats_merge_partition_invariance() {
    let mut rng = Rng::new(41);
    for _ in 0..50 {
        let n = 2 + rng.below(300);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 100.0).collect();
        let mut whole = RunningStats::new();
        xs.iter().for_each(|&x| whole.push(x));
        // Random 3-way partition, merged in random order.
        let mut parts = [RunningStats::new(), RunningStats::new(), RunningStats::new()];
        for &x in &xs {
            parts[rng.below(3)].push(x);
        }
        let mut merged = RunningStats::new();
        let mut order = [0usize, 1, 2];
        rng.shuffle(&mut order);
        for &i in &order {
            merged.merge(&parts[i]);
        }
        assert_eq!(merged.count(), whole.count());
        assert!((merged.mean() - whole.mean()).abs() < 1e-9 * (1.0 + whole.mean().abs()));
        assert!(
            (merged.variance() - whole.variance()).abs()
                < 1e-8 * (1.0 + whole.variance().abs())
        );
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
    }
}

/// Snapshot encode/decode is the identity on random stores and client
/// states.
#[test]
fn prop_snapshot_roundtrip_random() {
    let mut rng = Rng::new(53);
    for _ in 0..30 {
        let mut store = snapshot::Store::new();
        for _ in 0..rng.below(60) {
            let key = (rng.below(3) as u8, rng.below(1000) as u32);
            let row: Vec<i32> = (0..rng.below(16))
                .map(|_| rng.below(100_000) as i32 - 50_000)
                .collect();
            store.insert(key, row.into());
        }
        let bytes = snapshot::encode_store(&store);
        assert_eq!(snapshot::decode_store(&bytes).unwrap(), store);

        // v3: random hyperparameter headers round-trip bit-for-bit too,
        // with and without the optional table section.
        let meta = snapshot::SnapshotMeta {
            model: format!("AliasLDA{}", rng.below(10)),
            k: rng.below(2000) as u32,
            alpha: rng.f64() * 2.0,
            beta: rng.f64() * 0.5,
            vocab_size: rng.below(100_000) as u32,
            slot: rng.below(16) as u32,
            n_servers: 1 + rng.below(16) as u32,
            vnodes: 1 + rng.below(256) as u32,
            iterations: rng.next_u64() % 1_000,
            run_id: rng.next_u64(),
            tables: if rng.coin(0.5) {
                Some(snapshot::TableHyper {
                    discount: rng.f64(),
                    concentration: rng.f64() * 20.0,
                    root: rng.f64() * 2.0,
                })
            } else {
                None
            },
        };
        let bytes = snapshot::encode_store_meta(&store, &meta);
        let (meta2, store2) = snapshot::decode_store_meta(&bytes).unwrap();
        assert_eq!(meta2.as_ref(), Some(&meta));
        assert_eq!(store2, store);

        let n_docs = rng.below(10);
        let snap = snapshot::ClientSnapshot {
            shard: rng.below(100),
            iteration: rng.next_u64() % 10_000,
            z: (0..n_docs)
                .map(|_| (0..rng.below(30)).map(|_| rng.below(500) as u32).collect())
                .collect(),
            r: (0..n_docs)
                .map(|_| (0..rng.below(30)).map(|_| rng.coin(0.5)).collect())
                .collect(),
            replicas: (0..rng.below(3))
                .map(|m| {
                    let rows = (0..rng.below(5))
                        .map(|w| {
                            let row = if rng.coin(0.5) {
                                RowData::Dense(
                                    (0..1 + rng.below(6))
                                        .map(|_| rng.below(50) as i32 - 25)
                                        .collect::<Vec<_>>()
                                        .into_boxed_slice(),
                                )
                            } else {
                                RowData::Sparse(
                                    (0..rng.below(4))
                                        .map(|t| (t as u32, rng.below(50) as i32 - 25))
                                        .collect(),
                                )
                            };
                            (w as u32, row)
                        })
                        .collect();
                    (m as u8, rows)
                })
                .collect(),
        };
        // r rows must match z rows in length for the roundtrip contract.
        let snap = snapshot::ClientSnapshot {
            r: snap
                .z
                .iter()
                .zip(snap.r.iter())
                .map(|(z, r)| {
                    let mut r = r.clone();
                    r.resize(z.len(), false);
                    r
                })
                .collect(),
            ..snap
        };
        let bytes = snapshot::encode_client(&snap);
        assert_eq!(snapshot::decode_client(&bytes).unwrap(), snap);
    }
}

/// Ring routing is deterministic, total, and balanced for random vocab
/// samples at random slot counts.
#[test]
fn prop_ring_total_and_balanced() {
    let mut rng = Rng::new(61);
    for _ in 0..10 {
        let slots = 1 + rng.below(12);
        let ring = hplvm::ps::ring::Ring::new(slots, 64);
        let mut counts = vec![0usize; slots];
        for _ in 0..6_000 {
            let w = rng.below(1_000_000) as u32;
            let m = rng.below(3) as u8;
            let s = ring.route(m, w);
            assert_eq!(s, ring.route(m, w));
            counts[s as usize] += 1;
        }
        let expect = 6_000.0 / slots as f64;
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > 0.3 * expect && (c as f64) < 2.2 * expect,
                "slot {s}/{slots}: {c} keys (expect ≈{expect})"
            );
        }
    }
}
