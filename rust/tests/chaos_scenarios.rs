//! Elastic-membership chaos scenarios: kill and resize the live cluster
//! under load, then prove convergence and serving availability survived.
//!
//! Every scenario derives its fault schedule from one seed; set
//! `CHAOS_SEED` to replay a failing CI run locally:
//!
//! ```text
//! CHAOS_SEED=12345 cargo test --release --test chaos_scenarios
//! ```
//!
//! Fault *schedules* are deterministic; *outcomes* (perplexity, how many
//! queries landed while a membership change committed) ride real thread
//! scheduling and are asserted with tolerances.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use hplvm::chaos::{
    chaos_seed, chaos_train_config, ChaosEvent, ChaosHarness, ChaosPlan, Fault,
};
use hplvm::coordinator::TrainSession;
use hplvm::corpus::SyntheticSource;
use hplvm::serve::{InferConfig, ReplicaSet, ServingModel};
use hplvm::util::rng::Rng;

/// Uniform-guess perplexity over the chaos corpus vocabulary — any
/// model that learned *anything* sits below this.
const CHANCE_PERPLEXITY: f64 = 300.0;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "hplvm_chaos_test_{tag}_{}_{:x}",
        std::process::id(),
        chaos_seed()
    ))
}

/// Kill one worker mid-segment: the quorum still reaches the target,
/// the session performs a failover reassignment, and the post-chaos
/// model still beats chance.
#[test]
fn killed_worker_quorum_completes_and_converges() {
    let seed = chaos_seed();
    let plan = ChaosPlan {
        seed,
        events: vec![ChaosEvent {
            at_iteration: 6,
            fault: Fault::KillWorker,
        }],
    };
    let report = ChaosHarness::new(chaos_train_config(), plan, 1, 4, 10)
        .run()
        .expect("chaos run");
    assert_eq!(report.workers_killed, 1, "{:?}", report.faults);
    assert_eq!(
        report.reached_iterations, 10,
        "quorum must still reach the target (lost {})",
        report.iterations_lost()
    );
    assert!(
        report.reassignments >= 1,
        "the killed worker's shard must be reassigned: {:?}",
        report.faults
    );
    assert!(
        report.final_perplexity.is_finite()
            && report.final_perplexity > 1.0
            && report.final_perplexity < CHANCE_PERPLEXITY,
        "post-chaos perplexity {} must beat chance ({CHANCE_PERPLEXITY})",
        report.final_perplexity
    );
    assert_eq!(report.queries_dropped(), 0);
    assert!(report.queries_answered > 0, "query stream never ran");
}

/// Kill one server slot: the manager freezes, restores the slot from
/// its latest periodic snapshot, and thaws — and because resampling
/// moves tokens *within* a word row, the store's total token count is
/// conserved across the kill/restore cycle.
#[test]
fn killed_server_slot_restores_with_counts_conserved() {
    let cfg = chaos_train_config();
    let source = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &source).expect("start");
    session.run_to(4).expect("warmup");

    // Let the manager's periodic snapshot cadence (100ms) capture the
    // now-idle stores, so the restore below is loss-free.
    std::thread::sleep(Duration::from_millis(300));

    let before = temp_dir("slotkill_before");
    session.checkpoint(&before).expect("checkpoint");
    let total_before = ServingModel::load_dir(&before)
        .expect("serve checkpoint")
        .total_tokens();
    assert!(total_before > 0);

    let elastic = session.elastic().expect("elastic");
    assert_eq!(elastic.n_slots(), 2);
    elastic.kill_slot(1);

    // Training continues while the manager restores slot 1; the next
    // checkpoint needs every slot answering again.
    session.run_to(8).expect("post-kill segment");
    let after = temp_dir("slotkill_after");
    session.checkpoint(&after).expect("checkpoint after restore");
    let total_after = ServingModel::load_dir(&after)
        .expect("serve post-restore checkpoint")
        .total_tokens();

    let drift = (total_after - total_before).abs() as f64 / total_before as f64;
    assert!(
        drift <= 0.10,
        "token totals must be conserved across slot kill/restore: \
         {total_before} -> {total_after} ({:.1}% drift)",
        drift * 100.0
    );

    session.finish().expect("finish");
    let _ = std::fs::remove_dir_all(&before);
    let _ = std::fs::remove_dir_all(&after);
}

/// Grow the server ring 2 → 3 while a segment is training: consistent
/// hashing means only ≈1/3 of the rows hand off, the drain completes,
/// and the posterior stays in the same regime.
#[test]
fn ring_grow_under_load_moves_about_one_over_n_rows() {
    let cfg = chaos_train_config();
    let source = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &source).expect("start");
    session.run_to(4).expect("warmup");

    let elastic = session.elastic().expect("elastic");
    let progress = session.progress_probe();
    let grower = std::thread::spawn(move || {
        while progress.load(Ordering::Relaxed) < 6 {
            std::thread::sleep(Duration::from_millis(1));
        }
        elastic.grow()
    });

    let seg = session.run_to(10).expect("segment under grow");
    let stats = grower.join().expect("grow thread");

    assert!(stats.complete, "drain-and-handoff must complete: {stats:?}");
    assert!(stats.rows_total > 0, "grow saw an empty ring: {stats:?}");
    // Chord-style ring: the new slot should take ≈1/3 of the keys.
    // Same tolerance band the ring partition tests use.
    let f = stats.moved_fraction();
    assert!(
        f > 0.35 / 3.0 && f < 2.5 / 3.0,
        "grow 2->3 moved {:.1}% of rows; expected ≈33%",
        f * 100.0
    );
    assert_eq!(session.elastic().expect("elastic").n_slots(), 3);

    let ppl = seg.report.final_perplexity();
    assert!(
        ppl.is_finite() && ppl < CHANCE_PERPLEXITY,
        "post-grow perplexity {ppl} left the convergence regime"
    );
    session.finish().expect("finish");
}

/// Kill (shrink away) a serving replica while a query stream is live:
/// pinned generations keep scattering over the old membership, the
/// router re-scatters new queries over the survivors, and zero queries
/// drop across both membership changes.
#[test]
fn replica_killed_mid_query_stream_drops_zero_queries() {
    let cfg = chaos_train_config();
    let vocab = cfg.corpus.vocab_size as usize;
    let source = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &source).expect("start");
    session.run_to(4).expect("warmup");
    let dir = temp_dir("replica_kill");
    session.checkpoint(&dir).expect("checkpoint");
    session.finish().expect("finish");

    let set = ReplicaSet::load_dir(&dir, 3).expect("load serving set");
    let gen0 = set.generation();

    let stop = Arc::new(AtomicBool::new(false));
    let sent = Arc::new(AtomicU64::new(0));
    let answered = Arc::new(AtomicU64::new(0));
    let streamer = {
        let (set, stop) = (set.clone(), stop.clone());
        let (sent, answered) = (sent.clone(), answered.clone());
        std::thread::spawn(move || {
            let mut rng = Rng::new(chaos_seed() ^ 0xDEAD_BEEF);
            let icfg = InferConfig::default();
            while !stop.load(Ordering::Relaxed) {
                let doc: Vec<u32> = (0..16).map(|_| rng.below(vocab) as u32).collect();
                sent.fetch_add(1, Ordering::Relaxed);
                let res = set.infer(&doc, &icfg, &mut rng);
                assert!(!res.theta.is_empty(), "query answered with empty posterior");
                answered.fetch_add(1, Ordering::Relaxed);
            }
        })
    };

    // Let the stream establish, then kill a replica (shrink 3 -> 2) and
    // later bring the set back to 3 — both while queries are in flight.
    std::thread::sleep(Duration::from_millis(50));
    set.resize(2).expect("shrink must commit");
    std::thread::sleep(Duration::from_millis(50));
    set.resize(3).expect("regrow must commit");
    std::thread::sleep(Duration::from_millis(50));

    stop.store(true, Ordering::Relaxed);
    streamer.join().expect("query stream must not panic");

    let (s, a) = (sent.load(Ordering::Relaxed), answered.load(Ordering::Relaxed));
    assert!(s > 0, "stream never sent a query");
    assert_eq!(s, a, "queries dropped across replica membership changes");
    assert_eq!(set.replicas(), 3);
    assert!(
        set.generation() >= gen0 + 2,
        "both membership changes must commit new generations"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full seeded drill — the issue's acceptance criteria in one run:
/// one schedule kills ≥1 worker, ≥1 server slot, and ≥1 serving replica
/// (plus a net spike, an aborted reload, and a ring grow), and the
/// report shows convergence with zero dropped queries.
#[test]
fn full_seeded_drill_kills_everything_once_and_survives() {
    let seed = chaos_seed();
    let cfg = chaos_train_config();
    let n_servers = cfg.cluster.n_servers();
    let (warmup, target, replicas) = (4, 16, 2);

    // The schedule is a pure function of the seed (the determinism
    // contract CI's CHAOS_SEED replay relies on).
    let plan = ChaosPlan::seeded(seed, warmup, target, n_servers, replicas);
    assert_eq!(
        plan,
        ChaosPlan::seeded(seed, warmup, target, n_servers, replicas)
    );
    assert_eq!(plan.events.len(), 8);

    let report = ChaosHarness::new(cfg, plan, replicas, warmup, target)
        .run()
        .expect("chaos run");
    let text = report.render();

    assert!(report.workers_killed >= 1, "{text}");
    assert!(report.server_slots_killed >= 1, "{text}");
    assert!(report.replica_reloads_aborted >= 1, "{text}");
    // The plan resizes 2 -> 3 -> 1: at least one replica was killed.
    assert!(report.replica_resizes >= 1, "{text}");
    assert!(report.reassignments >= 1, "{text}");

    assert_eq!(
        report.reached_iterations, target,
        "training availability: quorum must absorb the chaos — {text}"
    );
    assert!(
        report.final_perplexity.is_finite()
            && report.final_perplexity < CHANCE_PERPLEXITY,
        "convergence survived: {text}"
    );

    assert!(report.queries_answered > 0, "{text}");
    assert_eq!(
        report.queries_dropped(),
        0,
        "serving availability: no query may drop — {text}"
    );

    // The ring grow's handoff accounting: complete, and only ≈1/(N+1)
    // of the rows moved.
    assert_eq!(report.handoffs.len(), 1, "{text}");
    let h = &report.handoffs[0];
    assert!(h.complete, "{text}");
    assert!(h.rows_total > 0, "{text}");
    let f = h.moved_fraction();
    assert!(
        f > 0.35 / 3.0 && f < 2.5 / 3.0,
        "grow 2->3 moved {:.1}% of rows — {text}",
        f * 100.0
    );
}
