//! Integration of the training → snapshot → serving pipeline, for every
//! model family: a trained snapshot loads into the serving layer behind a
//! [`ServingHandle`], fold-in queries return sane topic mixtures, scoring
//! held-out documents with the *served* mixtures lands within 10% of the
//! evaluation stack's own perplexity on the same frozen statistics (LDA,
//! PDP, and HDP alike), and a hot reload swaps generations under
//! concurrent load without dropping a single request.

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use hplvm::eval::perplexity::{perplexity, score_with_theta};
use hplvm::serve::{
    infer_doc, InferConfig, InferenceService, ReplicaSet, ServeConfig, ServingHandle,
    ServingModel,
};
use hplvm::util::rng::Rng;
use std::sync::Arc;

/// One trained snapshot shared by the assertions below (training on the
/// simulated cluster dominates the test's cost, so do it once).
fn trained_snapshot(tag: &str, cfg: &TrainConfig) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hplvm_serve_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = cfg.clone();
    cfg.cluster.snapshot_dir = Some(dir.clone());
    let report = Trainer::new(cfg).run().expect("training failed");
    assert!(report.final_perplexity().is_finite());
    dir
}

fn serving_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::small_lda();
    // Keep the cluster small and fully seeded: this test is about the
    // serving handoff, not training scale.
    cfg.corpus.n_docs = 400;
    cfg.iterations = 12;
    cfg.eval_every = 6;
    cfg.test_docs = 60;
    cfg.cluster.clients = 2;
    cfg.seed = 4242;
    cfg.corpus.seed = 4242;
    cfg.cluster.net.seed = 4242;
    cfg.cluster.net.base_latency = std::time::Duration::from_micros(50);
    cfg.cluster.net.jitter = std::time::Duration::from_micros(50);
    cfg
}

fn pdp_serving_cfg() -> TrainConfig {
    let mut cfg = serving_cfg();
    cfg.model = ModelKind::AliasPdp;
    cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
    cfg.corpus.n_docs = 300;
    cfg.iterations = 10;
    cfg.eval_every = 5;
    cfg.test_docs = 50;
    cfg
}

fn hdp_serving_cfg() -> TrainConfig {
    let mut cfg = serving_cfg();
    cfg.model = ModelKind::AliasHdp;
    cfg.params.topics = 16; // truncation
    cfg.corpus.n_topics = 8;
    cfg.corpus.n_docs = 300;
    cfg.iterations = 10;
    cfg.eval_every = 5;
    cfg.test_docs = 50;
    cfg
}

/// The family-parity core: train, load through the handle, answer every
/// held-out document through the micro-batching service, and require the
/// served mixtures to score within `tol` of the evaluation stack's EM
/// fold-in on the same frozen statistics.
fn assert_served_matches_eval(tag: &str, cfg: &TrainConfig, tol: f64) {
    let dir = trained_snapshot(tag, cfg);

    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let model = handle.model();
    // The snapshot header reproduces the training hyperparameters and
    // records the family.
    assert_eq!(model.k(), cfg.params.topics);
    assert_eq!(model.meta().model, cfg.model.name());
    assert_eq!(model.kind().family_name(), cfg.model.family_name());
    assert_eq!(model.meta().alpha.to_bits(), cfg.params.alpha.to_bits());
    assert_eq!(model.meta().beta.to_bits(), cfg.params.beta.to_bits());
    assert_eq!(
        model.meta().tables.is_some(),
        cfg.model.has_table_constraints(),
        "table-constrained families must snapshot their hyperparameters"
    );

    // The held-out documents: the split is deterministic in the corpus
    // seed, so regenerating reproduces exactly what training held out.
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);

    // Baseline: the evaluation stack's EM fold-in on the same frozen φ.
    let baseline = perplexity(&*model, &test, 3, None);
    assert!(baseline.perplexity.is_finite() && baseline.perplexity > 1.0);

    // Served: every mixture comes out of the micro-batching service. A
    // few extra sweeps of averaging narrows the estimator gap between
    // the Gibbs fold-in and the baseline's EM fold-in.
    let svc = InferenceService::spawn(
        handle.clone(),
        ServeConfig {
            infer: hplvm::serve::InferConfig {
                burnin: 5,
                samples: 5,
                mh_steps: 2,
            },
            ..Default::default()
        },
    );
    let mut generations = Vec::new();
    let thetas: Vec<Vec<f64>> = test
        .docs
        .iter()
        .map(|d| {
            let res = svc.infer(d.tokens.clone()).expect("service closed");
            generations.push(res.generation);
            res.theta
        })
        .collect();
    let served = score_with_theta(&*model, &test.docs, &thetas);
    svc.shutdown();
    assert!(
        generations.iter().all(|&g| g == 1),
        "no reload happened — every response must carry generation 1"
    );

    assert_eq!(served.tokens, baseline.tokens);
    let rel = (served.perplexity - baseline.perplexity).abs() / baseline.perplexity;
    assert!(
        rel < tol,
        "[{tag}] served perplexity {:.2} vs eval {:.2} (rel {:.3})",
        served.perplexity,
        baseline.perplexity,
        rel
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn served_mixtures_match_eval_perplexity_within_10_percent() {
    assert_served_matches_eval("perp", &serving_cfg(), 0.10);
}

/// Satellite: PDP serving parity — same statistical tolerance as LDA.
#[test]
fn pdp_served_mixtures_match_eval_perplexity() {
    assert_served_matches_eval("pdp", &pdp_serving_cfg(), 0.10);
}

/// Satellite: HDP serving parity — same statistical tolerance as LDA.
#[test]
fn hdp_served_mixtures_match_eval_perplexity() {
    assert_served_matches_eval("hdp", &hdp_serving_cfg(), 0.10);
}

#[test]
fn snapshot_dir_round_trips_through_serving_layer() {
    let mut cfg = serving_cfg();
    cfg.corpus.n_docs = 200;
    cfg.iterations = 6;
    cfg.eval_every = 3;
    cfg.test_docs = 30;
    let dir = trained_snapshot("load", &cfg);

    // One snapshot per server slot, all self-describing.
    let slots: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| {
            hplvm::ps::snapshot::is_slot_snapshot_name(&e.file_name().to_string_lossy())
        })
        .collect();
    assert_eq!(slots.len(), cfg.cluster.n_servers());

    let model = ServingModel::load_dir(&dir).expect("snapshot load");
    assert!(model.total_tokens() > 0, "frozen statistics are empty");
    assert_eq!(model.meta().n_servers as usize, cfg.cluster.n_servers());

    // The --model contradiction check: same family passes, cross-family
    // errors out with a message naming both sides.
    assert!(model.ensure_family(ModelKind::AliasLda).is_ok());
    assert!(model.ensure_family(ModelKind::YahooLda).is_ok());
    let msg = match model.ensure_family(ModelKind::AliasHdp) {
        Ok(()) => panic!("HDP against an LDA snapshot must be refused"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("AliasHDP") && msg.contains("AliasLDA"), "{msg}");

    // Fold-in against the loaded model produces a proper distribution
    // that beats the uniform mixture on its own document.
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let doc = test
        .docs
        .iter()
        .find(|d| d.tokens.len() >= 10)
        .expect("no usable held-out doc");
    let mut rng = hplvm::util::rng::Rng::new(7);
    let res = hplvm::serve::infer_doc(
        &model,
        &doc.tokens,
        &hplvm::serve::InferConfig::default(),
        &mut rng,
    );
    assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    let uniform = vec![vec![1.0 / model.k() as f64; model.k()]];
    let docs = vec![doc.clone()];
    let with_inferred = score_with_theta(&model, &docs, &[res.theta.clone()]);
    let with_uniform = score_with_theta(&model, &docs, &uniform);
    assert!(
        with_inferred.avg_log_lik >= with_uniform.avg_log_lik,
        "inferred mixture ({:.4}) scored below uniform ({:.4})",
        with_inferred.avg_log_lik,
        with_uniform.avg_log_lik
    );

    // Routed parity on a real trained directory: a 2-replica set loaded
    // from the same snapshots answers bit-identically at a fixed seed
    // and reports the replicas that served.
    let set = ReplicaSet::load_dir(&dir, 2).expect("replica-set load");
    let single = infer_doc(&model, &doc.tokens, &InferConfig::default(), &mut Rng::new(99));
    let routed = set.infer(&doc.tokens, &InferConfig::default(), &mut Rng::new(99));
    assert_bit_identical("trained-lda", &single.theta, &routed.theta);
    assert!(!routed.served_by.is_empty() && routed.served_by.len() <= 2);

    std::fs::remove_dir_all(&dir).ok();
}

/// Legacy-format compatibility: hand-written v2 slot files (no table
/// section) still load and serve LDA, exactly as before the v3 format.
#[test]
fn v2_lda_snapshots_still_serve() {
    use hplvm::ps::snapshot::{self, SnapshotMeta, Store};
    let dir = std::env::temp_dir().join(format!("hplvm_serve_v2_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut store = Store::new();
    for w in 0..10u32 {
        store.insert((0, w), if w < 5 { vec![50, 0] } else { vec![0, 50] }.into());
    }
    let meta = SnapshotMeta {
        model: "AliasLDA".to_string(),
        k: 2,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: 10,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0,
        tables: None,
    };
    let bytes = snapshot::encode_store_meta_v2(&store, &meta);
    snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();

    let handle = ServingHandle::load_dir(&dir).expect("v2 snapshot must load");
    let model = handle.model();
    assert_eq!(model.kind(), ModelKind::AliasLda);
    assert!(model.meta().tables.is_none());
    let mut rng = hplvm::util::rng::Rng::new(3);
    let res = hplvm::serve::infer_doc(
        &model,
        &[0, 1, 2, 3],
        &hplvm::serve::InferConfig::default(),
        &mut rng,
    );
    assert_eq!(res.top_topics(1)[0].0, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: hot reload under concurrent load — zero dropped/errored
/// requests across a mid-stream `reload()`, and post-swap responses
/// carry the new generation.
#[test]
fn hot_reload_under_load_drops_nothing_and_bumps_generation() {
    let mut cfg = serving_cfg();
    cfg.corpus.n_docs = 200;
    cfg.iterations = 6;
    cfg.eval_every = 3;
    cfg.test_docs = 30;
    let dir = trained_snapshot("reload", &cfg);

    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    assert_eq!(handle.generation(), 1);
    let svc = Arc::new(InferenceService::spawn(
        handle.clone(),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            ..Default::default()
        },
    ));

    let vocab = handle.model().vocab();
    let n_threads = 4usize;
    let per_thread = 30usize;
    let mut joins = Vec::new();
    for th in 0..n_threads {
        let svc = svc.clone();
        let queries = hplvm::serve::synth_queries(vocab, per_thread, 16.0, 90 + th as u64);
        joins.push(std::thread::spawn(move || {
            let mut gens = Vec::with_capacity(per_thread);
            for doc in queries {
                let res = svc
                    .infer(doc)
                    .expect("request dropped/errored across reload");
                assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
                gens.push(res.generation);
            }
            gens
        }));
    }

    // Mid-stream: swap in the next generation from the same directory
    // (contents are identical — the point is the swap mechanics).
    let swapped = handle.reload(&dir).expect("reload failed");
    assert_eq!(swapped, 2);

    let mut all_gens = Vec::new();
    for j in joins {
        all_gens.extend(j.join().expect("query thread panicked"));
    }
    assert_eq!(all_gens.len(), n_threads * per_thread, "every request answered");
    assert!(
        all_gens.iter().all(|&g| g == 1 || g == 2),
        "responses must come from generation 1 or 2: {all_gens:?}"
    );

    // Post-swap: a request submitted after reload() returned must be
    // served by the new generation.
    let res = svc
        .infer(hplvm::serve::synth_queries(vocab, 1, 16.0, 7).remove(0))
        .expect("service closed");
    assert_eq!(res.generation, 2, "post-swap response on the old generation");
    assert_eq!(
        svc.stats().served,
        (n_threads * per_thread + 1) as u64,
        "served-counter mismatch — something was dropped"
    );
    drop(svc); // Drop closes the queue and joins the pool.
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn service_is_deterministic_and_batch_shape_invariant() {
    let mut cfg = serving_cfg();
    cfg.corpus.n_docs = 200;
    cfg.iterations = 5;
    cfg.eval_every = 5;
    cfg.test_docs = 20;
    let dir = trained_snapshot("det", &cfg);
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");

    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let run = |workers: usize, batch: usize| -> Vec<Vec<f64>> {
        let svc = InferenceService::spawn(
            handle.clone(),
            ServeConfig {
                workers,
                max_batch: batch,
                ..Default::default()
            },
        );
        let rxs: Vec<_> = test
            .docs
            .iter()
            .map(|d| svc.submit(d.tokens.clone()))
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("service closed").theta)
            .collect();
        svc.shutdown();
        out
    };
    assert_eq!(
        run(1, 1),
        run(4, 16),
        "served mixtures depend on pool shape — RNG streams leak across requests"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Multi-replica serving: routed-vs-single parity, set-wide reload under
// faults, and the alias pre-warm regression.
// ---------------------------------------------------------------------------

fn assert_bit_identical(tag: &str, a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len(), "[{tag}] θ length mismatch");
    for (t, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "[{tag}] θ[{t}] diverged: {x} vs {y}"
        );
    }
}

fn synth_meta(model: &str, k: u32, vocab: u32) -> hplvm::ps::snapshot::SnapshotMeta {
    hplvm::ps::snapshot::SnapshotMeta {
        model: model.to_string(),
        k,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: vocab,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0,
        tables: None,
    }
}

/// Synthetic statistics for each family over a 48-word vocabulary —
/// large enough that 2- and 3-replica rings give every replica a share.
fn family_fixtures() -> Vec<(
    &'static str,
    hplvm::ps::snapshot::SnapshotMeta,
    Vec<hplvm::ps::snapshot::Store>,
)> {
    use hplvm::ps::snapshot::{Store, TableHyper};
    const V: u32 = 48;
    let mut out = Vec::new();

    // LDA: four blocky topics.
    let mut lda = Store::new();
    for w in 0..V {
        let mut row = vec![0i32; 4];
        row[(w / 12) as usize] = 60 + (w % 5) as i32;
        lda.insert((0, w), row.into());
    }
    out.push(("lda", synth_meta("AliasLDA", 4, V), vec![lda]));

    // PDP: customers (matrix 0) + tables (matrix 1), v3 hyperparameters.
    let mut pdp = Store::new();
    for w in 0..V {
        let t = (w % 3) as usize;
        let mut m_row = vec![0i32; 3];
        let mut s_row = vec![0i32; 3];
        m_row[t] = 40 + (w % 4) as i32;
        s_row[t] = 4 + (w % 3) as i32;
        pdp.insert((0, w), m_row.into());
        pdp.insert((1, w), s_row.into());
    }
    let mut pdp_meta = synth_meta("AliasPDP", 3, V);
    pdp_meta.tables = Some(TableHyper {
        discount: 0.1,
        concentration: 10.0,
        root: 0.5,
    });
    out.push(("pdp", pdp_meta, vec![pdp]));

    // HDP: three represented truncation slots + one empty, root row.
    let mut hdp = Store::new();
    for w in 0..V {
        let mut row = vec![0i32; 4];
        row[(w % 3) as usize] = 50 + (w % 6) as i32;
        hdp.insert((0, w), row.into());
    }
    hdp.insert((1, 0), vec![9, 6, 3, 0].into());
    let mut hdp_meta = synth_meta("AliasHDP", 4, V);
    hdp_meta.tables = Some(TableHyper {
        discount: 0.0,
        concentration: 1.0,
        root: 1.0,
    });
    out.push(("hdp", hdp_meta, vec![hdp]));
    out
}

/// Satellite: routed inference through 2- and 3-replica sets is
/// bit-identical to the single-replica path for LDA, PDP, and HDP under
/// the same per-request seed — empty, single-word, and mixed documents.
#[test]
fn routed_inference_is_bit_identical_for_all_families() {
    let cfg = InferConfig::default();
    for (tag, meta, stores) in family_fixtures() {
        let single =
            ServingModel::from_stores(meta.clone(), stores.clone(), 1 << 20).unwrap();
        let docs: Vec<Vec<u32>> = vec![
            vec![],
            vec![7],
            (0..40).map(|i| (i * 5 % 48) as u32).collect(),
            (0..17).map(|i| (i % 48) as u32).collect(),
        ];
        for replicas in [2usize, 3] {
            let set = ReplicaSet::from_stores(meta.clone(), stores.clone(), replicas, 1 << 20)
                .unwrap();
            for (d, doc) in docs.iter().enumerate() {
                for seed in [1u64, 42, 9999] {
                    let a = infer_doc(&single, doc, &cfg, &mut Rng::new(seed));
                    let b = set.infer(doc, &cfg, &mut Rng::new(seed));
                    assert_bit_identical(
                        &format!("{tag} doc{d} N={replicas} seed={seed}"),
                        &a.theta,
                        &b.theta,
                    );
                    assert_eq!(a.tokens, b.tokens);
                    assert_eq!(a.accepted, b.accepted, "MH chain diverged");
                    // served_by covers exactly the replicas owning the
                    // document's words.
                    let mut expect: Vec<u32> = doc
                        .iter()
                        .map(|&w| set.router().owner(w))
                        .collect();
                    expect.sort_unstable();
                    expect.dedup();
                    assert_eq!(b.served_by, expect, "[{tag}] served_by wrong");
                }
            }
        }
    }
}

/// Satellite: drop one replica mid-reload — the set keeps serving the
/// old generation with zero dropped requests; a re-install then commits
/// a set-wide generation bump visible to post-swap queries.
#[test]
fn replica_fault_mid_reload_keeps_serving_then_commits_set_wide() {
    let (_, meta, stores) = family_fixtures().remove(0);
    let set = ReplicaSet::from_stores(meta.clone(), stores.clone(), 3, 1 << 20).unwrap();
    let svc = Arc::new(InferenceService::spawn(
        set.clone(),
        ServeConfig {
            workers: 2,
            max_batch: 8,
            ..Default::default()
        },
    ));

    // Concurrent load across the faulted reload and the successful one.
    let n_threads = 4usize;
    let per_thread = 25usize;
    let mut joins = Vec::new();
    for th in 0..n_threads {
        let svc = svc.clone();
        let queries = hplvm::serve::synth_queries(48, per_thread, 12.0, 500 + th as u64);
        joins.push(std::thread::spawn(move || {
            let mut gens = Vec::with_capacity(per_thread);
            for doc in queries {
                let res = svc.infer(doc).expect("request dropped across faulted reload");
                assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
                gens.push(res.generation);
            }
            gens
        }));
    }

    // Mid-stream: replica 1 drops during the reload → set-wide abort.
    set.replica(1).fail_next_reload();
    let err = set
        .install_stores(meta.clone(), &stores)
        .expect_err("faulted reload must abort");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("injected fault") && msg.contains("still serving generation 1"),
        "{msg}"
    );
    assert_eq!(set.generation(), 1, "aborted reload must not bump the set");

    // Re-install (fault was one-shot): set-wide commit to generation 2.
    let g = set
        .install_stores(meta.clone(), &stores)
        .expect("clean reload must commit");
    assert_eq!(g, 2);
    assert_eq!(set.generation(), 2);

    let mut all_gens = Vec::new();
    for j in joins {
        all_gens.extend(j.join().expect("query thread panicked"));
    }
    assert_eq!(
        all_gens.len(),
        n_threads * per_thread,
        "every request must be answered across the faulted reload"
    );
    assert!(
        all_gens.iter().all(|&g| g == 1 || g == 2),
        "only committed set generations may serve: {all_gens:?}"
    );

    // Post-swap: strictly-after queries see the bumped set generation.
    let res = svc.infer(vec![0, 5, 10]).expect("service closed");
    assert_eq!(res.generation, 2, "post-commit query on the old generation");
    assert!(!res.served_by.is_empty());
    assert_eq!(
        svc.stats().served,
        (n_threads * per_thread + 1) as u64,
        "served-counter mismatch — something was dropped"
    );
    drop(svc);
}

/// Satellite (ROADMAP cold-cache fix): after a hot reload, the first
/// query for a previously-resident word must not trigger an O(K)
/// rebuild — the incoming generation's alias cache is pre-warmed from
/// the outgoing generation's resident word set.
#[test]
fn reload_prewarms_alias_cache_so_hot_words_never_rebuild() {
    use hplvm::ps::snapshot::{self, Store};
    let dir = std::env::temp_dir().join(format!(
        "hplvm_serve_prewarm_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let mut store = Store::new();
    for w in 0..10u32 {
        store.insert((0, w), if w < 5 { vec![50, 0] } else { vec![0, 50] }.into());
    }
    let meta = synth_meta("AliasLDA", 2, 10);
    let bytes = snapshot::encode_store_meta(&store, &meta);
    snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();

    // Single-handle path.
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let hot_doc = vec![0u32, 1, 2, 3, 4];
    infer_doc(&handle.model(), &hot_doc, &InferConfig::default(), &mut Rng::new(5));
    let old_stats = handle.model().cache_stats();
    assert!(old_stats.misses >= 5, "warm-up must have built tables");
    assert_eq!(handle.reload(&dir).unwrap(), 2);
    let new_model = handle.model();
    let warm = new_model.cache_stats();
    assert_eq!(warm.misses, 0, "pre-warm must not count as misses");
    assert!(
        warm.prewarmed as usize >= hot_doc.len(),
        "outgoing resident set not pre-warmed ({} tables)",
        warm.prewarmed
    );
    // The regression: first post-swap touch of a hot word is a hit.
    infer_doc(&new_model, &hot_doc, &InferConfig::default(), &mut Rng::new(6));
    let after = new_model.cache_stats();
    assert_eq!(
        after.misses, 0,
        "previously-resident words rebuilt after reload (cold-cache p99 spike)"
    );
    assert!(after.hits >= hot_doc.len() as u64);

    // Replica-set path: each replica pre-warms from its own outgoing
    // slice across a set-wide reload.
    let set = ReplicaSet::load_dir(&dir, 2).expect("replica-set load");
    let doc: Vec<u32> = (0..10).collect();
    set.infer(&doc, &InferConfig::default(), &mut Rng::new(7));
    assert_eq!(set.reload(&dir).unwrap(), 2);
    let gen = set.current();
    for (r, m) in gen.models().iter().enumerate() {
        let st = m.cache_stats();
        assert_eq!(st.misses, 0, "replica {r} pre-warm counted as misses");
    }
    set.infer(&doc, &InferConfig::default(), &mut Rng::new(8));
    for (r, m) in gen.models().iter().enumerate() {
        let st = m.cache_stats();
        assert_eq!(
            st.misses, 0,
            "replica {r} rebuilt a previously-resident word after the set reload"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
