//! Integration of the training → snapshot → serving pipeline: a trained
//! snapshot loads into the serving layer, fold-in queries return sane
//! topic mixtures, and scoring held-out documents with the *served*
//! mixtures lands within 10% of the evaluation stack's own perplexity on
//! the same frozen statistics.

use hplvm::config::TrainConfig;
use hplvm::coordinator::trainer::Trainer;
use hplvm::eval::perplexity::{perplexity, score_with_theta};
use hplvm::serve::{InferenceService, ServeConfig, ServingModel};
use std::sync::Arc;

/// One trained snapshot shared by the assertions below (training on the
/// simulated cluster dominates the test's cost, so do it once).
fn trained_snapshot(tag: &str, cfg: &TrainConfig) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hplvm_serve_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = cfg.clone();
    cfg.cluster.snapshot_dir = Some(dir.clone());
    let report = Trainer::new(cfg).run().expect("training failed");
    assert!(report.final_perplexity().is_finite());
    dir
}

fn serving_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::small_lda();
    // Keep the cluster small and fully seeded: this test is about the
    // serving handoff, not training scale.
    cfg.corpus.n_docs = 400;
    cfg.iterations = 12;
    cfg.eval_every = 6;
    cfg.test_docs = 60;
    cfg.cluster.clients = 2;
    cfg.seed = 4242;
    cfg.corpus.seed = 4242;
    cfg.cluster.net.seed = 4242;
    cfg.cluster.net.base_latency = std::time::Duration::from_micros(50);
    cfg.cluster.net.jitter = std::time::Duration::from_micros(50);
    cfg
}

#[test]
fn served_mixtures_match_eval_perplexity_within_10_percent() {
    let cfg = serving_cfg();
    let dir = trained_snapshot("perp", &cfg);

    let model = Arc::new(ServingModel::load_dir(&dir).expect("snapshot load"));
    // The v2 header reproduces the training hyperparameters.
    assert_eq!(model.k(), cfg.params.topics);
    assert_eq!(model.meta().model, cfg.model.name());
    assert_eq!(model.meta().alpha.to_bits(), cfg.params.alpha.to_bits());
    assert_eq!(model.meta().beta.to_bits(), cfg.params.beta.to_bits());
    assert_eq!(model.vocab(), cfg.corpus.vocab_size);

    // The held-out documents: the split is deterministic in the corpus
    // seed, so regenerating reproduces exactly what training held out.
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);

    // Baseline: the evaluation stack's EM fold-in on the same frozen φ.
    let baseline = perplexity(&*model, &test, 3, None);
    assert!(baseline.perplexity.is_finite() && baseline.perplexity > 1.0);

    // Served: every mixture comes out of the micro-batching service. A
    // few extra sweeps of averaging narrows the estimator gap between
    // the Gibbs fold-in and the baseline's EM fold-in.
    let svc = InferenceService::spawn(
        model.clone(),
        ServeConfig {
            infer: hplvm::serve::InferConfig {
                burnin: 5,
                samples: 5,
                mh_steps: 2,
            },
            ..Default::default()
        },
    );
    let thetas: Vec<Vec<f64>> = test
        .docs
        .iter()
        .map(|d| svc.infer(d.tokens.clone()).expect("service closed").theta)
        .collect();
    let served = score_with_theta(&*model, &test.docs, &thetas);
    svc.shutdown();

    assert_eq!(served.tokens, baseline.tokens);
    let rel = (served.perplexity - baseline.perplexity).abs() / baseline.perplexity;
    assert!(
        rel < 0.10,
        "served perplexity {:.2} vs eval {:.2} (rel {:.3})",
        served.perplexity,
        baseline.perplexity,
        rel
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_dir_round_trips_through_serving_layer() {
    let mut cfg = serving_cfg();
    cfg.corpus.n_docs = 200;
    cfg.iterations = 6;
    cfg.eval_every = 3;
    cfg.test_docs = 30;
    let dir = trained_snapshot("load", &cfg);

    // One snapshot per server slot, all self-describing.
    let slots: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.starts_with("server_slot") && n.ends_with(".snap")
        })
        .collect();
    assert_eq!(slots.len(), cfg.cluster.n_servers());

    let model = ServingModel::load_dir(&dir).expect("snapshot load");
    assert!(model.total_tokens() > 0, "frozen statistics are empty");
    assert_eq!(model.meta().n_servers as usize, cfg.cluster.n_servers());

    // Fold-in against the loaded model produces a proper distribution
    // that beats the uniform mixture on its own document.
    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let doc = test
        .docs
        .iter()
        .find(|d| d.tokens.len() >= 10)
        .expect("no usable held-out doc");
    let mut rng = hplvm::util::rng::Rng::new(7);
    let res = hplvm::serve::infer_doc(
        &model,
        &doc.tokens,
        &hplvm::serve::InferConfig::default(),
        &mut rng,
    );
    assert!((res.theta.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    let uniform = vec![vec![1.0 / model.k() as f64; model.k()]];
    let docs = vec![doc.clone()];
    let with_inferred = score_with_theta(&model, &docs, &[res.theta.clone()]);
    let with_uniform = score_with_theta(&model, &docs, &uniform);
    assert!(
        with_inferred.avg_log_lik >= with_uniform.avg_log_lik,
        "inferred mixture ({:.4}) scored below uniform ({:.4})",
        with_inferred.avg_log_lik,
        with_uniform.avg_log_lik
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn service_is_deterministic_and_batch_shape_invariant() {
    let mut cfg = serving_cfg();
    cfg.corpus.n_docs = 200;
    cfg.iterations = 5;
    cfg.eval_every = 5;
    cfg.test_docs = 20;
    let dir = trained_snapshot("det", &cfg);
    let model = Arc::new(ServingModel::load_dir(&dir).expect("snapshot load"));

    let (corpus, _) = cfg.corpus.generate();
    let (_, test) = corpus.split_test(cfg.test_docs);
    let run = |workers: usize, batch: usize| -> Vec<Vec<f64>> {
        let svc = InferenceService::spawn(
            model.clone(),
            ServeConfig {
                workers,
                max_batch: batch,
                ..Default::default()
            },
        );
        let rxs: Vec<_> = test
            .docs
            .iter()
            .map(|d| svc.submit(d.tokens.clone()))
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("service closed").theta)
            .collect();
        svc.shutdown();
        out
    };
    assert_eq!(
        run(1, 1),
        run(4, 16),
        "served mixtures depend on pool shape — RNG streams leak across requests"
    );
    std::fs::remove_dir_all(&dir).ok();
}
