//! Session-lifecycle integration tests: segments, cluster checkpoints,
//! cross-"process" resume under a preserved run id, file-backed corpora,
//! and the serving-layer handoff of resumed runs.
//!
//! Like `integration_cluster`, quality comparisons are *statistical*
//! (beat chance decisively, land in the same regime): every RNG is
//! seeded, but thread interleaving legitimately perturbs trajectories
//! under eventual consistency.

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::session::TrainSession;
use hplvm::corpus::source::{write_docword, FileSource, SyntheticSource};
use hplvm::serve::ServingModel;
use std::path::PathBuf;
use std::time::Duration;

fn base_cfg(model: ModelKind, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model;
    cfg.params.topics = 10;
    cfg.corpus.n_docs = 240;
    cfg.corpus.vocab_size = 500;
    cfg.corpus.n_topics = 10;
    cfg.corpus.doc_len_mean = 20.0;
    cfg.cluster.clients = 3;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(100);
    cfg.iterations = 8;
    cfg.eval_every = 4;
    cfg.test_docs = 40;
    cfg.seed = seed;
    cfg.corpus.seed = seed;
    cfg.cluster.net.seed = seed ^ 0x7EA7;
    cfg
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hplvm_session_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Chance level: a uniform model over the configured vocabulary.
fn chance(cfg: &TrainConfig) -> f64 {
    cfg.corpus.vocab_size as f64
}

/// Train K iterations straight through vs. K/2 → checkpoint → resume in a
/// *fresh* session → K/2 more. Statistically equivalent perplexity, and
/// the resumed run keeps the original `run_id` so its snapshots still
/// merge as the same run at serving time.
fn checkpoint_resume_parity(model: ModelKind, seed: u64, regime_ratio: f64) {
    let mut cfg = base_cfg(model, seed);
    if model == ModelKind::AliasPdp {
        cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
    }
    let k = cfg.iterations;
    let chance_level = chance(&cfg);

    // Reference: one session, straight to K.
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut straight = TrainSession::start(cfg.clone(), &src).unwrap();
    straight.run_to(k).unwrap();
    let p_straight = straight.finish().unwrap().final_perplexity();

    // Split: K/2, checkpoint, resume fresh, the remaining K/2.
    let ckpt = tmpdir(&format!("parity_{}", model.name()));
    let ckpt2 = tmpdir(&format!("parity2_{}", model.name()));
    let mut first = TrainSession::start(cfg.clone(), &src).unwrap();
    let run_id = first.run_id();
    let seg1 = first.run_to(k / 2).unwrap();
    assert_eq!(seg1.end_iteration, k / 2);
    assert!(seg1.report.final_perplexity().is_finite());
    first.checkpoint(&ckpt).unwrap();
    drop(first); // the "old process" goes away without a clean finish

    let mut resumed = TrainSession::resume(&ckpt).unwrap();
    assert_eq!(resumed.run_id(), run_id, "resume must keep the run id");
    assert_eq!(resumed.iteration(), k / 2);
    let seg2 = resumed.run_to(k).unwrap();
    assert_eq!((seg2.start_iteration, seg2.end_iteration), (k / 2, k));
    // Checkpoint the *resumed* run too: its snapshots must carry the
    // original run id and merge cleanly at serving time.
    resumed.checkpoint(&ckpt2).unwrap();
    let p_split = seg2.report.final_perplexity();
    let _ = resumed.finish().unwrap();

    assert!(p_straight.is_finite() && p_split.is_finite());
    assert!(
        p_straight < 0.7 * chance_level,
        "{model:?} straight run never converged ({p_straight:.1})"
    );
    assert!(
        p_split < 0.7 * chance_level,
        "{model:?} resumed run never converged ({p_split:.1})"
    );
    let ratio = (p_split / p_straight).max(p_straight / p_split);
    assert!(
        ratio < regime_ratio,
        "{model:?} straight {p_straight:.1} vs checkpoint/resume {p_split:.1} \
         (ratio {ratio:.2})"
    );

    // Serving accepts the resumed run's snapshots as one run.
    let served = ServingModel::load_dir(&ckpt2).expect("resumed checkpoint must serve");
    assert_eq!(served.meta().run_id, run_id, "serving sees the original run id");
    assert_eq!(served.kind().family_name(), model.family_name());
    assert!(served.total_tokens() > 0);

    std::fs::remove_dir_all(&ckpt).ok();
    std::fs::remove_dir_all(&ckpt2).ok();
}

#[test]
fn checkpoint_resume_parity_lda() {
    checkpoint_resume_parity(ModelKind::AliasLda, 41, 1.5);
}

#[test]
fn checkpoint_resume_parity_pdp() {
    // Table statistics re-derive through the CRP on resume and re-converge
    // via projection — a looser (but still same-regime) bound than LDA.
    checkpoint_resume_parity(ModelKind::AliasPdp, 43, 2.0);
}

/// A corpus written to the docword format and loaded back through
/// [`FileSource`] trains to finite (better-than-chance) perplexity via
/// the same `TrainSession` path — real corpora are first-class.
#[test]
fn file_source_trains_through_session() {
    let cfg = base_cfg(ModelKind::AliasLda, 47);
    let dir = tmpdir("docword");
    std::fs::create_dir_all(&dir).unwrap();
    let dw = dir.join("docword.txt");
    let (corpus, _) = cfg.corpus.generate();
    write_docword(&dw, &corpus).unwrap();
    // A vocab file wider than the docword header widens the effective V —
    // and must survive checkpoint/resume.
    let widened = corpus.vocab_size + 20;
    let vpath = dir.join("vocab.txt");
    let words: String = (0..widened).map(|w| format!("w{w:06}\n")).collect();
    std::fs::write(&vpath, words).unwrap();

    let src = FileSource::new(&dw).with_vocab(&vpath);
    let mut session = TrainSession::start(cfg.clone(), &src).unwrap();
    assert_eq!(session.vocab(), widened);
    let seg = session.run_to(6).unwrap();
    let p = seg.report.final_perplexity();
    assert!(p.is_finite(), "file-backed run produced {p}");
    assert!(
        p < 0.8 * chance(&cfg),
        "file-backed run never beat chance ({p:.1})"
    );

    // Checkpoint + resume records the docword path and reloads it.
    let ckpt = tmpdir("docword_ckpt");
    session.checkpoint(&ckpt).unwrap();
    let _ = session.finish().unwrap();
    let mut resumed = TrainSession::resume(&ckpt).unwrap();
    assert_eq!(resumed.iteration(), 6);
    assert_eq!(
        resumed.vocab(),
        widened,
        "the vocab file's widened V must survive resume"
    );
    let seg2 = resumed.run_for(2).unwrap();
    assert!(seg2.report.final_perplexity().is_finite());
    let _ = resumed.finish().unwrap();

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ckpt).ok();
}

/// Satellite regression: the auto-created temp snapshot dir used to be
/// deleted at the end of the run even when a checkpoint had been written
/// into it. Any directory a checkpoint went to survives `finish()`.
#[test]
fn checkpoint_into_auto_snapshot_dir_survives_finish() {
    let mut cfg = base_cfg(ModelKind::AliasLda, 53);
    cfg.iterations = 4;
    cfg.cluster.snapshot_every = Some(Duration::from_millis(50));
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(4).unwrap();
    let auto_dir = session
        .snapshot_dir()
        .expect("snapshot_every must auto-create a dir")
        .to_path_buf();
    session.checkpoint(&auto_dir).unwrap();
    let _ = session.finish().unwrap();
    assert!(
        auto_dir.join(hplvm::ps::snapshot::SESSION_META_NAME).exists(),
        "checkpointed auto dir was deleted by finish()"
    );
    // And it is a valid resume target.
    let resumed = TrainSession::resume(&auto_dir).unwrap();
    assert_eq!(resumed.iteration(), 4);
    drop(resumed);
    std::fs::remove_dir_all(&auto_dir).ok();

    // Control: without a checkpoint the auto temp dir is still cleaned up.
    let mut cfg = base_cfg(ModelKind::AliasLda, 59);
    cfg.iterations = 2;
    cfg.cluster.snapshot_every = Some(Duration::from_millis(50));
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(2).unwrap();
    let auto_dir = session.snapshot_dir().unwrap().to_path_buf();
    let _ = session.finish().unwrap();
    assert!(
        !auto_dir.exists(),
        "un-checkpointed auto temp dir must still be cleaned up"
    );
}

/// Resume refuses directories that are not (complete) checkpoints.
#[test]
fn resume_rejects_bad_directories() {
    let dir = tmpdir("not_a_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let err = match TrainSession::resume(&dir) {
        Ok(_) => panic!("empty dir must not resume"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("session"), "{err}");

    // A checkpoint whose slot snapshots are gone is partial.
    let mut cfg = base_cfg(ModelKind::AliasLda, 61);
    cfg.iterations = 2;
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(2).unwrap();
    let ckpt = tmpdir("partial_ckpt");
    session.checkpoint(&ckpt).unwrap();
    let _ = session.finish().unwrap();
    std::fs::remove_file(ckpt.join(hplvm::ps::snapshot::slot_snapshot_name(0))).unwrap();
    let err = match TrainSession::resume(&ckpt) {
        Ok(_) => panic!("partial checkpoint must not resume"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("missing"), "{err}");
    std::fs::remove_dir_all(&ckpt).ok();
}
