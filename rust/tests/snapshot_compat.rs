//! Snapshot format compatibility matrix — one table-driven test.
//!
//! Four snapshot formats exist on disk: v1 (`HPLVMSNP`, store body
//! only, no metadata), v2 (`HPLVMSN2`, hyperparameter header, no table
//! section), v3 (`HPLVMSN3`, + `run_id` + optional table-side
//! hyperparameters), and v4 (`HPLVMSN4`, the slot file is an LSM-style
//! *manifest* naming immutable segment files instead of carrying the
//! store body). Which combinations serve is a contract the individual
//! PR-era tests asserted piecemeal; this file pins the whole matrix in
//! one place:
//!
//! | format | LDA    | PDP    | HDP    |
//! |--------|--------|--------|--------|
//! | v1     | refuse | refuse | refuse | (no hyperparameters at all)
//! | v2     | serve  | refuse | refuse | (PDP/HDP need the v3 table section)
//! | v3     | serve  | serve  | serve  |
//! | v4     | serve  | serve  | serve  | (manifest + segment replay)
//!
//! A refused load must also say *why* in a way that points at the fix
//! (re-train), so each refusal asserts its diagnostic substring. The v4
//! row additionally pins the *reader* direction of the contract: a
//! pre-v4 full-dump reader ([`snapshot::decode_store_meta`]) must refuse
//! a v4 manifest outright — its magic is unknown to them — rather than
//! misread the segment list as row data.

use hplvm::ps::snapshot::{self, SnapshotMeta, Store, TableHyper};
use hplvm::serve::ServingModel;

fn synth_meta(model: &str, k: u32, vocab: u32) -> SnapshotMeta {
    SnapshotMeta {
        model: model.to_string(),
        k,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: vocab,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0xFEED,
        tables: None,
    }
}

/// One synthetic single-slot statistics set per family (same shapes the
/// serving tests use: LDA word–topic only; PDP customers + tables; HDP
/// word–topic + root sticks).
fn family_fixtures() -> Vec<(&'static str, SnapshotMeta, Store)> {
    const V: u32 = 48;
    let mut out = Vec::new();

    let mut lda = Store::new();
    for w in 0..V {
        let mut row = vec![0i32; 4];
        row[(w / 12) as usize] = 60 + (w % 5) as i32;
        lda.insert((0, w), row.into());
    }
    out.push(("lda", synth_meta("AliasLDA", 4, V), lda));

    let mut pdp = Store::new();
    for w in 0..V {
        let t = (w % 3) as usize;
        let mut m_row = vec![0i32; 3];
        let mut s_row = vec![0i32; 3];
        m_row[t] = 40 + (w % 4) as i32;
        s_row[t] = 4 + (w % 3) as i32;
        pdp.insert((0, w), m_row.into());
        pdp.insert((1, w), s_row.into());
    }
    let mut pdp_meta = synth_meta("AliasPDP", 3, V);
    pdp_meta.tables = Some(TableHyper {
        discount: 0.1,
        concentration: 10.0,
        root: 0.5,
    });
    out.push(("pdp", pdp_meta, pdp));

    let mut hdp = Store::new();
    for w in 0..V {
        let mut row = vec![0i32; 4];
        row[(w % 3) as usize] = 50 + (w % 6) as i32;
        hdp.insert((0, w), row.into());
    }
    hdp.insert((1, 0), vec![9, 6, 3, 0].into());
    let mut hdp_meta = synth_meta("AliasHDP", 4, V);
    hdp_meta.tables = Some(TableHyper {
        discount: 0.0,
        concentration: 1.0,
        root: 1.0,
    });
    out.push(("hdp", hdp_meta, hdp));
    out
}

#[test]
fn format_family_matrix_accepts_and_refuses_exactly_as_documented() {
    let base = std::env::temp_dir().join(format!("hplvm_compat_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    for (family, meta, store) in family_fixtures() {
        for version in ["v1", "v2", "v3", "v4"] {
            let dir = base.join(format!("{family}_{version}"));
            std::fs::create_dir_all(&dir).unwrap();
            if version == "v4" {
                // v4: written by the segment log's seal — a manifest
                // named like the legacy slot file plus immutable
                // segment files next to it.
                let mut log = snapshot::SegmentLog::new(0);
                log.seal_to(&dir, &store, &meta).unwrap();
                let name = snapshot::slot_snapshot_name(0);
                let manifest_bytes = std::fs::read(dir.join(&name)).unwrap();
                // Pre-v4 full-dump readers must refuse the manifest
                // outright (unknown magic), never misread it...
                assert!(
                    snapshot::decode_store_meta(&manifest_bytes).is_none(),
                    "{family}: a v4 manifest must not decode as a pre-v4 full dump"
                );
                // ...while the header-only probe (the `--watch`
                // fingerprint) and the versioned loader understand it.
                let m = snapshot::decode_meta_prefix(&manifest_bytes)
                    .expect("v4 header probe must parse")
                    .expect("v4 carries a header");
                assert_eq!(m.run_id, meta.run_id);
                assert_eq!(m.tables, meta.tables);
                let (lm, lstore, generation) =
                    snapshot::load_slot_file(&dir, &name).unwrap();
                assert_eq!(lstore, store, "{family} v4 segment replay round-trip");
                assert_eq!(lm.unwrap().model, meta.model);
                assert_eq!(generation, 1, "first seal is generation 1");
            } else {
                let bytes = match version {
                    // v1: store body only — no header to interpret.
                    "v1" => snapshot::encode_store(&store),
                    // v2: hyperparameter header, table section impossible
                    // (the encoder ignores meta.tables — v2 had nowhere to
                    // put it), which is exactly what makes PDP/HDP
                    // unservable from v2 files.
                    "v2" => snapshot::encode_store_meta_v2(&store, &meta),
                    _ => snapshot::encode_store_meta(&store, &meta),
                };
                snapshot::write_atomic(&dir.join(snapshot::slot_snapshot_name(0)), &bytes)
                    .unwrap();

                // Round-trip sanity: every pre-v4 format still *decodes*
                // — the refusals below are serving-layer policy, not
                // parse errors.
                let (decoded_meta, decoded_store) =
                    snapshot::decode_store_meta(&bytes).expect("all formats must decode");
                assert_eq!(decoded_store, store, "{family} {version} store round-trip");
                match version {
                    "v1" => assert!(decoded_meta.is_none(), "v1 carries no header"),
                    "v2" => {
                        let m = decoded_meta.unwrap();
                        assert_eq!(m.model, meta.model);
                        assert_eq!(m.run_id, 0, "v2 predates run ids");
                        assert!(m.tables.is_none(), "v2 has no table section");
                    }
                    _ => {
                        let m = decoded_meta.unwrap();
                        assert_eq!(m.run_id, meta.run_id);
                        assert_eq!(m.tables, meta.tables);
                    }
                }
            }

            let serves =
                matches!((version, family), ("v3", _) | ("v4", _) | ("v2", "lda"));
            match (serves, ServingModel::load_dir(&dir)) {
                (true, Ok(model)) => {
                    assert_eq!(model.kind().family_name(), family);
                    assert!(model.total_tokens() > 0, "{family} {version}");
                    assert_eq!(
                        model.meta().tables.is_some(),
                        matches!(version, "v3" | "v4") && family != "lda",
                    );
                }
                (true, Err(e)) => {
                    panic!("{family} {version} must serve, got: {e:#}")
                }
                (false, Ok(_)) => panic!("{family} {version} must be refused"),
                (false, Err(e)) => {
                    let msg = format!("{e:#}");
                    let needle = if version == "v1" {
                        // No hyperparameters at all.
                        "predate the v2 format"
                    } else {
                        // v2 PDP/HDP: counts but no table hyperparameters.
                        "predates format v3"
                    };
                    assert!(
                        msg.contains(needle) && msg.contains("re-train"),
                        "{family} {version} refusal must explain itself: {msg}"
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}
