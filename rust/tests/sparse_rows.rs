//! Property tests for the hybrid word-topic row (short-list → hash →
//! dense) and its conversions: dense/`RowData` round-trips across the
//! promotion thresholds, fold/add equivalence against a dense oracle,
//! wire-form parity with the dense-era encoder, the cell-level filter's
//! losslessness, and the client-snapshot v2 replica section.

use hplvm::ps::filter::Filter;
use hplvm::ps::snapshot::{self, ClientSnapshot};
use hplvm::sampler::counts::{CountMatrix, HybridRow, RowData, RowReprKind};
use hplvm::util::rng::Rng;

/// Apply a random op sequence to both a [`HybridRow`] and a dense oracle
/// vector, spread over topic ranges that cross the short→hash→dense
/// promotion thresholds.
fn drive(k: usize, ops: usize, seed: u64) -> (HybridRow, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let mut row = HybridRow::new(k);
    let mut oracle = vec![0i32; k];
    for _ in 0..ops {
        // Skew topics toward a small hot set so nnz grows slowly enough
        // to exercise every representation on the way up.
        let t = if rng.coin(0.5) {
            rng.below(8.min(k))
        } else {
            rng.below(k)
        };
        match rng.below(4) {
            0 => {
                let d = rng.below(9) as i32 - 4;
                row.add(t, d);
                oracle[t] = oracle[t].wrapping_add(d);
            }
            1 => {
                let v = rng.below(100) as i32 - 50;
                row.set(t, v);
                oracle[t] = v;
            }
            2 => {
                // Drive a cell back to exactly zero (nnz shrink path).
                row.set(t, 0);
                oracle[t] = 0;
            }
            _ => {
                let d = rng.below(5) as i32;
                row.add_saturating(t, d);
                oracle[t] = oracle[t].saturating_add(d);
            }
        }
    }
    (row, oracle)
}

fn assert_matches_oracle(row: &HybridRow, oracle: &[i32], ctx: &str) {
    assert_eq!(row.k(), oracle.len(), "{ctx}: width");
    for (t, &v) in oracle.iter().enumerate() {
        assert_eq!(row.get(t), v, "{ctx}: cell {t}");
    }
    assert_eq!(
        row.nnz(),
        oracle.iter().filter(|&&v| v != 0).count(),
        "{ctx}: nnz"
    );
    assert_eq!(&*row.to_dense_box(), oracle, "{ctx}: to_dense_box");
}

#[test]
fn prop_hybrid_row_tracks_dense_oracle_across_promotions() {
    for (k, ops, seed) in [
        (4usize, 200usize, 1u64), // tiny K: short → dense directly
        (16, 300, 2),             // dense cut = 8: short ↔ dense boundary
        (64, 600, 3),             // short → hash → dense
        (256, 2_000, 4),          // full ladder with a real hash stage
        (10_000, 3_000, 5),       // target regime: stays hash
    ] {
        let (row, oracle) = drive(k, ops, seed);
        assert_matches_oracle(&row, &oracle, &format!("k={k}"));
        // from_dense of the oracle equals the incrementally-built row.
        assert_eq!(row, HybridRow::from_dense(&oracle), "k={k}: from_dense");
    }
}

#[test]
fn prop_rowdata_roundtrip_and_wire_parity() {
    for seed in 0..20u64 {
        let k = [8usize, 32, 128, 1_024][seed as usize % 4];
        let (row, oracle) = drive(k, 50 + 40 * seed as usize, 100 + seed);
        // to_rowdata picks the same encoding and bytes as the dense-era
        // encoder fed the full-width row — wire traffic is bit-identical.
        let ours = row.to_rowdata();
        let dense_era = RowData::from_dense_auto(&oracle);
        assert_eq!(ours, dense_era, "k={k} seed={seed}: wire form");
        assert_eq!(ours.wire_bytes(), dense_era.wire_bytes());
        // Lossless both ways, whatever the width hint.
        let back = HybridRow::from_rowdata(&ours, k);
        assert_eq!(back, row, "k={k} seed={seed}: from_rowdata");
        assert_eq!(&*ours.to_dense(k), &oracle[..]);
    }
}

#[test]
fn promotion_thresholds_and_kinds() {
    // Short list holds the first 8 distinct topics.
    let k = 256usize;
    let mut row = HybridRow::new(k);
    for t in 0..8 {
        row.add(t, 1);
    }
    assert_eq!(row.repr_kind(), RowReprKind::Short);
    // 9th distinct topic spills to the hash stage (dense cut is k/4=64).
    row.add(100, 1);
    assert_eq!(row.repr_kind(), RowReprKind::Hash);
    // Crossing ~K/4 occupancy promotes to dense.
    for t in 0..80 {
        row.add(t, 1);
    }
    assert_eq!(row.repr_kind(), RowReprKind::Dense);
    assert_eq!(row.nnz(), 81);

    // Tiny K skips the hash stage: the 9th topic goes straight dense.
    let mut tiny = HybridRow::new(16);
    for t in 0..9 {
        tiny.add(t, 1);
    }
    assert_eq!(tiny.repr_kind(), RowReprKind::Dense);

    // compact() demotes a dense row whose nnz collapsed.
    let mut big = HybridRow::from_dense(&vec![1; 256]);
    assert_eq!(big.repr_kind(), RowReprKind::Dense);
    for t in 0..253 {
        big.set(t, 0);
    }
    big.compact();
    assert_ne!(big.repr_kind(), RowReprKind::Dense);
    assert_eq!(big.nnz(), 3);
    assert_eq!(big.get(254), 1);
}

#[test]
fn prop_fold_and_add_match_dense_oracle() {
    for seed in 0..10u64 {
        let k = 64usize;
        let (mut row, mut oracle) = drive(k, 150, 200 + seed);
        let (delta_row, delta) = drive(k, 100, 300 + seed);
        let wire = delta_row.to_rowdata();

        let mut folded = row.clone();
        folded.fold_rowdata(&wire);
        for (t, &d) in delta.iter().enumerate() {
            let want = oracle[t].saturating_add(d);
            assert_eq!(folded.get(t), want, "seed={seed}: fold cell {t}");
        }

        row.add_rowdata(&wire);
        for (t, &d) in delta.iter().enumerate() {
            oracle[t] = oracle[t].wrapping_add(d);
            assert_eq!(row.get(t), oracle[t], "seed={seed}: add cell {t}");
        }
    }
}

#[test]
fn prop_count_matrix_export_import_roundtrip() {
    let mut rng = Rng::new(77);
    let (vocab, k) = (40usize, 500usize);
    let mut m = CountMatrix::new(vocab, k);
    for _ in 0..5_000 {
        let w = rng.below(vocab) as u32;
        let t = rng.below(k);
        m.inc_local(w, t, 1 + rng.below(3) as i32);
    }
    let exported = m.export_rows();
    let mut m2 = CountMatrix::new(vocab, k);
    for (w, row) in &exported {
        m2.apply_pull_row(*w, row);
    }
    for w in 0..vocab as u32 {
        for t in 0..k {
            assert_eq!(m2.get(w, t), m.get(w, t), "word {w} topic {t}");
        }
    }
    assert_eq!(m2.totals(), m.totals());
}

#[test]
fn prop_cell_filter_partition_is_lossless() {
    let mut rng = Rng::new(99);
    for trial in 0..30u64 {
        let filter = Filter {
            magnitude_fraction: rng.f64(),
            uniform_prob: rng.f64() * 0.5,
            cell_level: true,
        };
        let k = 32usize;
        let rows: Vec<(u32, RowData)> = (0..2 + rng.below(10))
            .map(|w| {
                let (row, dense) = drive(k, rng.below(60), 1_000 + trial * 100 + w as u64);
                let data = if rng.coin(0.5) {
                    row.to_rowdata()
                } else {
                    RowData::Dense(dense.into_boxed_slice())
                };
                (w as u32, data)
            })
            .collect();
        // Dense totals per word before/after must match exactly.
        let total_of = |batch: &[(u32, RowData)]| -> Vec<(u32, Vec<i32>)> {
            let mut m: std::collections::BTreeMap<u32, Vec<i32>> = Default::default();
            for (w, r) in batch {
                let acc = m.entry(*w).or_insert_with(|| vec![0i32; k]);
                for (t, &v) in r.to_dense(k).iter().enumerate() {
                    acc[t] += v;
                }
            }
            m.into_iter().collect()
        };
        let before = total_of(&rows);
        let (send, retain) = filter.select(rows, &mut rng);
        let mut merged = send;
        merged.extend(retain);
        assert_eq!(total_of(&merged), before, "trial {trial}");
    }
}

#[test]
fn client_snapshot_v2_replicas_roundtrip() {
    let snap = ClientSnapshot {
        shard: 2,
        iteration: 9,
        z: vec![vec![0, 1, 2]],
        r: vec![vec![false, true, false]],
        replicas: vec![
            (0, vec![(4, RowData::Sparse(vec![(0, 3), (7, -1)]))]),
            (
                1,
                vec![(0, RowData::Dense(vec![5, 0, 2].into_boxed_slice()))],
            ),
        ],
    };
    let bytes = snapshot::encode_client(&snap);
    assert_eq!(snapshot::decode_client(&bytes).unwrap(), snap);

    // Replica rows survive a HybridRow round-trip too (the worker's
    // checkpoint → export_rows → apply_pull_row path).
    for (_, rows) in &snap.replicas {
        for (_, data) in rows {
            let width = data.min_width().max(8);
            let row = HybridRow::from_rowdata(data, width);
            assert_eq!(&*row.to_dense_box(), &*data.to_dense(width));
        }
    }
}
