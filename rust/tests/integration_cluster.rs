//! Integration tests across the parameter-server + sampler + projection
//! stack: distributed training equivalence, lossy transport, projection
//! placements, and the end-to-end consistency story.
//!
//! Every configuration is seeded end-to-end — corpus generation, the
//! samplers (each worker derives its stream from `cfg.seed`), and the
//! transport's latency/drop decisions (`net.seed`). Thread interleaving
//! still varies between runs, so cross-run quality comparisons are
//! *statistical*: a run must decisively beat chance (perplexity far
//! below the vocabulary size) and land in the same quality regime as its
//! reference, not reproduce it to a few percent.

use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use std::time::Duration;

fn base_cfg(model: ModelKind, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = model;
    cfg.params.topics = 10;
    cfg.corpus.n_docs = 240;
    cfg.corpus.vocab_size = 500;
    cfg.corpus.n_topics = 10;
    cfg.corpus.doc_len_mean = 20.0;
    cfg.cluster.clients = 3;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(100);
    cfg.iterations = 8;
    cfg.eval_every = 4;
    cfg.test_docs = 40;
    // Fixed RNG seeds end-to-end: global (samplers), corpus synthesis,
    // and the simulated transport's jitter/drop stream.
    cfg.seed = seed;
    cfg.corpus.seed = seed;
    cfg.cluster.net.seed = seed ^ 0x7EA7;
    cfg
}

/// Chance level: a uniform model over the configured vocabulary.
fn chance(cfg: &TrainConfig) -> f64 {
    cfg.corpus.vocab_size as f64
}

/// Distributed AliasLDA must converge to roughly the same perplexity as a
/// single-client run — eventual consistency costs iterations, not
/// correctness. The comparison is statistical (same quality regime, both
/// decisively better than chance), not bit-level: thread scheduling
/// legitimately perturbs the trajectories.
#[test]
fn distributed_matches_single_client_quality() {
    let mut single = base_cfg(ModelKind::AliasLda, 11);
    single.cluster.clients = 1;
    single.iterations = 10;
    let chance_level = chance(&single);
    let rep1 = Trainer::new(single).run().unwrap();

    let mut multi = base_cfg(ModelKind::AliasLda, 11);
    multi.cluster.clients = 4;
    multi.iterations = 10;
    let rep4 = Trainer::new(multi).run().unwrap();

    let p1 = rep1.final_perplexity();
    let p4 = rep4.final_perplexity();
    assert!(p1.is_finite() && p4.is_finite());
    // Both runs must have actually learned the corpus structure…
    assert!(
        p1 < 0.6 * chance_level,
        "single-client run never converged ({p1:.1})"
    );
    assert!(
        p4 < 0.6 * chance_level,
        "distributed run never converged ({p4:.1})"
    );
    // …and land in the same quality regime.
    let ratio = (p4 / p1).max(p1 / p4);
    assert!(
        ratio < 1.5,
        "single {p1:.1} vs distributed {p4:.1} (ratio {ratio:.2})"
    );
}

/// A lossy, high-latency transport slows mixing but must not break
/// training (the eventual-consistency claim).
#[test]
fn survives_lossy_network() {
    let mut cfg = base_cfg(ModelKind::AliasLda, 13);
    cfg.cluster.net.drop_prob = 0.15;
    cfg.cluster.net.base_latency = Duration::from_millis(1);
    cfg.cluster.net.jitter = Duration::from_millis(2);
    let chance_level = chance(&cfg);
    let rep = Trainer::new(cfg).run().unwrap();
    assert!(rep.final_perplexity().is_finite());
    let (_, dropped, _, _) = rep.net;
    assert!(dropped > 0, "drop injection never fired");
    // Quality is degraded but sane: better than chance.
    assert!(rep.final_perplexity() < 0.9 * chance_level);
}

/// All three projection algorithm placements keep PDP training stable.
#[test]
fn projection_placements_all_converge_pdp() {
    let mut finals = Vec::new();
    for mode in [
        ProjectionMode::SingleMachine,
        ProjectionMode::Distributed,
        ProjectionMode::OnDemandServer,
    ] {
        let mut cfg = base_cfg(ModelKind::AliasPdp, 17);
        cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
        cfg.projection = mode;
        cfg.cluster.net.drop_prob = 0.05;
        let chance_level = chance(&cfg);
        let rep = Trainer::new(cfg).run().unwrap();
        let p = rep.final_perplexity();
        assert!(p.is_finite(), "{mode:?} produced non-finite perplexity");
        assert!(p < chance_level, "{mode:?} never beat chance ({p:.1})");
        finals.push((mode, p));
    }
    // All placements land in the same quality regime (statistical bound;
    // the placements run different correction schedules by design).
    let max = finals.iter().map(|&(_, p)| p).fold(0.0f64, f64::max);
    let min = finals.iter().map(|&(_, p)| p).fold(f64::MAX, f64::min);
    assert!(
        max / min < 2.0,
        "projection placements disagree wildly: {finals:?}"
    );
}

/// Algorithm 3 (server-side) actually performs corrections when the
/// transport is hostile.
#[test]
fn ondemand_server_projection_corrects() {
    let mut cfg = base_cfg(ModelKind::AliasPdp, 19);
    cfg.corpus.model = hplvm::corpus::generator::GenerativeModel::Pyp;
    cfg.projection = ProjectionMode::OnDemandServer;
    cfg.cluster.net.drop_prob = 0.20;
    cfg.cluster.clients = 4;
    let rep = Trainer::new(cfg).run().unwrap();
    assert!(
        rep.corrections > 0,
        "server-side projection never corrected anything under 20% loss"
    );
}

/// The data-points column must never exceed the client count and the
/// iteration times must be recorded for every row.
#[test]
fn report_shape_is_sane() {
    let cfg = base_cfg(ModelKind::AliasLda, 23);
    let clients = cfg.cluster.clients as u64;
    let rep = Trainer::new(cfg).run().unwrap();
    assert!(!rep.per_iteration.is_empty());
    for row in &rep.per_iteration {
        assert!(row.datapoints <= clients);
        if row.datapoints > 0 {
            assert!(row.time.mean() > 0.0);
            assert!(row.topics_per_word.mean() > 0.0);
        }
    }
    assert!(rep.tokens_per_sec > 0.0);
    assert!(rep.net.0 > 0, "no network traffic recorded");
}

/// HDP under the full distributed stack stays within its truncation and
/// produces finite estimates with projection enabled.
#[test]
fn hdp_distributed_with_drops() {
    let mut cfg = base_cfg(ModelKind::AliasHdp, 29);
    cfg.params.topics = 24;
    cfg.cluster.net.drop_prob = 0.10;
    cfg.projection = ProjectionMode::Distributed;
    let rep = Trainer::new(cfg).run().unwrap();
    assert!(rep.final_perplexity().is_finite());
    assert!(rep.final_log_lik().is_finite());
}

/// Determinism: two runs with identical config and seed produce identical
/// corpora and the same *number* of records (thread scheduling may differ,
/// so values can differ — but the workload structure must be stable).
#[test]
fn run_structure_is_reproducible() {
    let cfg = base_cfg(ModelKind::AliasLda, 31);
    let a = Trainer::new(cfg.clone()).run().unwrap();
    let b = Trainer::new(cfg).run().unwrap();
    assert_eq!(a.per_iteration.len(), b.per_iteration.len());
    assert_eq!(a.total_tokens, b.total_tokens);
}
