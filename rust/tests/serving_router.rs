//! Property tests for the multi-replica query router: the vocabulary
//! partition is total and disjoint for any replica count, growing the
//! set `N → N+1` remaps only the expected ~`1/(N+1)` fraction of words
//! (and never moves a word between existing replicas), and the
//! per-replica model slices materialize exactly the partition the
//! router announces.

use hplvm::ps::snapshot::{SnapshotMeta, Store};
use hplvm::serve::{QueryRouter, ReplicaSet, ServingModel};
use hplvm::util::rng::Rng;

/// 1000 randomized cases: for any replica count and vocabulary size,
/// every word is owned by exactly one replica and the per-replica lists
/// cover the vocabulary.
#[test]
fn partition_is_total_and_disjoint_1000_cases() {
    let mut rng = Rng::new(0x90_07E5);
    for case in 0..1000 {
        let replicas = 1 + rng.below(8);
        let vocab = 1 + rng.below(2048);
        let router = QueryRouter::new(replicas);
        assert_eq!(router.replicas(), replicas);
        let parts = router.partition(vocab);
        assert_eq!(parts.len(), replicas);
        assert_eq!(
            parts.iter().map(Vec::len).sum::<usize>(),
            vocab,
            "case {case}: partition not total (N={replicas}, V={vocab})"
        );
        let mut owner_of = vec![usize::MAX; vocab];
        for (r, part) in parts.iter().enumerate() {
            for &w in part {
                assert!(
                    (w as usize) < vocab,
                    "case {case}: out-of-vocab word {w}"
                );
                assert_eq!(
                    owner_of[w as usize],
                    usize::MAX,
                    "case {case}: word {w} owned by two replicas"
                );
                owner_of[w as usize] = r;
                assert_eq!(
                    router.owner(w) as usize,
                    r,
                    "case {case}: partition disagrees with owner()"
                );
            }
        }
        assert!(
            owner_of.iter().all(|&o| o != usize::MAX),
            "case {case}: some word has no owner"
        );
        // Scatter agrees with the partition for a random document.
        let doc: Vec<u32> = (0..rng.below(64)).map(|_| rng.below(vocab) as u32).collect();
        let scatter = router.scatter(&doc);
        assert_eq!(scatter.iter().map(Vec::len).sum::<usize>(), doc.len());
        for (r, indices) in scatter.iter().enumerate() {
            for &i in indices {
                assert_eq!(owner_of[doc[i] as usize], r);
            }
        }
    }
}

/// 1000 randomized resize cases: the consistent-hash monotonicity
/// invariant — a word's owner either stays put or moves to the *new*
/// replica, never between existing replicas.
#[test]
fn resize_moves_words_only_to_the_new_replica_1000_cases() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..1000 {
        let n = 1 + rng.below(7);
        let old = QueryRouter::new(n);
        let new = QueryRouter::new(n + 1);
        // A random probe set is enough for the invariant (the fraction
        // bound gets its own exhaustive test below).
        for _ in 0..64 {
            let w = rng.below(1 << 20) as u32;
            let a = old.owner(w);
            let b = new.owner(w);
            assert!(
                a == b || b == n as u32,
                "case {case}: word {w} moved between existing replicas \
                 ({a} → {b}, N={n})"
            );
        }
    }
}

/// Growing `N → N+1` remaps ≈ `1/(N+1)` of a large vocabulary.
#[test]
fn resize_remaps_about_one_over_n_plus_one() {
    const VOCAB: usize = 50_000;
    for n in 1..=6usize {
        let old = QueryRouter::new(n);
        let new = QueryRouter::new(n + 1);
        let moved = (0..VOCAB as u32)
            .filter(|&w| old.owner(w) != new.owner(w))
            .count();
        let frac = moved as f64 / VOCAB as f64;
        let expect = 1.0 / (n + 1) as f64;
        assert!(
            frac > 0.4 * expect && frac < 2.2 * expect,
            "{n}→{} replicas remapped {frac:.4} of the vocab (expected ≈{expect:.4})",
            n + 1
        );
    }
}

fn toy_meta(vocab: u32) -> SnapshotMeta {
    SnapshotMeta {
        model: "AliasLDA".to_string(),
        k: 4,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: vocab,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0,
        tables: None,
    }
}

/// Statistics with every word observed, spread over 4 topics.
fn toy_stores(vocab: u32) -> Vec<Store> {
    let mut s = Store::new();
    for w in 0..vocab {
        let mut row = vec![0i32; 4];
        row[(w % 4) as usize] = 10 + (w % 7) as i32;
        s.insert((0, w), row.into());
    }
    vec![s]
}

/// The replica slices materialize exactly the router's partition: each
/// observed word's row lives on its owner and nowhere else, and the
/// slices' union is the full model's row set.
#[test]
fn slices_materialize_exactly_the_router_partition() {
    const VOCAB: u32 = 512;
    let full = ServingModel::from_stores(toy_meta(VOCAB), toy_stores(VOCAB), 1 << 20).unwrap();
    for replicas in [2usize, 3, 5] {
        let set =
            ReplicaSet::from_stores(toy_meta(VOCAB), toy_stores(VOCAB), replicas, 1 << 20)
                .unwrap();
        let gen = set.current();
        for w in 0..VOCAB {
            let owners: Vec<usize> = gen
                .models()
                .iter()
                .enumerate()
                .filter(|(_, m)| m.has_row(w))
                .map(|(r, _)| r)
                .collect();
            if full.has_row(w) {
                assert_eq!(
                    owners,
                    vec![set.router().owner(w) as usize],
                    "word {w} must live on exactly its owner ({replicas} replicas)"
                );
            } else {
                assert!(owners.is_empty(), "unobserved word {w} grew a row");
            }
        }
    }
}
