//! End-to-end assertions for the sparse hot path: the end-of-iteration
//! sync ships ≥2× fewer bytes than the dense-era wire format, and AliasLDA
//! trained *through* the sparse wire (push → server aggregate → sparse
//! pull → replica merge, every sweep) lands in the same posterior regime
//! as a purely local run.

use std::time::Duration;

use hplvm::corpus::generator::CorpusConfig;
use hplvm::ps::client::{ClientEvent, PsClient};
use hplvm::ps::filter::Filter;
use hplvm::ps::msg::Payload;
use hplvm::ps::network::{NetConfig, SimNet};
use hplvm::ps::server::{ServerConfig, ServerGroup};
use hplvm::sampler::alias_lda::AliasLda;
use hplvm::sampler::DocSampler;
use hplvm::util::rng::Rng;

fn fast_net(seed: u64) -> SimNet {
    SimNet::new(
        0,
        NetConfig {
            base_latency: Duration::from_micros(50),
            jitter: Duration::ZERO,
            drop_prob: 0.0,
            seed,
        },
    )
}

fn joint_ll(s: &AliasLda, beta: f64, beta_bar: f64) -> f64 {
    let mut ll = 0.0;
    for (d, doc) in s.docs.iter().enumerate() {
        for (i, &w) in doc.tokens.iter().enumerate() {
            let t = s.state.z[d][i] as usize;
            let phi = (s.nwt.get(w, t).max(0) as f64 + beta)
                / ((s.nwt.total(t) as f64).max(0.0) + beta_bar);
            ll += phi.max(1e-300).ln();
        }
    }
    ll
}

/// Acceptance gate: at K=256 (the small_lda family's serving tier), a
/// steady-state end-of-iteration sync measured through `SimNet`'s byte
/// accounting costs at most half of what the dense-era encoding
/// (4 bytes × K per row, every row) would have shipped.
#[test]
fn end_of_iteration_sync_bytes_drop_2x_vs_dense() {
    let k = 256usize;
    let vocab = 500usize;
    let (c, _) = CorpusConfig {
        n_docs: 120,
        vocab_size: vocab,
        n_topics: 16,
        doc_len_mean: 30.0,
        seed: 11,
        ..Default::default()
    }
    .generate();
    let mut rng = Rng::new(42);
    let mut s = AliasLda::new(c.docs, vocab, k, 0.1, 0.01, &mut rng);
    // Discard the init burst; measure a real steady-state sweep's sync.
    let _ = s.nwt.drain_deltas();
    for d in 0..s.docs.len() {
        s.sample_doc(d, &mut rng);
    }
    let rows = s.nwt.drain_deltas();
    assert!(!rows.is_empty(), "a sweep must leave deltas to sync");

    // Dense-era cost of the same sync: every row 4 (key) + 5 + 4·K bytes
    // (see Payload::wire_bytes), same 16-byte message framing.
    let dense_bytes: u64 = 16 + rows.len() as u64 * (4 + 5 + 4 * k as u64);

    // Actual cost through the transport's byte metric.
    let net = SimNet::new(2, NetConfig::default());
    let payload = Payload::Push { matrix: 0, rows };
    let payload_bytes = payload.wire_bytes();
    assert!(net.send(0, 1, payload));
    let (_, _, _, sim_bytes) = net.stats();
    assert_eq!(
        sim_bytes, payload_bytes,
        "SimNet accounting must match the payload encoding"
    );
    assert!(
        sim_bytes * 2 <= dense_bytes,
        "sync shipped {sim_bytes} bytes; dense era would ship {dense_bytes} — \
         expected ≥2× reduction"
    );
}

/// AliasLDA trained over the sparse wire (a full push/aggregate/pull round
/// trip per sweep, rows in whichever encoding the density picks) must
/// match a purely local run's posterior at the dense-era tolerance (5%
/// relative joint log-likelihood, the same bar the alias-vs-sparse
/// sampler parity test uses).
#[test]
fn alias_lda_over_sparse_wire_matches_local_posterior() {
    let (vocab, k, beta) = (250usize, 16usize, 0.01);
    let beta_bar = beta * vocab as f64;
    let (c, _) = CorpusConfig {
        n_docs: 120,
        vocab_size: vocab,
        n_topics: 8,
        doc_len_mean: 30.0,
        seed: 9,
        ..Default::default()
    }
    .generate();

    // Local reference: no parameter server in the loop.
    let mut rng_a = Rng::new(100);
    let mut local = AliasLda::new(c.docs.clone(), vocab, k, 0.1, beta, &mut rng_a);

    // Wired run: one client, two server slots (exercises ring routing of
    // sparse rows), sync every sweep.
    let net = fast_net(5);
    let me = net.add_node();
    let group = ServerGroup::spawn(
        &net,
        ServerConfig {
            n_servers: 2,
            row_width: k,
            ..Default::default()
        },
    );
    let mut client = PsClient::new(
        net.clone(),
        me,
        group.ring.clone(),
        group.slots.clone(),
        group.frozen.clone(),
        Filter::default(),
        7,
    );
    let mut rng_b = Rng::new(200);
    let mut wired = AliasLda::new(c.docs, vocab, k, 0.1, beta, &mut rng_b);
    let words: Vec<u32> = (0..vocab as u32).collect();

    let ll0 = joint_ll(&wired, beta, beta_bar);
    for _ in 0..20 {
        for d in 0..local.docs.len() {
            local.sample_doc(d, &mut rng_a);
            wired.sample_doc(d, &mut rng_b);
        }
        let _ = local.nwt.drain_deltas();
        // End-of-iteration sync for the wired run: push, then pull every
        // word and merge whatever arrives (replica := server + pending).
        client.push_matrix(0, &mut wired.nwt);
        std::thread::sleep(Duration::from_millis(5));
        client.request_rows(0, &words);
        let mut got = 0usize;
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while got < vocab && std::time::Instant::now() < deadline {
            for ev in client.drain_responses(Duration::from_millis(20)) {
                if let ClientEvent::Rows(0, rows) = ev {
                    for (w, row) in rows {
                        wired.nwt.apply_pull_row(w, &row);
                        wired.invalidate_word(w);
                        got += 1;
                    }
                }
            }
        }
        assert_eq!(got, vocab, "pull responses missing");
    }
    let lla = joint_ll(&local, beta, beta_bar);
    let llb = joint_ll(&wired, beta, beta_bar);
    assert!(
        llb > ll0 + 100.0,
        "wired training failed to improve: {ll0} -> {llb}"
    );
    let rel = (lla - llb).abs() / lla.abs();
    assert!(
        rel < 0.05,
        "posterior regime mismatch: local {lla} vs sparse-wire {llb} ({rel:.3} rel)"
    );
    group.shutdown();
}
