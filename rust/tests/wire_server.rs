//! End-to-end tests for the wire front-end ([`hplvm::net`]): the framed
//! protocol server on its thread-per-core reactor, driven by the load
//! generator over real sockets.
//!
//! The contract under test: answers off the wire are **bit-identical**
//! to in-process answers at the same service seed (request seeds travel
//! in-band); a hot reload mid-stream advances generations with zero
//! dropped or errored frames; routed (multi-replica) serving over the
//! wire matches single-replica bit-for-bit; and malformed input —
//! truncated frames, oversize lengths, foreign versions, unknown
//! opcodes, garbage payloads — never takes the server down or disturbs
//! other connections.

use hplvm::net::loadgen;
use hplvm::net::proto::{self, err, op, Request, Response};
use hplvm::net::{
    connection_queries, frame, ListenAddr, LoadgenConfig, ModelInfo, WireConfig, WireServer,
};
use hplvm::ps::snapshot::{self, SnapshotMeta, Store};
use hplvm::serve::{InferenceService, ReplicaSet, ServeConfig, ServingHandle};
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic toy statistics: every word observed, spread over `k`
/// topics; `bump` perturbs the counts so generation 2 is a genuinely
/// different model.
fn write_snapshot(dir: &Path, k: u32, vocab: u32, bump: i32) {
    let mut store = Store::new();
    for w in 0..vocab {
        let mut row = vec![0i32; k as usize];
        row[(w % k) as usize] = 10 + (w % 7) as i32 + bump;
        store.insert((0, w), row.into());
    }
    let meta = SnapshotMeta {
        model: "AliasLDA".to_string(),
        k,
        alpha: 0.1,
        beta: 0.01,
        vocab_size: vocab,
        slot: 0,
        n_servers: 1,
        vnodes: 8,
        iterations: 1,
        run_id: 0,
        tables: None,
    };
    let bytes = snapshot::encode_store_meta(&store, &meta);
    snapshot::write_atomic(&dir.join("server_slot0.snap"), &bytes).unwrap();
}

fn snapshot_dir(tag: &str, k: u32, vocab: u32) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hplvm_wire_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_snapshot(&dir, k, vocab, 0);
    dir
}

fn model_info(handle: &ServingHandle) -> ModelInfo {
    let m = handle.model();
    ModelInfo {
        family: m.kind().family_name().to_string(),
        k: m.k() as u32,
        vocab: m.vocab() as u32,
    }
}

/// Blocking raw client for the protocol-robustness tests.
fn connect(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
    let _ = s.set_nodelay(true);
    s
}

/// Read one frame off a blocking socket (10 s deadline). `None` = the
/// peer closed (or went silent) without completing a frame.
fn read_frame(s: &mut TcpStream, buf: &mut Vec<u8>) -> Option<frame::Frame> {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut chunk = [0u8; 4096];
    loop {
        if let Ok(Some((f, used))) = frame::decode(buf) {
            buf.drain(..used);
            return Some(f);
        }
        if Instant::now() > deadline {
            return None;
        }
        match s.read(&mut chunk) {
            Ok(0) => {
                return match frame::decode(buf) {
                    Ok(Some((f, used))) => {
                        buf.drain(..used);
                        Some(f)
                    }
                    _ => None,
                };
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(ref e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return None,
        }
    }
}

fn expect_error(s: &mut TcpStream, buf: &mut Vec<u8>, want_code: u8, what: &str) {
    let f = read_frame(s, buf).unwrap_or_else(|| panic!("{what}: no error frame"));
    match proto::decode_response(&f) {
        Ok(Response::Error { code, .. }) => {
            assert_eq!(code, want_code, "{what}: wrong error code")
        }
        other => panic!("{what}: expected an error frame, got {other:?}"),
    }
}

fn expect_pong(s: &mut TcpStream, buf: &mut Vec<u8>, want_id: u64, what: &str) {
    let f = read_frame(s, buf).unwrap_or_else(|| panic!("{what}: no PONG"));
    match proto::decode_response(&f) {
        Ok(Response::Pong { id }) => assert_eq!(id, want_id, "{what}: PONG id"),
        other => panic!("{what}: expected PONG, got {other:?}"),
    }
}

/// The acceptance core: ≥64 requests in flight across 8 connections
/// against a 2-reactor server, zero drops or errors, and every θ off the
/// wire bit-identical to the in-process [`InferenceService`] answer at
/// the same service seed + request seed.
#[test]
fn wire_answers_match_in_process_bitwise_under_concurrency() {
    let dir = snapshot_dir("parity", 8, 64);
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let server = WireServer::start(
        handle.clone(),
        model_info(&handle),
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig::default(),
    )
    .expect("server start");

    // 8 connections × window 16 = up to 128 requests in flight.
    let lg = LoadgenConfig {
        connections: 8,
        requests: 16,
        window: 16,
        vocab: 64,
        doc_len: 12.0,
        keep_responses: true,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(server.local_addr(), &lg).expect("loadgen");
    assert_eq!(report.errors, 0, "errored frames under concurrent load");
    assert_eq!(report.timed_out, 0, "dropped requests under concurrent load");
    assert_eq!(report.answered, 8 * 16, "every request must be answered");
    assert_eq!(report.responses.len(), 8 * 16);

    // Replay the identical streams in-process: same service seed (the
    // default both here and in WireConfig::default), same request seeds.
    let svc = InferenceService::spawn(handle.clone(), ServeConfig::default());
    for ans in &report.responses {
        let queries = connection_queries(&lg, ans.conn);
        let (seed, tokens) = &queries[ans.id as usize];
        assert_eq!(*seed, ans.seed, "stream seed mismatch");
        let local = svc
            .submit_with_seed(tokens.clone(), *seed)
            .recv()
            .expect("in-process answer");
        assert_eq!(local.generation, ans.generation);
        let wire_bits: Vec<u64> = ans.theta.iter().map(|t| t.to_bits()).collect();
        let local_bits: Vec<u64> = local.theta.iter().map(|t| t.to_bits()).collect();
        assert_eq!(
            wire_bits, local_bits,
            "conn {} request {}: θ off the wire differs from in-process",
            ans.conn, ans.id
        );
    }
    svc.shutdown();

    let stats = server.stats();
    assert_eq!(stats.served, 8 * 16);
    assert_eq!(stats.errors, 0);
    assert!(stats.accepted >= 8);
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Hot reload mid-stream: swap in a new snapshot while the loadgen is
/// pumping; generations advance 1 → 2, and not a single request drops
/// or errors across the swap.
#[test]
fn hot_reload_mid_stream_advances_generations_with_zero_drops() {
    let dir = snapshot_dir("reload", 8, 64);
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let server = WireServer::start(
        handle.clone(),
        model_info(&handle),
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig::default(),
    )
    .expect("server start");
    let addr = server.local_addr().to_string();

    let lg = LoadgenConfig {
        connections: 4,
        requests: 150,
        window: 4,
        vocab: 64,
        doc_len: 10.0,
        timeout: Duration::from_secs(120),
        ..LoadgenConfig::default()
    };
    let total = (lg.connections * lg.requests) as u64;
    let client = {
        let lg = lg.clone();
        std::thread::spawn(move || loadgen::run(&addr, &lg).expect("loadgen"))
    };

    // Reload early in the stream so the bulk of the answers land on the
    // new generation.
    let deadline = Instant::now() + Duration::from_secs(60);
    while server.stats().served < total / 20 {
        assert!(Instant::now() < deadline, "load never got going");
        std::thread::sleep(Duration::from_millis(1));
    }
    write_snapshot(&dir, 8, 64, 5);
    assert_eq!(handle.reload(&dir).expect("reload"), 2);

    let report = client.join().expect("client thread");
    assert_eq!(report.errors, 0, "errors across the hot reload");
    assert_eq!(report.timed_out, 0, "drops across the hot reload");
    assert_eq!(report.answered, total, "every request answered");
    assert!(report.min_generation >= 1);
    assert_eq!(
        report.max_generation, 2,
        "no answer was served by the reloaded generation"
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Routed serving over the wire: a 3-replica backend answers
/// bit-identically to a single-replica backend at the same seeds, long
/// documents engage the concurrent scatter-gather, and answers report
/// the replicas that served them.
#[test]
fn routed_wire_serving_is_bit_identical_to_single_replica() {
    let dir = snapshot_dir("routed", 8, 96);
    let set = ReplicaSet::load_dir(&dir, 3).expect("replica-set load");
    let info = {
        let m = set.current().models()[0].clone();
        ModelInfo {
            family: m.kind().family_name().to_string(),
            k: m.k() as u32,
            vocab: m.vocab() as u32,
        }
    };
    let server = WireServer::start(
        set.clone(),
        info,
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig::default(),
    )
    .expect("server start");

    // Mean length 96 ≫ the concurrent-gather threshold (64 tokens).
    let lg = LoadgenConfig {
        connections: 4,
        requests: 8,
        window: 4,
        vocab: 96,
        doc_len: 96.0,
        keep_responses: true,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(server.local_addr(), &lg).expect("loadgen");
    assert_eq!(report.errors, 0);
    assert_eq!(report.timed_out, 0);
    assert_eq!(report.answered, 4 * 8);

    let single = ServingHandle::load_dir(&dir).expect("single-replica load");
    let svc = InferenceService::spawn(single, ServeConfig::default());
    let mut multi_replica_answers = 0usize;
    for ans in &report.responses {
        assert!(
            !ans.served_by.is_empty(),
            "routed answer must report its serving replicas"
        );
        if ans.served_by.len() >= 2 {
            multi_replica_answers += 1;
        }
        let queries = connection_queries(&lg, ans.conn);
        let (seed, tokens) = &queries[ans.id as usize];
        let local = svc
            .submit_with_seed(tokens.clone(), *seed)
            .recv()
            .expect("single-replica answer");
        let wire_bits: Vec<u64> = ans.theta.iter().map(|t| t.to_bits()).collect();
        let local_bits: Vec<u64> = local.theta.iter().map(|t| t.to_bits()).collect();
        assert_eq!(
            wire_bits, local_bits,
            "conn {} request {}: routed θ differs from single-replica",
            ans.conn, ans.id
        );
    }
    assert!(
        multi_replica_answers > 0,
        "no document scattered across ≥2 replicas — the gather path never ran"
    );
    svc.shutdown();
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Protocol robustness: truncated frames, oversize lengths, foreign
/// versions, unknown opcodes, and garbage payloads each get the
/// documented treatment — and a well-behaved connection opened before
/// the abuse keeps working throughout.
#[test]
fn malformed_frames_never_kill_the_server_or_other_connections() {
    let dir = snapshot_dir("abuse", 4, 32);
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let server = WireServer::start(
        handle.clone(),
        model_info(&handle),
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig::default(),
    )
    .expect("server start");
    let addr = server.local_addr().to_string();

    // The bystander: a healthy connection that must survive everything.
    let mut good = connect(&addr);
    let mut good_buf = Vec::new();
    let mut wire = Vec::new();
    proto::encode_request_into(&mut wire, &Request::Ping { id: 1 });
    good.write_all(&wire).unwrap();
    expect_pong(&mut good, &mut good_buf, 1, "bystander warm-up");

    // 1. Truncated frame, then the peer vanishes: header promises 100
    //    payload bytes, 10 arrive. The server just sees a half frame and
    //    an EOF — no panic, nothing to answer.
    {
        let mut s = connect(&addr);
        let mut bad = Vec::new();
        bad.extend_from_slice(&100u32.to_le_bytes());
        bad.push(frame::PROTO_VERSION);
        bad.push(op::PING);
        bad.extend_from_slice(&[0u8; 10]);
        s.write_all(&bad).unwrap();
        drop(s);
    }

    // 2. Oversize length: rejected from the 4 header bytes alone —
    //    explicit error frame, then the connection closes.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        s.write_all(&(2u32 << 20).to_le_bytes()).unwrap();
        s.write_all(&[frame::PROTO_VERSION, op::PING]).unwrap();
        expect_error(&mut s, &mut buf, err::OVERSIZE, "oversize length");
        assert!(
            read_frame(&mut s, &mut buf).is_none(),
            "oversize connection must close after the error frame"
        );
    }

    // 3. Foreign protocol version: error frame (not a hang, not a
    //    panic), then close.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        let mut bad = Vec::new();
        frame::encode_parts_into(&mut bad, 99, op::PING, &7u64.to_le_bytes());
        s.write_all(&bad).unwrap();
        expect_error(&mut s, &mut buf, err::BAD_VERSION, "foreign version");
        assert!(
            read_frame(&mut s, &mut buf).is_none(),
            "foreign-version connection must close after the error frame"
        );
    }

    // 4. Unknown opcode in a well-formed frame: error frame, and the
    //    connection SURVIVES — a later valid PING is answered.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        s.write_all(&frame::encode(0x55, &11u64.to_le_bytes())).unwrap();
        expect_error(&mut s, &mut buf, err::UNKNOWN_OPCODE, "unknown opcode");
        let mut ping = Vec::new();
        proto::encode_request_into(&mut ping, &Request::Ping { id: 12 });
        s.write_all(&ping).unwrap();
        expect_pong(&mut s, &mut buf, 12, "after unknown opcode");
    }

    // 5. Garbage INFER payload (too short to parse): MALFORMED, close.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        s.write_all(&frame::encode(op::INFER, &[1, 2, 3])).unwrap();
        expect_error(&mut s, &mut buf, err::MALFORMED, "garbage INFER");
        assert!(
            read_frame(&mut s, &mut buf).is_none(),
            "malformed-payload connection must close after the error frame"
        );
    }

    // The bystander still answers real queries.
    let mut infer = Vec::new();
    proto::encode_request_into(
        &mut infer,
        &Request::Infer {
            id: 2,
            seed: 7,
            min_generation: 0,
            tokens: vec![1, 2, 3, 4],
        },
    );
    good.write_all(&infer).unwrap();
    let f = read_frame(&mut good, &mut good_buf).expect("bystander INFER answer");
    match proto::decode_response(&f) {
        Ok(Response::InferOk { id, theta, .. }) => {
            assert_eq!(id, 2);
            assert_eq!(theta.len(), 4);
            let sum: f64 = theta.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "θ must normalize (sum {sum})");
        }
        other => panic!("bystander expected INFER_OK, got {other:?}"),
    }
    assert!(server.stats().errors >= 4, "each abuse must be counted");
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Application-level refusals: a HELLO naming the wrong family closes
/// with FAMILY_MISMATCH; an INFER demanding a future generation gets
/// GENERATION_MISMATCH but the connection keeps serving.
#[test]
fn family_and_generation_mismatches_get_explicit_error_frames() {
    let dir = snapshot_dir("mismatch", 4, 32);
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let server = WireServer::start(
        handle.clone(),
        model_info(&handle),
        &ListenAddr::parse("127.0.0.1:0"),
        WireConfig::default(),
    )
    .expect("server start");
    let addr = server.local_addr().to_string();

    // Family mismatch: error + close.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        let mut wire = Vec::new();
        proto::encode_request_into(
            &mut wire,
            &Request::Hello {
                id: 3,
                family: "NotAFamily".to_string(),
            },
        );
        s.write_all(&wire).unwrap();
        expect_error(&mut s, &mut buf, err::FAMILY_MISMATCH, "family mismatch");
        assert!(
            read_frame(&mut s, &mut buf).is_none(),
            "family-mismatch connection must close"
        );
    }

    // Generation mismatch: error frame, connection survives.
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        let mut wire = Vec::new();
        proto::encode_request_into(
            &mut wire,
            &Request::Infer {
                id: 4,
                seed: 1,
                min_generation: 99,
                tokens: vec![1, 2, 3],
            },
        );
        s.write_all(&wire).unwrap();
        expect_error(
            &mut s,
            &mut buf,
            err::GENERATION_MISMATCH,
            "future generation",
        );
        let mut ping = Vec::new();
        proto::encode_request_into(&mut ping, &Request::Ping { id: 5 });
        s.write_all(&ping).unwrap();
        expect_pong(&mut s, &mut buf, 5, "after generation mismatch");
    }

    // The handshake + STATS report the model shape and live counters.
    let shape = loadgen::hello(&addr, Duration::from_secs(10)).expect("HELLO");
    assert_eq!(shape.k, 4);
    assert_eq!(shape.vocab, 32);
    assert_eq!(shape.generation, 1);
    {
        let mut s = connect(&addr);
        let mut buf = Vec::new();
        let mut wire = Vec::new();
        proto::encode_request_into(&mut wire, &Request::Stats { id: 6 });
        s.write_all(&wire).unwrap();
        let f = read_frame(&mut s, &mut buf).expect("STATS answer");
        match proto::decode_response(&f) {
            Ok(Response::StatsOk {
                id,
                generation,
                errors,
                reactors,
                ..
            }) => {
                assert_eq!(id, 6);
                assert_eq!(generation, 1);
                assert_eq!(errors, 2, "the two refusals above");
                assert_eq!(reactors, WireConfig::default().reactors as u32);
            }
            other => panic!("expected STATS_OK, got {other:?}"),
        }
    }
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The same stack over a Unix-domain socket (the `unix:` address form).
#[cfg(unix)]
#[test]
fn unix_socket_serving_round_trips() {
    let dir = snapshot_dir("unix", 4, 32);
    let sock = std::env::temp_dir().join(format!("hplvm_wire_{}.sock", std::process::id()));
    let handle = ServingHandle::load_dir(&dir).expect("snapshot load");
    let server = WireServer::start(
        handle.clone(),
        model_info(&handle),
        &ListenAddr::parse(&format!("unix:{}", sock.display())),
        WireConfig::default(),
    )
    .expect("server start");
    assert_eq!(server.local_addr(), format!("unix:{}", sock.display()));

    let lg = LoadgenConfig {
        connections: 2,
        requests: 8,
        window: 4,
        vocab: 32,
        doc_len: 8.0,
        keep_responses: true,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(server.local_addr(), &lg).expect("loadgen over unix socket");
    assert_eq!(report.errors, 0);
    assert_eq!(report.answered, 2 * 8);

    // Unix-socket answers are the same bits as in-process answers.
    let svc = InferenceService::spawn(handle.clone(), ServeConfig::default());
    let ans = &report.responses[0];
    let (seed, tokens) = &connection_queries(&lg, ans.conn)[ans.id as usize];
    let local = svc
        .submit_with_seed(tokens.clone(), *seed)
        .recv()
        .expect("in-process answer");
    assert_eq!(
        ans.theta.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
        local.theta.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
    );
    svc.shutdown();
    server.shutdown();
    assert!(!sock.exists(), "socket file must be removed on shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

/// `Arc<ServingHandle>` and `Arc<ReplicaSet>` both satisfy the
/// `Arc<dyn QueryBackend>` the server takes — the compile-time seam the
/// CLI relies on.
#[test]
fn server_takes_either_backend() {
    let dir = snapshot_dir("seam", 4, 32);
    let single: Arc<dyn hplvm::serve::QueryBackend> =
        ServingHandle::load_dir(&dir).expect("single");
    let routed: Arc<dyn hplvm::serve::QueryBackend> =
        ReplicaSet::load_dir(&dir, 2).expect("routed");
    assert_eq!(single.generation(), 1);
    assert_eq!(routed.generation(), 1);
    std::fs::remove_dir_all(&dir).ok();
}
