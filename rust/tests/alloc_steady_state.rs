//! Steady-state allocation audit for the sampler hot loop.
//!
//! The acceptance bar for the sparse hot path is that the per-token inner
//! loop performs **zero heap allocations** once warm: the delta log
//! updates in place, alias rebuilds reuse pooled buffers, and pulls decode
//! through a scratch row. Rust has no per-thread alloc hook offline, so
//! this binary installs a counting global allocator and asserts the
//! *per-token* allocation rate of a warm sweep is (near) zero — a loose
//! epsilon absorbs the rare amortized container-capacity events (a delta
//! record spilling dense, a `SparseCounts` vec growing one slot) that are
//! O(vocab) over a run, not O(tokens).
//!
//! This test lives in its own integration binary so no concurrently
//! running test can inflate the global counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use hplvm::corpus::generator::{CorpusConfig, GenerativeModel};
use hplvm::sampler::alias_lda::AliasLda;
use hplvm::sampler::hdp::AliasHdp;
use hplvm::sampler::pdp::AliasPdp;
use hplvm::sampler::sparse_lda::SparseLda;
use hplvm::sampler::DocSampler;
use hplvm::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `sweeps` warm sweeps, then measure one more; returns
/// `(allocations, tokens)` for the measured sweep.
fn measure<S: DocSampler>(
    s: &mut S,
    n_docs: usize,
    tokens: u64,
    rng: &mut Rng,
    sweeps: usize,
) -> (u64, u64) {
    for _ in 0..sweeps {
        for d in 0..n_docs {
            s.sample_doc(d, rng);
        }
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for d in 0..n_docs {
        s.sample_doc(d, rng);
    }
    (ALLOCS.load(Ordering::Relaxed) - before, tokens)
}

fn lda_corpus(seed: u64) -> (Vec<hplvm::corpus::doc::Document>, u64) {
    let (c, _) = CorpusConfig {
        n_docs: 100,
        vocab_size: 200,
        n_topics: 4,
        doc_len_mean: 30.0,
        seed,
        ..Default::default()
    }
    .generate();
    let tokens: u64 = c.docs.iter().map(|d| d.tokens.len() as u64).sum();
    (c.docs, tokens)
}

/// < 1 allocation per 100 tokens, for every sampler. A dense-era delta
/// log alone allocated one K-wide row *per touched word per sync* and the
/// alias path a fresh table per rebuild — orders of magnitude above this
/// bar.
#[test]
fn warm_sampler_sweeps_allocate_nearly_nothing() {
    // K=4: the sparse delta record provably never spills (≤K distinct
    // topics always fit its preallocated threshold), so LDA-family allocs
    // can only come from rare SparseCounts capacity growth.
    let (docs, tokens) = lda_corpus(1);
    let mut rng = Rng::new(17);
    let mut alias = AliasLda::new(docs.clone(), 200, 4, 0.1, 0.01, &mut rng);
    let (a, n) = measure(&mut alias, 100, tokens, &mut rng, 3);
    assert!(
        a * 100 <= n,
        "AliasLDA: {a} allocations over {n} tokens in a warm sweep"
    );

    let mut yahoo = SparseLda::new(docs, 200, 4, 0.1, 0.01, &mut rng);
    let (a, n) = measure(&mut yahoo, 100, tokens, &mut rng, 3);
    assert!(
        a * 100 <= n,
        "SparseLDA: {a} allocations over {n} tokens in a warm sweep"
    );

    let (c, _) = CorpusConfig {
        n_docs: 80,
        vocab_size: 150,
        n_topics: 4,
        doc_len_mean: 25.0,
        model: GenerativeModel::Pyp,
        seed: 2,
        ..Default::default()
    }
    .generate();
    let tokens: u64 = c.docs.iter().map(|d| d.tokens.len() as u64).sum();
    // PDP/HDP keep table statistics whose delta records can still make
    // their one-time sparse→dense spill during the measured sweep (plus
    // occasional Stirling growth) — a per-word event, so the bar is a
    // notch looser but still far below one allocation per token.
    let mut pdp = AliasPdp::new(c.docs, 150, 4, 0.1, 0.1, 10.0, 0.5, &mut rng);
    let (a, n) = measure(&mut pdp, 80, tokens, &mut rng, 3);
    assert!(
        a * 50 <= n,
        "AliasPDP: {a} allocations over {n} tokens in a warm sweep"
    );

    let (docs, tokens) = lda_corpus(3);
    let mut hdp = AliasHdp::new(docs, 200, 8, 1.0, 1.0, 0.01, &mut rng);
    let (a, n) = measure(&mut hdp, 100, tokens, &mut rng, 3);
    assert!(
        a * 50 <= n,
        "AliasHDP: {a} allocations over {n} tokens in a warm sweep"
    );
}

/// The hybrid-row regime the refactor targets: K=10k, where every
/// word-topic row lives far below the dense cutoff (a 30-token doc over a
/// 200-word vocabulary touches a handful of topics per word). Warm sweeps
/// must stay under 1 allocation per 100 tokens — short-list and hash rows
/// mutate in place, and promotions are one-time per-word events absorbed
/// by the warmup sweeps.
#[test]
fn warm_sweeps_stay_allocation_free_at_k10k() {
    let (docs, tokens) = lda_corpus(4);
    let mut rng = Rng::new(23);
    let mut alias = AliasLda::new(docs, 200, 10_000, 0.1, 0.01, &mut rng);
    let (a, n) = measure(&mut alias, 100, tokens, &mut rng, 3);
    assert!(
        a * 100 <= n,
        "AliasLDA K=10k: {a} allocations over {n} tokens in a warm sweep"
    );
}
