//! Incremental (v4) checkpoint integration tests: byte proportionality,
//! torn-checkpoint recovery, and generation-diff serving reloads — the
//! LSM snapshot store exercised end-to-end through a real
//! `TrainSession`, not synthetic stores.
//!
//! The contract under test:
//!
//! * a second `checkpoint(dir)` writes bytes proportional to the rows
//!   that changed since the first — an immediate re-checkpoint carries
//!   every segment forward (by hardlink where the filesystem allows)
//!   and writes (almost) nothing new;
//! * a crash between sealing a segment and renaming the manifest leaves
//!   only *unreferenced* files, which every reader ignores — resume and
//!   serving both work and token totals are conserved;
//! * a *referenced* segment that is truncated is a hard, named error —
//!   never folded silently;
//! * a serving reload after more training takes the generation-diff
//!   path and stays bit-identical to a from-scratch full load.

use hplvm::config::{ModelKind, TrainConfig};
use hplvm::coordinator::session::TrainSession;
use hplvm::corpus::source::SyntheticSource;
use hplvm::eval::perplexity::TopicModelView;
use hplvm::ps::snapshot::{self, SegmentKind};
use hplvm::serve::{ServingHandle, ServingModel};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 10;
    cfg.corpus.n_docs = 200;
    cfg.corpus.vocab_size = 400;
    cfg.corpus.n_topics = 10;
    cfg.corpus.doc_len_mean = 20.0;
    cfg.cluster.clients = 2;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(100);
    cfg.iterations = 8;
    cfg.eval_every = 8;
    cfg.test_docs = 20;
    cfg.seed = seed;
    cfg.corpus.seed = seed;
    cfg.cluster.net.seed = seed ^ 0x7EA7;
    cfg
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hplvm_incr_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Every segment file in `dir`: name → byte length.
fn seg_files(dir: &Path) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    for entry in std::fs::read_dir(dir).unwrap() {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().into_owned();
        if snapshot::is_segment_name(&name) {
            out.insert(name, entry.metadata().unwrap().len());
        }
    }
    out
}

/// (device, inode) identity — two paths with the same pair are the same
/// file, i.e. the carry was a hardlink and rewrote zero bytes.
#[cfg(unix)]
fn file_id(path: &Path) -> (u64, u64) {
    use std::os::unix::fs::MetadataExt;
    let md = std::fs::metadata(path).unwrap();
    (md.dev(), md.ino())
}

/// The acceptance criterion: checkpoint bytes are proportional to rows
/// changed. An immediate re-checkpoint (zero training in between) must
/// carry the previous live set forward and write (almost — the SimNet
/// may deliver a straggler push between the two seals) no new segment
/// bytes; a checkpoint after more training writes delta segments.
#[test]
fn second_checkpoint_writes_bytes_proportional_to_changed_rows() {
    let cfg = base_cfg(71);
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(4).unwrap();

    let d1 = tmpdir("bytes1");
    let d2 = tmpdir("bytes2");
    let d3 = tmpdir("bytes3");
    session.checkpoint(&d1).unwrap();
    session.checkpoint(&d2).unwrap();

    let segs1 = seg_files(&d1);
    let segs2 = seg_files(&d2);
    assert!(!segs1.is_empty(), "a v4 checkpoint must write segment files");
    let base_bytes: u64 = segs1.values().sum();
    // Carried segments keep their names; anything newly sealed gets a
    // fresh generation number and therefore a fresh name.
    let new_bytes: u64 = segs2
        .iter()
        .filter(|(name, _)| !segs1.contains_key(*name))
        .map(|(_, len)| len)
        .sum();
    assert!(
        new_bytes * 4 < base_bytes,
        "re-checkpoint with no training wrote {new_bytes} of {base_bytes} \
         base bytes — the live set was not carried forward"
    );
    // On Unix the carry is a hardlink: same device and inode, zero bytes
    // rewritten — not even a copy.
    #[cfg(unix)]
    for name in segs2.keys().filter(|n| segs1.contains_key(*n)) {
        assert_eq!(
            file_id(&d1.join(name)),
            file_id(&d2.join(name)),
            "{name} was copied, not hardlinked"
        );
    }

    // More training dirties rows; the next checkpoint seals them as
    // *delta* segments on top of the carried set and advances the
    // manifest generation.
    session.run_to(6).unwrap();
    session.checkpoint(&d3).unwrap();
    let segs3 = seg_files(&d3);
    let fresh: Vec<&String> = segs3
        .keys()
        .filter(|n| !segs2.contains_key(*n))
        .collect();
    assert!(
        !fresh.is_empty(),
        "training between checkpoints must seal at least one new segment"
    );
    for name in &fresh {
        assert!(
            name.ends_with("-delta.seg"),
            "{name}: post-training seal should be a delta, not a rebase"
        );
    }
    let m1 = snapshot::read_manifest(&d1.join(snapshot::slot_snapshot_name(0)))
        .expect("slot 0 manifest in d1");
    let m3 = snapshot::read_manifest(&d3.join(snapshot::slot_snapshot_name(0)))
        .expect("slot 0 manifest in d3");
    assert!(
        m3.generation > m1.generation,
        "sealing new rows must advance the manifest generation"
    );

    // Every checkpoint in the chain still serves.
    let model = ServingModel::load_dir(&d3).expect("incremental checkpoint must serve");
    assert!(model.total_tokens() > 0);
    let _ = session.finish().unwrap();

    for d in [&d1, &d2, &d3] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// A crash between sealing a segment and renaming the manifest leaves
/// orphan segment files — valid or truncated — next to a complete
/// manifest. Readers open only manifest-referenced files, so orphans are
/// inert: resume works, serving works, token totals are conserved. A
/// truncated *referenced* segment, by contrast, is a hard error naming
/// the file.
#[test]
fn torn_checkpoint_orphans_are_inert_but_referenced_truncation_refuses() {
    let cfg = base_cfg(73);
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(4).unwrap();
    let ckpt = tmpdir("torn");
    session.checkpoint(&ckpt).unwrap();
    let _ = session.finish().unwrap();

    let tokens_before = ServingModel::load_dir(&ckpt).unwrap().total_tokens();
    assert!(tokens_before > 0);

    // Simulate the crash window: a fully-written orphan delta (sealed,
    // never referenced — the manifest rename never happened) and a
    // truncated one (the crash hit mid-write, before the atomic rename
    // would have published it).
    let orphan = snapshot::encode_segment(0, 999, SegmentKind::Delta, &[]);
    std::fs::write(
        ckpt.join(snapshot::segment_name(0, 999, SegmentKind::Delta)),
        &orphan,
    )
    .unwrap();
    std::fs::write(
        ckpt.join(snapshot::segment_name(0, 998, SegmentKind::Delta)),
        &orphan[..orphan.len() / 2],
    )
    .unwrap();

    // Serving: same model, same totals — the orphans were never opened.
    let tokens_after = ServingModel::load_dir(&ckpt)
        .expect("orphan segments must not break serving")
        .total_tokens();
    assert_eq!(tokens_before, tokens_after, "orphans changed the fold");

    // Resume: the checkpoint is still a valid continuation point, and
    // the resumed run can keep training and re-checkpoint.
    let mut resumed =
        TrainSession::resume(&ckpt).expect("orphan segments must not break resume");
    assert_eq!(resumed.iteration(), 4);
    resumed.run_for(1).unwrap();
    let ckpt2 = tmpdir("torn2");
    resumed.checkpoint(&ckpt2).unwrap();
    let _ = resumed.finish().unwrap();
    assert!(ServingModel::load_dir(&ckpt2).unwrap().total_tokens() > 0);

    // Now damage a segment the manifest *does* reference: that must be
    // a hard, named refusal — in serving and in resume alike.
    let manifest = snapshot::read_manifest(&ckpt.join(snapshot::slot_snapshot_name(0)))
        .expect("slot 0 manifest");
    let victim = &manifest.segments[0].name;
    let bytes = std::fs::read(ckpt.join(victim)).unwrap();
    std::fs::write(ckpt.join(victim), &bytes[..bytes.len() - 20]).unwrap();

    let err = match ServingModel::load_dir(&ckpt) {
        Ok(_) => panic!("truncated referenced segment must refuse to serve"),
        Err(e) => format!("{e:#}"),
    };
    assert!(
        err.contains(victim.as_str()) && err.contains("torn"),
        "refusal must name the file and the tear: {err}"
    );
    let err = match TrainSession::resume(&ckpt) {
        Ok(_) => panic!("truncated referenced segment must refuse to resume"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("torn"), "resume refusal must explain itself: {err}");

    std::fs::remove_dir_all(&ckpt).ok();
    std::fs::remove_dir_all(&ckpt2).ok();
}

/// `--watch`-style reload after more training goes through the
/// generation-diff path (only the new segments are read) and the
/// resulting model is bit-identical to a from-scratch full load of the
/// same directory.
#[test]
fn generation_diff_reload_matches_full_load_bitwise() {
    let cfg = base_cfg(79);
    let src = SyntheticSource::new(cfg.corpus.clone());
    let mut session = TrainSession::start(cfg, &src).unwrap();
    session.run_to(4).unwrap();
    let ckpt = tmpdir("diffreload");
    session.checkpoint(&ckpt).unwrap();

    let handle = ServingHandle::load_dir(&ckpt).unwrap();
    assert!(
        handle.last_reload_stats().full,
        "the first load has no resident stores to diff against"
    );
    let gen0 = handle.generation();

    // Train on, checkpoint into the *same* directory (the watch target),
    // reload: only the freshly sealed segments should be replayed.
    session.run_to(6).unwrap();
    session.checkpoint(&ckpt).unwrap();
    let _ = session.finish().unwrap();
    let gen1 = handle.reload(&ckpt).unwrap();
    assert!(gen1 > gen0, "reload must advance the serving generation");
    let stats = handle.last_reload_stats();
    assert!(!stats.full, "second load of a v4 dir must take the diff path");
    assert!(
        stats.segments >= 1 && stats.rows >= 1,
        "training dirtied rows, so the diff must have replayed some: {stats:?}"
    );

    // Bit-identity: the diff-overlaid model answers exactly like a model
    // decoded from scratch — same φ bits, same priors, same totals.
    let fresh = ServingModel::load_dir(&ckpt).unwrap();
    let live = handle.model();
    assert_eq!(live.total_tokens(), fresh.total_tokens());
    assert_eq!(live.k(), fresh.k());
    for t in 0..fresh.k() {
        assert_eq!(live.doc_prior(t).to_bits(), fresh.doc_prior(t).to_bits());
    }
    let vocab = fresh.meta().vocab_size;
    for w in 0..vocab {
        for t in 0..fresh.k() {
            assert_eq!(
                live.phi(w, t).to_bits(),
                fresh.phi(w, t).to_bits(),
                "φ({w},{t}) diverged between diff reload and full load"
            );
        }
    }

    std::fs::remove_dir_all(&ckpt).ok();
}
