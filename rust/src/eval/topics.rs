//! Topic diagnostics: the "average number of topics per word" panel of
//! Figs 4/5/7 and top-word inspection for the examples.

use crate::sampler::counts::CountMatrix;

/// Average number of non-zero topics across words present in the counts —
/// exactly the figures' definition ("the average number of non-zero
/// topics across all words in the local vocabulary").
pub fn avg_topics_per_word(nwt: &CountMatrix) -> f64 {
    nwt.avg_topics_per_word()
}

/// The `n` highest-count words for each topic (word id, count).
pub fn top_words(nwt: &CountMatrix, n: usize) -> Vec<Vec<(u32, i32)>> {
    let k = nwt.k();
    let mut tops: Vec<Vec<(u32, i32)>> = vec![Vec::new(); k];
    for (w, row) in nwt.iter_rows() {
        row.for_each(|t, c| {
            if c > 0 {
                tops[t as usize].push((w, c));
            }
        });
    }
    for top in tops.iter_mut() {
        top.sort_unstable_by_key(|&(_, c)| std::cmp::Reverse(c));
        top.truncate(n);
    }
    tops
}

/// Topic share: fraction of tokens per topic (sorted descending) — a
/// quick skew diagnostic used by the examples.
pub fn topic_shares(nwt: &CountMatrix) -> Vec<f64> {
    let total: i64 = nwt.grand_total().max(1);
    let mut shares: Vec<f64> = nwt
        .totals()
        .iter()
        .map(|&c| c.max(0) as f64 / total as f64)
        .collect();
    shares.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    shares
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts() -> CountMatrix {
        let mut m = CountMatrix::new(4, 3);
        m.inc_local(0, 0, 10);
        m.inc_local(0, 1, 2);
        m.inc_local(1, 1, 5);
        m.inc_local(2, 2, 1);
        m
    }

    #[test]
    fn topics_per_word() {
        // words 0 (2 topics), 1 (1), 2 (1) → mean 4/3.
        assert!((avg_topics_per_word(&counts()) - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn top_words_sorted() {
        let tops = top_words(&counts(), 2);
        assert_eq!(tops[0], vec![(0, 10)]);
        assert_eq!(tops[1], vec![(1, 5), (0, 2)]);
        assert_eq!(tops[2], vec![(2, 1)]);
    }

    #[test]
    fn shares_sum_to_one() {
        let s = topic_shares(&counts());
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(s[0] >= s[1] && s[1] >= s[2]);
    }
}
