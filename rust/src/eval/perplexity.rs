//! Test perplexity (§6):
//!
//! ```text
//! π(W|rest) := [Σ_d N_d]⁻¹ Σ_d log p(w_d|rest)
//! p(w_d|rest) = Π_i Σ_t p(w_i|z=t, rest)·p(z=t|rest)
//! ```
//!
//! `p(w|z=t)` comes from the model under training; the test document's
//! topic weights are folded in with a few EM steps (deterministic, so all
//! clients agree on the estimator). "Unseen words are evaluated by
//! assuming sufficient statistics related to the word are zero instead of
//! being totally ignored" — zero rows flow through the same formula.
//!
//! The final scoring pass (the dense `log Σ_t θ·φ` over gathered rows) is
//! exactly the `perplexity` PJRT artifact; [`perplexity`] takes an
//! optional [`crate::runtime::Engine`] and falls back to pure rust.

use crate::corpus::doc::Corpus;

/// A trained model's view of `p(w|t)` — implemented by every sampler.
pub trait TopicModelView {
    /// Number of topics.
    fn k(&self) -> usize;
    /// `p(w | z=t)` under the current statistics.
    fn phi(&self, w: u32, t: usize) -> f64;
    /// Document-topic smoothing mass used for fold-in (α, or b₁θ₀ for HDP).
    fn doc_prior(&self, t: usize) -> f64;
    /// Fill `out[t] = phi(w, t)` (batch row gather for the PJRT path).
    fn phi_row(&self, w: u32, out: &mut [f64]) {
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.phi(w, t);
        }
    }
}

/// Evaluation output.
#[derive(Clone, Copy, Debug)]
pub struct PerplexityReport {
    /// Mean per-token log-likelihood (the paper's π).
    pub avg_log_lik: f64,
    /// `exp(−avg_log_lik)` — conventional perplexity.
    pub perplexity: f64,
    /// Tokens scored.
    pub tokens: u64,
}

/// Fold-in EM: estimate θ̂_d for one test document against fixed φ.
fn fold_in(view: &dyn TopicModelView, tokens: &[u32], em_iters: usize) -> Vec<f64> {
    let k = view.k();
    let prior: Vec<f64> = (0..k).map(|t| view.doc_prior(t).max(1e-12)).collect();
    let prior_sum: f64 = prior.iter().sum();
    let mut theta: Vec<f64> = prior.iter().map(|p| p / prior_sum).collect();
    let mut resp = vec![0.0f64; k];
    for _ in 0..em_iters {
        let mut acc = prior.clone();
        for &w in tokens {
            let mut z = 0.0;
            for t in 0..k {
                resp[t] = theta[t] * view.phi(w, t);
                z += resp[t];
            }
            if z <= 0.0 {
                continue;
            }
            for t in 0..k {
                acc[t] += resp[t] / z;
            }
        }
        let s: f64 = acc.iter().sum();
        for t in 0..k {
            theta[t] = acc[t] / s;
        }
    }
    theta
}

/// Score a test corpus. When `engine` is provided and the artifact fits
/// (`K ≤` the artifact's padded width), the dense scoring pass runs on the
/// AOT-compiled PJRT executable; otherwise pure rust.
pub fn perplexity(
    view: &dyn TopicModelView,
    test: &Corpus,
    em_iters: usize,
    engine: Option<&dyn crate::runtime::DenseEval>,
) -> PerplexityReport {
    let k = view.k();
    let mut total_ll = 0.0f64;
    let mut tokens = 0u64;

    // Batch buffers for the PJRT path.
    let mut theta_batch: Vec<f32> = Vec::new();
    let mut phi_batch: Vec<f32> = Vec::new();
    let mut pending = 0usize;
    let use_engine = engine
        .map(|e| e.supports_log_dot(k))
        .unwrap_or(false);

    let flush =
        |theta_batch: &mut Vec<f32>, phi_batch: &mut Vec<f32>, pending: &mut usize| -> f64 {
            if *pending == 0 {
                return 0.0;
            }
            let e = engine.unwrap();
            let lls = e
                .log_dot(theta_batch, phi_batch, *pending, k)
                .expect("PJRT log_dot failed");
            theta_batch.clear();
            phi_batch.clear();
            let s: f64 = lls.iter().take(*pending).map(|&x| x as f64).sum();
            *pending = 0;
            s
        };

    let mut phi_row = vec![0.0f64; k];
    for doc in &test.docs {
        if doc.tokens.is_empty() {
            continue;
        }
        let theta = fold_in(view, &doc.tokens, em_iters);
        for &w in &doc.tokens {
            tokens += 1;
            if use_engine {
                view.phi_row(w, &mut phi_row);
                theta_batch.extend(theta.iter().map(|&x| x as f32));
                phi_batch.extend(phi_row.iter().map(|&x| x as f32));
                pending += 1;
                if pending == crate::runtime::LOG_DOT_BATCH {
                    total_ll += flush(&mut theta_batch, &mut phi_batch, &mut pending);
                }
            } else {
                let mut p = 0.0;
                for t in 0..k {
                    p += theta[t] * view.phi(w, t);
                }
                total_ll += p.max(1e-300).ln();
            }
        }
    }
    if use_engine {
        total_ll += flush(&mut theta_batch, &mut phi_batch, &mut pending);
    }

    let avg = if tokens == 0 {
        0.0
    } else {
        total_ll / tokens as f64
    };
    PerplexityReport {
        avg_log_lik: avg,
        perplexity: (-avg).exp(),
        tokens,
    }
}

/// Score documents with *externally supplied* topic mixtures (e.g. the
/// serving layer's fold-in estimates) instead of the internal EM fold-in:
/// `log p(w_d) = Σ_i log Σ_t θ_d[t]·φ(w_i,t)`. Documents beyond
/// `thetas.len()` and empty documents are skipped.
pub fn score_with_theta(
    view: &dyn TopicModelView,
    docs: &[crate::corpus::doc::Document],
    thetas: &[Vec<f64>],
) -> PerplexityReport {
    let k = view.k();
    let mut total_ll = 0.0f64;
    let mut tokens = 0u64;
    for (doc, theta) in docs.iter().zip(thetas.iter()) {
        if doc.tokens.is_empty() {
            continue;
        }
        for &w in &doc.tokens {
            tokens += 1;
            let mut p = 0.0;
            for t in 0..k.min(theta.len()) {
                p += theta[t] * view.phi(w, t);
            }
            total_ll += p.max(1e-300).ln();
        }
    }
    let avg = if tokens == 0 {
        0.0
    } else {
        total_ll / tokens as f64
    };
    PerplexityReport {
        avg_log_lik: avg,
        perplexity: (-avg).exp(),
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::doc::{Corpus, Document};

    /// A fixed two-topic model for closed-form checks.
    struct Toy;
    impl TopicModelView for Toy {
        fn k(&self) -> usize {
            2
        }
        fn phi(&self, w: u32, t: usize) -> f64 {
            // topic 0 → word 0, topic 1 → word 1, smoothed.
            match (w, t) {
                (0, 0) | (1, 1) => 0.9,
                _ => 0.1,
            }
        }
        fn doc_prior(&self, _t: usize) -> f64 {
            0.5
        }
    }

    fn corpus(docs: Vec<Vec<u32>>) -> Corpus {
        Corpus {
            docs: docs.into_iter().map(|tokens| Document { tokens }).collect(),
            vocab_size: 2,
            true_topics: 2,
        }
    }

    #[test]
    fn pure_topic_doc_scores_high() {
        let c = corpus(vec![vec![0; 50]]);
        let rep = perplexity(&Toy, &c, 10, None);
        // θ̂ → (1, 0): p(w=0) ≈ 0.9 → perplexity ≈ 1/0.9.
        assert_eq!(rep.tokens, 50);
        assert!((rep.perplexity - 1.0 / 0.9).abs() < 0.05, "{}", rep.perplexity);
    }

    #[test]
    fn mixed_doc_scores_lower_than_pure() {
        let pure = perplexity(&Toy, &corpus(vec![vec![0; 40]]), 10, None);
        let mixed = perplexity(&Toy, &corpus(vec![vec![0, 1].repeat(20)]), 10, None);
        assert!(mixed.perplexity > pure.perplexity);
        assert!(mixed.avg_log_lik < pure.avg_log_lik);
    }

    #[test]
    fn unseen_words_do_not_panic() {
        struct Zeroish;
        impl TopicModelView for Zeroish {
            fn k(&self) -> usize {
                3
            }
            fn phi(&self, _w: u32, _t: usize) -> f64 {
                0.0 // all-zero stats for unseen words
            }
            fn doc_prior(&self, _t: usize) -> f64 {
                0.1
            }
        }
        let rep = perplexity(&Zeroish, &corpus(vec![vec![0, 1]]), 3, None);
        assert!(rep.avg_log_lik.is_finite());
        assert!(rep.perplexity.is_finite());
    }

    #[test]
    fn empty_corpus_is_neutral() {
        let rep = perplexity(&Toy, &corpus(vec![]), 3, None);
        assert_eq!(rep.tokens, 0);
        assert_eq!(rep.avg_log_lik, 0.0);
    }

    #[test]
    fn score_with_theta_matches_fold_in_at_same_theta() {
        // With an (almost) pure-topic doc both estimators converge to the
        // same θ, so the scores must agree closely.
        let c = corpus(vec![vec![0; 40]]);
        let em = perplexity(&Toy, &c, 10, None);
        let ext = score_with_theta(&Toy, &c.docs, &[vec![1.0, 0.0]]);
        assert_eq!(em.tokens, ext.tokens);
        assert!(
            (em.perplexity - ext.perplexity).abs() / em.perplexity < 0.05,
            "em {} vs external {}",
            em.perplexity,
            ext.perplexity
        );
    }

    #[test]
    fn score_with_theta_handles_short_theta_list() {
        let c = corpus(vec![vec![0, 1], vec![1, 1]]);
        let rep = score_with_theta(&Toy, &c.docs, &[vec![0.5, 0.5]]);
        assert_eq!(rep.tokens, 2, "second doc has no θ and is skipped");
    }
}
