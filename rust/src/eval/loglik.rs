//! Training-set document log-likelihood — the metric of Fig 6 (the
//! 5-billion-document LDA run reports log-likelihood rather than held-out
//! perplexity).

use super::perplexity::TopicModelView;
use crate::corpus::doc::Document;

/// Joint log-likelihood of the assigned tokens under the current model:
/// `Σ_{d,i} log p(w_di | z_di)` — cheap, local, and what the paper plots
/// at the largest scale.
pub fn doc_log_likelihood(
    view: &dyn TopicModelView,
    docs: &[Document],
    z: &[Vec<u32>],
) -> f64 {
    let mut ll = 0.0;
    for (doc, zs) in docs.iter().zip(z.iter()) {
        for (&w, &t) in doc.tokens.iter().zip(zs.iter()) {
            ll += view.phi(w, t as usize).max(1e-300).ln();
        }
    }
    ll
}

/// Per-token normalization of [`doc_log_likelihood`].
pub fn mean_token_log_likelihood(
    view: &dyn TopicModelView,
    docs: &[Document],
    z: &[Vec<u32>],
) -> f64 {
    let tokens: usize = docs.iter().map(|d| d.tokens.len()).sum();
    if tokens == 0 {
        return 0.0;
    }
    doc_log_likelihood(view, docs, z) / tokens as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Toy;
    impl TopicModelView for Toy {
        fn k(&self) -> usize {
            2
        }
        fn phi(&self, w: u32, t: usize) -> f64 {
            if (w as usize) == t {
                0.8
            } else {
                0.2
            }
        }
        fn doc_prior(&self, _t: usize) -> f64 {
            0.5
        }
    }

    #[test]
    fn perfect_assignment_beats_bad() {
        let docs = vec![Document { tokens: vec![0, 1, 0, 1] }];
        let good = vec![vec![0, 1, 0, 1]];
        let bad = vec![vec![1, 0, 1, 0]];
        let ll_good = doc_log_likelihood(&Toy, &docs, &good);
        let ll_bad = doc_log_likelihood(&Toy, &docs, &bad);
        assert!(ll_good > ll_bad);
        assert!((ll_good - 4.0 * 0.8f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn mean_is_normalized() {
        let docs = vec![Document { tokens: vec![0, 0] }];
        let z = vec![vec![0, 0]];
        let m = mean_token_log_likelihood(&Toy, &docs, &z);
        assert!((m - 0.8f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn empty_docs_are_zero() {
        assert_eq!(mean_token_log_likelihood(&Toy, &[], &[]), 0.0);
    }
}
