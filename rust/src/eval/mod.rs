//! Evaluation: the paper's test-perplexity estimator (§6 "Evaluation
//! criteria"), document log-likelihood (Fig 6), and topic diagnostics
//! (the "average topics per word" panels).
//!
//! The estimator's hot loop — `log Σ_t θ̂_dt·φ̂_tw` over every test token —
//! runs through the AOT-compiled PJRT artifact when available
//! ([`crate::runtime`]), with a bit-equivalent pure-rust fallback.

pub mod loglik;
pub mod perplexity;
pub mod topics;

pub use perplexity::{perplexity, score_with_theta, PerplexityReport, TopicModelView};
