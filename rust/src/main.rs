//! `hplvm` — CLI for the High Performance Latent Variable Models system.
//!
//! ```text
//! hplvm train [--model aliaslda|yahoolda|pdp|hdp] [--clients N] [--topics K]
//!             [--iterations N] [--docs N] [--vocab V] [--projection MODE]
//!             [--snapshot-dir DIR] [--config file.json] [--out report.json]
//!             [--corpus-file docword.txt] [--checkpoint-to DIR]
//!             [--resume-from DIR] [--progress] [--pjrt] [-v|-q]
//! hplvm serve --snapshot DIR [--model NAME] [--watch] [--queries N]
//!             [--replicas R] [--workers W] [--batch B] [--cache-mb M]
//!             [--seed S]     # load-test the inference server (any family)
//! hplvm serve --snapshot DIR --listen ADDR [--reactors N] [--watch]
//!             [--watch-interval-ms MS]
//!                            # wire front-end: framed protocol on a
//!                            # thread-per-core reactor (TCP host:port or
//!                            # unix:/path)
//! hplvm bench-serve (--snapshot DIR | --addr ADDR) [--connections C]
//!             [--requests N] [--rate QPS] [--window W] [--doc-len L]
//!                            # load-test the wire server: C concurrent
//!                            # connections, open- or closed-loop
//! hplvm infer --snapshot DIR --tokens "3 17 42" [--model NAME] [--top N]
//!             [--replicas R] # routed answers report the serving replicas
//! hplvm chaos [--seed S] [--replicas R] [--warmup N] [--iterations N]
//!                            # elastic-membership chaos drill: kill and
//!                            # resize the live cluster under load
//! hplvm pipeline [--corpus-file FILE] [--chunk-docs N] [--docs N] [--vocab V]
//!             [--model NAME] [--topics K] [--clients N] [--replicas R]
//!             [--checkpoint-dir DIR] [--checkpoint-every B] [--warmup N]
//!             [--kappa X] [--tau X] [--base-sweeps N] [--seed S]
//!                            # streaming ingest + online train-while-serve:
//!                            # bounded chunks through a live session with
//!                            # cadence checkpoints hot-reloading the
//!                            # serving tier under query load
//! hplvm eval-engine          # check PJRT artifacts load and execute
//! hplvm info                 # print the resolved configuration
//! ```

use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::metrics::TrainReport;
use hplvm::coordinator::session::{
    NullObserver, PrintObserver, TrainObserver, TrainSession,
};
use hplvm::corpus::source::{CorpusSource, FileSource, SyntheticSource};
use hplvm::serve::{
    InferenceService, QueryBackend, ReplicaSet, ServeConfig, ServingHandle, ServingModel,
};
use hplvm::util::json::Json;
use hplvm::util::logging::{self, Level};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn usage() -> ! {
    eprintln!(
        "usage: hplvm <train|serve|bench-serve|infer|chaos|pipeline|eval-engine|info> [options]\n\
         train options:\n\
           --model NAME          yahoolda | aliaslda | pdp | hdp\n\
           --clients N           client (worker) count\n\
           --topics K            topic count / HDP truncation\n\
           --iterations N        Gibbs sweeps\n\
           --docs N              synthetic corpus documents\n\
           --vocab V             vocabulary size\n\
           --doc-len L           mean document length\n\
           --projection MODE     off | single | distributed | ondemand\n\
           --snapshot-dir DIR    persist server snapshots here (serve input)\n\
           --corpus-file FILE    train on a docword file instead of the\n\
                                 synthetic corpus (UCI bag-of-words layout)\n\
           --checkpoint-to DIR   checkpoint the whole cluster (server +\n\
                                 client snapshots + session meta) at the\n\
                                 end of the run; resumable and servable\n\
           --resume-from DIR     resume a checkpointed session and train\n\
                                 --iterations MORE iterations under the\n\
                                 same run id\n\
           --progress            print live eval metrics as they stream\n\
           --seed S              global seed\n\
           --config FILE         JSON config overlay\n\
           --out FILE            write the report JSON here\n\
           --report-out FILE     alias for --out\n\
           --pjrt                evaluate through the PJRT artifacts\n\
           -v / -q               verbose / quiet\n\
         serve options:\n\
           --snapshot DIR        snapshot directory written by train\n\
           --model NAME          expected family; errors if the snapshot\n\
                                 records a different one\n\
           --watch               poll DIR and hot-reload newer snapshots\n\
                                 (generation swaps, queue preserved)\n\
           --watch-interval-ms MS  snapshot-poll interval (default 200)\n\
           --listen ADDR         serve over the wire protocol instead of\n\
                                 running the synthetic query stream: TCP\n\
                                 host:port (port 0 picks one) or unix:/path\n\
           --reactors N          reactor threads for --listen (default 2,\n\
                                 0 = one per core)\n\
           --replicas R          partition the vocabulary over R model\n\
                                 slices by consistent hashing (default 1);\n\
                                 reloads commit set-wide\n\
           --queries N           synthetic queries to run (default 2000)\n\
           --workers W           worker threads (default 2)\n\
           --batch B             max micro-batch size (default 32)\n\
           --cache-mb M          alias-cache budget in MiB, per replica\n\
                                 (default 64)\n\
           --doc-len L           mean query length (default 32)\n\
           --seed S              query + service seed\n\
         infer options:\n\
           --snapshot DIR        snapshot directory written by train\n\
           --tokens \"W W ...\"    word ids of the document\n\
           --model NAME          expected family (optional cross-check)\n\
           --replicas R          route through R replicas and report which\n\
                                 ones served (θ is bit-identical to R=1)\n\
           --top N               topics to print (default 8)\n\
         bench-serve options:\n\
           --snapshot DIR        spin up an in-process wire server over\n\
                                 this snapshot and load-test it\n\
           --addr ADDR           load-test an already-running wire server\n\
                                 instead (TCP host:port or unix:/path)\n\
           --connections C       concurrent connections (default 8)\n\
           --requests N          requests per connection (default 64)\n\
           --rate QPS            open-loop total arrival rate; 0 = closed\n\
                                 loop (default 0)\n\
           --window W            closed-loop in-flight per connection\n\
                                 (default 4)\n\
           --doc-len L           mean query length (default 20)\n\
           --reactors N          reactor threads for --snapshot (default 2)\n\
           --replicas R          serving replicas for --snapshot (default 1)\n\
           --seed S              query-stream + service seed\n\
         chaos options:\n\
           --seed S              fault-schedule seed (default: CHAOS_SEED\n\
                                 env var, else the built-in seed)\n\
           --replicas R          initial serving replica count (default 2)\n\
           --warmup N            pre-chaos iterations (default 4)\n\
           --iterations N        absolute iteration target of the chaotic\n\
                                 segment (default 16)\n\
         pipeline options:\n\
           --corpus-file FILE    stream this docword file (UCI bag-of-words\n\
                                 layout); default: generate a synthetic\n\
                                 corpus and stream it from a temp file\n\
           --chunk-docs N        documents per streamed chunk — the\n\
                                 resident stream-buffer bound (default 200)\n\
           --docs N              synthetic corpus documents (default 1000)\n\
           --vocab V             synthetic vocabulary size (default 1000)\n\
           --model NAME          yahoolda | aliaslda | pdp | hdp\n\
           --topics K            topic count (default 16)\n\
           --clients N           client (worker) count (default 2)\n\
           --replicas R          serving replicas (default 2)\n\
           --checkpoint-dir DIR  cluster checkpoints + serving reload\n\
                                 source (default: a temp directory)\n\
           --checkpoint-every B  checkpoint + reload every B batches\n\
                                 (default 2)\n\
           --warmup N            bootstrap-chunk sweeps before serving\n\
                                 starts (default 4)\n\
           --kappa X             online decay exponent in (0.5, 1]\n\
                                 (default 0.7)\n\
           --tau X               online decay delay ≥ 0 (default 1)\n\
           --base-sweeps N       sweeps for the first batch (default 4)\n\
           --seed S              global seed"
    );
    std::process::exit(2)
}

struct ArgIter<'a> {
    args: &'a [String],
    i: usize,
}

impl<'a> ArgIter<'a> {
    fn next(&mut self) -> Option<&'a str> {
        let v = self.args.get(self.i).map(String::as_str);
        self.i += 1;
        v
    }
    fn value(&mut self, flag: &str) -> &'a str {
        match self.next() {
            Some(v) => v,
            None => {
                eprintln!("missing value for {flag}");
                usage()
            }
        }
    }
}

struct TrainArgs {
    cfg: TrainConfig,
    out: Option<String>,
    resume_from: Option<std::path::PathBuf>,
    corpus_file: Option<std::path::PathBuf>,
    checkpoint_to: Option<std::path::PathBuf>,
    progress: bool,
    /// Config-shaping flags seen on the command line — incompatible with
    /// `--resume-from` (the checkpoint's recorded config wins there, and
    /// silently ignoring a contradiction would be an operator trap).
    cfg_flags: Vec<&'static str>,
}

fn parse_args(args: &[String]) -> TrainArgs {
    let mut cfg = TrainConfig::default();
    let mut out = None;
    let mut resume_from = None;
    let mut corpus_file = None;
    let mut checkpoint_to = None;
    let mut progress = false;
    let mut cfg_flags: Vec<&'static str> = Vec::new();
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        for flag in [
            "--model",
            "--clients",
            "--topics",
            "--docs",
            "--vocab",
            "--doc-len",
            "--projection",
            "--seed",
            "--snapshot-dir",
            "--config",
            "--corpus-file",
            "--pjrt",
        ] {
            if arg == flag {
                cfg_flags.push(flag);
            }
        }
        match arg {
            "--model" => {
                let v = it.value("--model");
                cfg.model = ModelKind::parse(v).unwrap_or_else(|| usage());
            }
            "--clients" => {
                cfg.cluster.clients = it.value("--clients").parse().unwrap_or_else(|_| usage())
            }
            "--topics" => {
                cfg.params.topics = it.value("--topics").parse().unwrap_or_else(|_| usage());
                cfg.corpus.n_topics = cfg.params.topics.min(64);
            }
            "--iterations" => {
                cfg.iterations = it.value("--iterations").parse().unwrap_or_else(|_| usage())
            }
            "--docs" => {
                cfg.corpus.n_docs = it.value("--docs").parse().unwrap_or_else(|_| usage())
            }
            "--vocab" => {
                cfg.corpus.vocab_size = it.value("--vocab").parse().unwrap_or_else(|_| usage())
            }
            "--doc-len" => {
                cfg.corpus.doc_len_mean =
                    it.value("--doc-len").parse().unwrap_or_else(|_| usage())
            }
            "--projection" => {
                let v = it.value("--projection");
                cfg.projection = ProjectionMode::parse(v).unwrap_or_else(|| usage());
            }
            "--seed" => {
                cfg.seed = it.value("--seed").parse().unwrap_or_else(|_| usage());
                cfg.corpus.seed = cfg.seed;
            }
            "--snapshot-dir" => {
                cfg.cluster.snapshot_dir =
                    Some(std::path::PathBuf::from(it.value("--snapshot-dir")));
            }
            "--config" => {
                let path = it.value("--config");
                let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                    eprintln!("cannot read {path}: {e}");
                    std::process::exit(2)
                });
                let j = Json::parse(&text).unwrap_or_else(|e| {
                    eprintln!("bad JSON in {path}: {e}");
                    std::process::exit(2)
                });
                cfg.apply_json(&j).unwrap_or_else(|e| {
                    eprintln!("bad config: {e}");
                    std::process::exit(2)
                });
            }
            "--out" => out = Some(it.value("--out").to_string()),
            "--report-out" => out = Some(it.value("--report-out").to_string()),
            "--resume-from" => {
                resume_from = Some(std::path::PathBuf::from(it.value("--resume-from")))
            }
            "--corpus-file" => {
                corpus_file = Some(std::path::PathBuf::from(it.value("--corpus-file")))
            }
            "--checkpoint-to" => {
                checkpoint_to = Some(std::path::PathBuf::from(it.value("--checkpoint-to")))
            }
            "--progress" => progress = true,
            "--pjrt" => cfg.use_pjrt_eval = true,
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    TrainArgs {
        cfg,
        out,
        resume_from,
        corpus_file,
        checkpoint_to,
        progress,
        cfg_flags,
    }
}

struct ServeArgs {
    snapshot: std::path::PathBuf,
    model: Option<ModelKind>,
    watch: bool,
    watch_interval_ms: u64,
    listen: Option<String>,
    reactors: usize,
    replicas: usize,
    queries: usize,
    workers: usize,
    batch: usize,
    cache_mb: usize,
    doc_len: f64,
    seed: u64,
    tokens: Vec<u32>,
    top: usize,
}

fn parse_serve_args(args: &[String]) -> ServeArgs {
    let mut out = ServeArgs {
        snapshot: std::path::PathBuf::new(),
        model: None,
        watch: false,
        watch_interval_ms: ServeConfig::default().watch_interval_ms,
        listen: None,
        reactors: 2,
        replicas: 1,
        queries: 2_000,
        workers: 2,
        batch: 32,
        cache_mb: 64,
        doc_len: 32.0,
        seed: 42,
        tokens: Vec::new(),
        top: 8,
    };
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        match arg {
            "--snapshot" => out.snapshot = std::path::PathBuf::from(it.value("--snapshot")),
            "--model" => {
                let v = it.value("--model");
                out.model = Some(ModelKind::parse(v).unwrap_or_else(|| usage()));
            }
            "--watch" => out.watch = true,
            "--watch-interval-ms" => {
                out.watch_interval_ms = it
                    .value("--watch-interval-ms")
                    .parse()
                    .unwrap_or_else(|_| usage());
                if out.watch_interval_ms == 0 {
                    eprintln!("--watch-interval-ms must be at least 1");
                    usage()
                }
            }
            "--listen" => out.listen = Some(it.value("--listen").to_string()),
            "--reactors" => {
                out.reactors = it.value("--reactors").parse().unwrap_or_else(|_| usage())
            }
            "--replicas" => {
                out.replicas = it.value("--replicas").parse().unwrap_or_else(|_| usage());
                if out.replicas == 0 {
                    eprintln!("--replicas must be at least 1");
                    usage()
                }
            }
            "--queries" => {
                out.queries = it.value("--queries").parse().unwrap_or_else(|_| usage())
            }
            "--workers" => {
                out.workers = it.value("--workers").parse().unwrap_or_else(|_| usage())
            }
            "--batch" => out.batch = it.value("--batch").parse().unwrap_or_else(|_| usage()),
            "--cache-mb" => {
                out.cache_mb = it.value("--cache-mb").parse().unwrap_or_else(|_| usage())
            }
            "--doc-len" => {
                out.doc_len = it.value("--doc-len").parse().unwrap_or_else(|_| usage())
            }
            "--seed" => out.seed = it.value("--seed").parse().unwrap_or_else(|_| usage()),
            "--top" => out.top = it.value("--top").parse().unwrap_or_else(|_| usage()),
            "--tokens" => {
                out.tokens = it
                    .value("--tokens")
                    .split([' ', ','])
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse().unwrap_or_else(|_| usage()))
                    .collect();
            }
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    if out.snapshot.as_os_str().is_empty() {
        eprintln!("--snapshot DIR is required");
        usage()
    }
    out
}

struct ChaosArgs {
    seed: u64,
    replicas: usize,
    warmup: u64,
    target: u64,
}

fn parse_chaos_args(args: &[String]) -> ChaosArgs {
    let mut out = ChaosArgs {
        seed: hplvm::chaos::chaos_seed(),
        replicas: 2,
        warmup: 4,
        target: 16,
    };
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        match arg {
            "--seed" => out.seed = it.value("--seed").parse().unwrap_or_else(|_| usage()),
            "--replicas" => {
                out.replicas = it.value("--replicas").parse().unwrap_or_else(|_| usage());
                if out.replicas == 0 {
                    eprintln!("--replicas must be at least 1");
                    usage()
                }
            }
            "--warmup" => {
                out.warmup = it.value("--warmup").parse().unwrap_or_else(|_| usage())
            }
            "--iterations" => {
                out.target = it.value("--iterations").parse().unwrap_or_else(|_| usage())
            }
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    if out.target <= out.warmup {
        eprintln!("--iterations must exceed --warmup");
        usage()
    }
    out
}

/// `hplvm chaos`: run the seeded elastic-membership drill — kill a
/// worker and a server slot, grow the server ring, resize the serving
/// set, spike the transport — against a live session with a query
/// stream, and print the [`hplvm::chaos::ChaosReport`].
fn cmd_chaos(a: ChaosArgs) -> hplvm::Result<()> {
    let cfg = hplvm::chaos::chaos_train_config();
    let plan = hplvm::chaos::ChaosPlan::seeded(
        a.seed,
        a.warmup,
        a.target,
        cfg.cluster.n_servers(),
        a.replicas,
    );
    println!(
        "chaos drill: seed {:#x} | {} scheduled fault(s) | warmup {} → target {} | \
         {} server slot(s), {} serving replica(s)",
        a.seed,
        plan.events.len(),
        a.warmup,
        a.target,
        cfg.cluster.n_servers(),
        a.replicas,
    );
    let report =
        hplvm::chaos::ChaosHarness::new(cfg, plan, a.replicas, a.warmup, a.target).run()?;
    print!("{}", report.render());
    println!("reproduce with: CHAOS_SEED={} hplvm chaos", report.seed);
    Ok(())
}

struct PipelineArgs {
    corpus_file: Option<std::path::PathBuf>,
    chunk_docs: usize,
    docs: usize,
    vocab: usize,
    model: ModelKind,
    topics: usize,
    clients: usize,
    replicas: usize,
    checkpoint_dir: Option<std::path::PathBuf>,
    checkpoint_every: u64,
    warmup: u64,
    kappa: f64,
    tau: f64,
    base_sweeps: u64,
    seed: u64,
}

fn parse_pipeline_args(args: &[String]) -> PipelineArgs {
    let mut out = PipelineArgs {
        corpus_file: None,
        chunk_docs: 200,
        docs: 1000,
        vocab: 1000,
        model: ModelKind::AliasLda,
        topics: 16,
        clients: 2,
        replicas: 2,
        checkpoint_dir: None,
        checkpoint_every: 2,
        warmup: 4,
        kappa: 0.7,
        tau: 1.0,
        base_sweeps: 4,
        seed: 42,
    };
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        match arg {
            "--corpus-file" => out.corpus_file = Some(it.value("--corpus-file").into()),
            "--chunk-docs" => {
                out.chunk_docs = it.value("--chunk-docs").parse().unwrap_or_else(|_| usage())
            }
            "--docs" => out.docs = it.value("--docs").parse().unwrap_or_else(|_| usage()),
            "--vocab" => out.vocab = it.value("--vocab").parse().unwrap_or_else(|_| usage()),
            "--model" => {
                let v = it.value("--model");
                out.model = ModelKind::parse(v).unwrap_or_else(|| usage());
            }
            "--topics" => out.topics = it.value("--topics").parse().unwrap_or_else(|_| usage()),
            "--clients" => {
                out.clients = it.value("--clients").parse().unwrap_or_else(|_| usage())
            }
            "--replicas" => {
                out.replicas = it.value("--replicas").parse().unwrap_or_else(|_| usage())
            }
            "--checkpoint-dir" => {
                out.checkpoint_dir = Some(it.value("--checkpoint-dir").into())
            }
            "--checkpoint-every" => {
                out.checkpoint_every = it
                    .value("--checkpoint-every")
                    .parse()
                    .unwrap_or_else(|_| usage())
            }
            "--warmup" => out.warmup = it.value("--warmup").parse().unwrap_or_else(|_| usage()),
            "--kappa" => out.kappa = it.value("--kappa").parse().unwrap_or_else(|_| usage()),
            "--tau" => out.tau = it.value("--tau").parse().unwrap_or_else(|_| usage()),
            "--base-sweeps" => {
                out.base_sweeps = it.value("--base-sweeps").parse().unwrap_or_else(|_| usage())
            }
            "--seed" => out.seed = it.value("--seed").parse().unwrap_or_else(|_| usage()),
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    if out.chunk_docs == 0 {
        eprintln!("--chunk-docs must be at least 1");
        usage()
    }
    out
}

/// `hplvm pipeline`: stream a docword file (or a freshly generated
/// synthetic corpus spilled to a temp file) through the online
/// train-while-serve loop and print the [`hplvm::pipeline::PipelineReport`]
/// time series.
fn cmd_pipeline(a: PipelineArgs) -> hplvm::Result<()> {
    use hplvm::corpus::stream::{CorpusStream, StreamingSource};
    use hplvm::pipeline::{OnlinePolicy, Pipeline, PipelineConfig};

    let tmp = std::env::temp_dir().join(format!("hplvm_pipeline_{}", std::process::id()));
    let scratch = a.corpus_file.is_none() || a.checkpoint_dir.is_none();
    if scratch {
        std::fs::create_dir_all(&tmp)?;
    }
    let path = match &a.corpus_file {
        Some(p) => p.clone(),
        None => {
            // No file given: generate the seeded synthetic corpus and
            // spill it to disk, then stream it back like any other file.
            let mut gen = hplvm::corpus::generator::CorpusConfig::default();
            gen.n_docs = a.docs;
            gen.vocab_size = a.vocab;
            gen.n_topics = a.topics.min(64);
            gen.seed = a.seed;
            let (corpus, _vocab) = gen.generate();
            let p = tmp.join("docword.pipeline.txt");
            hplvm::corpus::source::write_docword(&p, &corpus)?;
            println!(
                "generated {} synthetic docs (vocab {}) → {}",
                a.docs,
                a.vocab,
                p.display()
            );
            p
        }
    };
    let ckpt = a
        .checkpoint_dir
        .clone()
        .unwrap_or_else(|| tmp.join("ckpt"));

    let mut train = TrainConfig::default();
    train.model = a.model;
    train.params.topics = a.topics;
    train.cluster.clients = a.clients;
    train.seed = a.seed;
    train.eval_every = 2;
    // The held-out split comes out of the bootstrap chunk, so it must
    // fit inside one chunk with room to train on the rest.
    train.test_docs = (a.chunk_docs / 4).clamp(1, 200);

    let mut cfg = PipelineConfig::new(train, ckpt);
    cfg.policy = OnlinePolicy::new(a.kappa, a.tau, a.base_sweeps)?;
    cfg.checkpoint_every_batches = a.checkpoint_every;
    cfg.replicas = a.replicas;
    cfg.warmup_sweeps = a.warmup;

    let mut stream = StreamingSource::open(&path, a.chunk_docs)?;
    println!(
        "streaming {} (vocab {}) in {}-doc chunks | checkpoint every {} batches → {} replicas",
        stream.describe(),
        stream.vocab_size(),
        a.chunk_docs,
        a.checkpoint_every,
        a.replicas,
    );
    let report = Pipeline::run(cfg, &mut stream)?;
    print!("{}", report.render());
    if scratch {
        std::fs::remove_dir_all(&tmp).ok();
    }
    Ok(())
}

/// The loaded serving topology: one in-process model, or a
/// consistent-hash-routed replica set (`--replicas N`).
#[derive(Clone)]
enum Backend {
    Single(Arc<ServingHandle>),
    Set(Arc<ReplicaSet>),
}

impl Backend {
    fn load(a: &ServeArgs) -> Backend {
        let budget = a.cache_mb << 20;
        let loaded = if a.replicas > 1 {
            ReplicaSet::load_dir_with_budget(&a.snapshot, a.replicas, budget).map(Backend::Set)
        } else {
            ServingHandle::load_dir_with_budget(&a.snapshot, budget).map(Backend::Single)
        };
        let backend = match loaded {
            Ok(b) => b,
            Err(e) => {
                eprintln!("cannot load snapshot: {e:#}");
                std::process::exit(1)
            }
        };
        // An explicit --model that contradicts the family the snapshot
        // records is an operator error — refuse loudly instead of
        // silently serving the wrong posterior.
        if let Some(kind) = a.model {
            if let Err(e) = backend.primary_model().ensure_family(kind) {
                eprintln!("{e:#}");
                std::process::exit(1)
            }
        }
        backend
    }

    /// A representative model for header prints (replica 0's slice and
    /// the single model agree on all global metadata).
    fn primary_model(&self) -> Arc<ServingModel> {
        match self {
            Backend::Single(h) => h.model(),
            Backend::Set(s) => s.current().models()[0].clone(),
        }
    }

    fn generation(&self) -> u64 {
        match self {
            Backend::Single(h) => h.generation(),
            Backend::Set(s) => s.generation(),
        }
    }

    fn reload(&self, dir: &std::path::Path) -> hplvm::Result<u64> {
        match self {
            Backend::Single(h) => h.reload(dir),
            Backend::Set(s) => s.reload(dir),
        }
    }

    /// Whether the last reload decoded the whole directory or overlaid
    /// only the segments newer than the resident generation.
    fn last_reload_stats(&self) -> hplvm::serve::ReloadStats {
        match self {
            Backend::Single(h) => h.last_reload_stats(),
            Backend::Set(s) => s.last_reload_stats(),
        }
    }

    fn query_backend(&self) -> Arc<dyn QueryBackend> {
        match self {
            Backend::Single(h) => h.clone(),
            Backend::Set(s) => s.clone(),
        }
    }

    fn print_cache_stats(&self) {
        fn print_one(prefix: &str, c: &hplvm::serve::CacheStats) {
            println!(
                "{prefix}alias cache: {} resident ({:.1} MiB), {} hits / {} misses / {} \
                 evictions / {} pre-warmed",
                c.resident,
                c.resident_bytes as f64 / (1 << 20) as f64,
                c.hits,
                c.misses,
                c.evictions,
                c.prewarmed,
            );
        }
        match self {
            Backend::Single(h) => print_one("", &h.model().cache_stats()),
            Backend::Set(s) => {
                for (r, m) in s.current().models().iter().enumerate() {
                    print_one(&format!("replica {r} "), &m.cache_stats());
                }
            }
        }
    }
}

/// Fingerprint the slot snapshots in a directory (name, size, mtime,
/// run id): the `--watch` poller reloads when this changes. The run id
/// comes from a header-only read ([`hplvm::ps::snapshot::read_slot_meta`])
/// and catches a same-config *retrain* whose files match the old ones in
/// size and mtime tick. (A same-run periodic rewrite that keeps the byte
/// length and lands within one coarse mtime tick can still slip a poll;
/// it self-heals at the next snapshot cadence tick.)
fn snapshot_fingerprint(
    dir: &std::path::Path,
) -> Vec<(String, u64, std::time::SystemTime, u64)> {
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if !hplvm::ps::snapshot::is_slot_snapshot_name(&name) {
                continue;
            }
            if let Ok(md) = entry.metadata() {
                let mtime = md.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                let run_id = hplvm::ps::snapshot::read_slot_meta(&entry.path())
                    .map(|m| m.run_id)
                    .unwrap_or(0);
                out.push((name, md.len(), mtime, run_id));
            }
        }
    }
    out.sort();
    out
}

/// Spawn the `--watch` poller: fingerprint the snapshot directory every
/// `interval_ms` (lifted into [`ServeConfig::watch_interval_ms`], set
/// with `--watch-interval-ms`), debounce one full tick, and hot-reload
/// through the backend. Reload failures are **logged, never swallowed**
/// — the server keeps answering on the generation it has and retries
/// when the directory changes again.
fn spawn_watcher(
    backend: Backend,
    dir: std::path::PathBuf,
    baseline: Vec<(String, u64, std::time::SystemTime, u64)>,
    interval_ms: u64,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut loaded = baseline;
        let mut pending: Option<Vec<_>> = None;
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(1)));
            let now = snapshot_fingerprint(&dir);
            if now == loaded || now.is_empty() {
                pending = None;
                continue;
            }
            // Debounce: the trainer writes slot files sequentially, so
            // only reload once the directory has been stable for a
            // full tick (load_dir additionally rejects half-written
            // mixed-run directories).
            if pending.as_ref() != Some(&now) {
                pending = Some(now);
                continue;
            }
            pending = None;
            match backend.reload(&dir) {
                Ok(g) => {
                    let st = backend.last_reload_stats();
                    if st.full {
                        hplvm::info!(
                            "serve",
                            "hot-reloaded snapshots → generation {g} (full decode)"
                        );
                    } else {
                        hplvm::info!(
                            "serve",
                            "hot-reloaded snapshots → generation {g} \
                             (diff: {} segments, {} rows)",
                            st.segments,
                            st.rows
                        );
                    }
                }
                // Mark the failed fingerprint as seen either way: a
                // permanently bad directory is reported once, then
                // retried only when the directory changes again.
                Err(e) => hplvm::warn!(
                    "serve",
                    "hot-reload failed (still serving generation {}; will \
                     retry on the next directory change): {e:#}",
                    backend.generation()
                ),
            }
            loaded = now;
        }
    })
}

/// `hplvm train`: drive a [`TrainSession`] — fresh (synthetic or docword
/// corpus) or resumed from a checkpoint — then optionally checkpoint the
/// cluster and dump the report JSON.
fn cmd_train(a: TrainArgs) -> hplvm::Result<TrainReport> {
    let observer: Arc<dyn TrainObserver> = if a.progress {
        Arc::new(PrintObserver)
    } else {
        Arc::new(NullObserver)
    };
    let iterations = a.cfg.iterations;
    let mut session = match &a.resume_from {
        Some(dir) => {
            // The checkpoint's recorded config drives a resumed run;
            // silently ignoring contradicting flags would be a trap.
            anyhow::ensure!(
                a.cfg_flags.is_empty(),
                "--resume-from uses the checkpoint's recorded configuration; \
                 remove {} (only --iterations, --progress, --checkpoint-to and \
                 --out/--report-out apply to a resumed run)",
                a.cfg_flags.join(", ")
            );
            let session = TrainSession::resume_with_observer(dir, observer)?;
            println!(
                "resumed {} run {:#018x} at iteration {} from {} (+{} iterations)",
                session.config().model.name(),
                session.run_id(),
                session.iteration(),
                dir.display(),
                iterations,
            );
            session
        }
        None => {
            println!(
                "training {} | K={} clients={} servers={} iterations={} projection={:?}",
                a.cfg.model.name(),
                a.cfg.params.topics,
                a.cfg.cluster.clients,
                a.cfg.cluster.n_servers(),
                iterations,
                a.cfg.projection,
            );
            let source: Box<dyn CorpusSource> = match &a.corpus_file {
                Some(f) => Box::new(FileSource::new(f)),
                None => Box::new(SyntheticSource::new(a.cfg.corpus.clone())),
            };
            if let Some(f) = &a.corpus_file {
                println!("corpus: docword file {}", f.display());
            }
            TrainSession::start_with_observer(a.cfg, source.as_ref(), observer)?
        }
    };
    // A fresh run trains to the configured count; a resumed run trains
    // that many *more* under the same run id.
    session.run_for(iterations)?;
    if let Some(dir) = &a.checkpoint_to {
        session.checkpoint(dir)?;
        println!(
            "checkpoint written to {} (resume with --resume-from, serve with \
             --snapshot)",
            dir.display()
        );
    }
    let report = session.finish()?;
    report.print_table();
    if let Some(path) = &a.out {
        std::fs::write(path, report.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))?;
        println!("report written to {path}");
    }
    Ok(report)
}

fn cmd_serve(a: ServeArgs) {
    if a.listen.is_some() {
        cmd_serve_listen(a);
        return;
    }
    // Baseline the directory BEFORE loading (only when watching): a
    // snapshot landing between the load and the watcher's first poll
    // must still trigger a reload.
    let baseline = a.watch.then(|| snapshot_fingerprint(&a.snapshot));
    let backend = Backend::load(&a);
    {
        let model = backend.primary_model();
        println!(
            "serving {} (family {}) | K={} vocab={} | {} tokens in frozen statistics | generation {} | {} workers, batch {}, cache {} MiB{}{}",
            model.meta().model,
            model.kind().family_name(),
            model.k(),
            model.vocab(),
            model.total_tokens(),
            backend.generation(),
            a.workers.max(1),
            a.batch,
            a.cache_mb,
            if a.replicas > 1 { " per replica" } else { "" },
            if a.watch { " | watching for new snapshots" } else { "" },
        );
        if let Backend::Set(set) = &backend {
            // Replica topology: the router's vocabulary partition.
            for (r, owned) in set.router().spread(model.vocab()).iter().enumerate() {
                println!(
                    "  replica {r}: owns {owned} of {} words ({:.1}%)",
                    model.vocab(),
                    100.0 * *owned as f64 / model.vocab().max(1) as f64,
                );
            }
        }
    }
    let serve_cfg = ServeConfig {
        workers: a.workers,
        max_batch: a.batch,
        seed: a.seed,
        watch_interval_ms: a.watch_interval_ms,
        ..Default::default()
    };
    let svc = InferenceService::spawn(backend.query_backend(), serve_cfg.clone());
    // --watch: poll the snapshot directory in the background and swap in
    // newer generations without disturbing the queue. Replica sets
    // commit the swap set-wide: the bumped generation is visible only
    // once every replica has installed its slice.
    let stop_watch = Arc::new(AtomicBool::new(false));
    let watcher = baseline.map(|baseline| {
        spawn_watcher(
            backend.clone(),
            a.snapshot.clone(),
            baseline,
            serve_cfg.watch_interval_ms,
            stop_watch.clone(),
        )
    });
    // Synthetic Zipf query stream over the model's vocabulary.
    let vocab = backend.primary_model().vocab();
    let queries = hplvm::serve::synth_queries(vocab, a.queries, a.doc_len, a.seed ^ 0x5E17E);
    let t0 = std::time::Instant::now();
    let latencies = hplvm::serve::run_queries(&svc, &queries, 512);
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "{} queries in {:.2}s  →  {:.0} queries/s (final generation {})",
        latencies.len(),
        wall,
        latencies.len() as f64 / wall.max(1e-9),
        backend.generation(),
    );
    println!(
        "latency p50 {:.3} ms | p99 {:.3} ms | batches {} (avg size {:.1}) | peak queue {}",
        hplvm::bench::percentile(&latencies, 50.0) * 1e3,
        hplvm::bench::percentile(&latencies, 99.0) * 1e3,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64,
        stats.peak_queue,
    );
    backend.print_cache_stats();
    stop_watch.store(true, Ordering::Relaxed);
    if let Some(w) = watcher {
        let _ = w.join();
    }
    svc.shutdown();
}

/// `hplvm serve --listen`: the wire front-end. Bind the address, start
/// the accept + reactor threads over the loaded backend, optionally
/// watch the snapshot directory for hot reloads, and serve until the
/// process is killed (counters print once a minute).
fn cmd_serve_listen(a: ServeArgs) {
    let addr = hplvm::net::ListenAddr::parse(a.listen.as_deref().unwrap_or(""));
    let baseline = a.watch.then(|| snapshot_fingerprint(&a.snapshot));
    let backend = Backend::load(&a);
    let info = {
        let model = backend.primary_model();
        println!(
            "serving {} (family {}) over the wire | K={} vocab={} | generation {} | \
             {} replica(s) | batch {}{}",
            model.meta().model,
            model.kind().family_name(),
            model.k(),
            model.vocab(),
            backend.generation(),
            a.replicas,
            a.batch,
            if a.watch { " | watching for new snapshots" } else { "" },
        );
        hplvm::net::ModelInfo {
            family: model.kind().family_name().to_string(),
            k: model.k() as u32,
            vocab: model.vocab() as u32,
        }
    };
    let wire_cfg = hplvm::net::WireConfig {
        reactors: a.reactors,
        service: ServeConfig {
            workers: a.workers.max(1),
            max_batch: a.batch,
            seed: a.seed,
            watch_interval_ms: a.watch_interval_ms,
            ..Default::default()
        },
        ..hplvm::net::WireConfig::default()
    };
    let watch_ms = wire_cfg.service.watch_interval_ms;
    let server =
        match hplvm::net::WireServer::start(backend.query_backend(), info, &addr, wire_cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot start wire server: {e:#}");
                std::process::exit(1)
            }
        };
    println!("listening on {}", server.local_addr());
    let stop_watch = Arc::new(AtomicBool::new(false));
    let _watcher = baseline.map(|baseline| {
        spawn_watcher(
            backend.clone(),
            a.snapshot.clone(),
            baseline,
            watch_ms,
            stop_watch.clone(),
        )
    });
    loop {
        std::thread::sleep(std::time::Duration::from_secs(60));
        let s = server.stats();
        println!(
            "wire: {} open / {} accepted | {} frames in | {} served | {} errors \
             (generation {})",
            s.connections,
            s.accepted,
            s.frames_in,
            s.served,
            s.errors,
            backend.generation(),
        );
    }
}

struct BenchServeArgs {
    snapshot: Option<std::path::PathBuf>,
    addr: Option<String>,
    connections: usize,
    requests: usize,
    rate: f64,
    window: usize,
    doc_len: f64,
    seed: u64,
    reactors: usize,
    replicas: usize,
    workers: usize,
    batch: usize,
    cache_mb: usize,
}

fn parse_bench_serve_args(args: &[String]) -> BenchServeArgs {
    let mut out = BenchServeArgs {
        snapshot: None,
        addr: None,
        connections: 8,
        requests: 64,
        rate: 0.0,
        window: 4,
        doc_len: 20.0,
        seed: 42,
        reactors: 2,
        replicas: 1,
        workers: 1,
        batch: 32,
        cache_mb: 64,
    };
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        match arg {
            "--snapshot" => {
                out.snapshot = Some(std::path::PathBuf::from(it.value("--snapshot")))
            }
            "--addr" => out.addr = Some(it.value("--addr").to_string()),
            "--connections" => {
                out.connections =
                    it.value("--connections").parse().unwrap_or_else(|_| usage())
            }
            "--requests" => {
                out.requests = it.value("--requests").parse().unwrap_or_else(|_| usage())
            }
            "--rate" => out.rate = it.value("--rate").parse().unwrap_or_else(|_| usage()),
            "--window" => {
                out.window = it.value("--window").parse().unwrap_or_else(|_| usage())
            }
            "--doc-len" => {
                out.doc_len = it.value("--doc-len").parse().unwrap_or_else(|_| usage())
            }
            "--seed" => out.seed = it.value("--seed").parse().unwrap_or_else(|_| usage()),
            "--reactors" => {
                out.reactors = it.value("--reactors").parse().unwrap_or_else(|_| usage())
            }
            "--replicas" => {
                out.replicas = it.value("--replicas").parse().unwrap_or_else(|_| usage());
                if out.replicas == 0 {
                    eprintln!("--replicas must be at least 1");
                    usage()
                }
            }
            "--workers" => {
                out.workers = it.value("--workers").parse().unwrap_or_else(|_| usage())
            }
            "--batch" => out.batch = it.value("--batch").parse().unwrap_or_else(|_| usage()),
            "--cache-mb" => {
                out.cache_mb = it.value("--cache-mb").parse().unwrap_or_else(|_| usage())
            }
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    if out.snapshot.is_none() && out.addr.is_none() {
        eprintln!("bench-serve needs --snapshot DIR or --addr ADDR");
        usage()
    }
    out
}

/// `hplvm bench-serve`: drive the wire load generator — against an
/// already-running server (`--addr`), or against a wire server spun up
/// in-process over a snapshot directory (`--snapshot`, loopback TCP on a
/// free port). The HELLO handshake supplies the vocabulary the synthetic
/// query streams draw from.
fn cmd_bench_serve(a: BenchServeArgs) -> hplvm::Result<()> {
    let timeout = std::time::Duration::from_secs(60);
    // A locally spun-up server (and the backend keeping it alive) lives
    // here so it outlives the run and shuts down cleanly afterwards.
    let mut local: Option<hplvm::net::WireServer> = None;
    let addr = match (&a.addr, &a.snapshot) {
        (Some(addr), _) => addr.clone(),
        (None, Some(dir)) => {
            let serve_args = ServeArgs {
                snapshot: dir.clone(),
                model: None,
                watch: false,
                watch_interval_ms: ServeConfig::default().watch_interval_ms,
                listen: None,
                reactors: a.reactors,
                replicas: a.replicas,
                queries: 0,
                workers: a.workers,
                batch: a.batch,
                cache_mb: a.cache_mb,
                doc_len: a.doc_len,
                seed: a.seed,
                tokens: Vec::new(),
                top: 8,
            };
            let backend = Backend::load(&serve_args);
            let model = backend.primary_model();
            let info = hplvm::net::ModelInfo {
                family: model.kind().family_name().to_string(),
                k: model.k() as u32,
                vocab: model.vocab() as u32,
            };
            let server = hplvm::net::WireServer::start(
                backend.query_backend(),
                info,
                &hplvm::net::ListenAddr::parse("127.0.0.1:0"),
                hplvm::net::WireConfig {
                    reactors: a.reactors,
                    service: ServeConfig {
                        workers: a.workers.max(1),
                        max_batch: a.batch,
                        seed: a.seed,
                        ..Default::default()
                    },
                    ..hplvm::net::WireConfig::default()
                },
            )?;
            let addr = server.local_addr().to_string();
            local = Some(server);
            addr
        }
        (None, None) => {
            eprintln!("bench-serve needs --snapshot DIR or --addr ADDR");
            usage()
        }
    };
    let hello = hplvm::net::hello(&addr, timeout)?;
    println!(
        "bench-serve → {addr} | family {} K={} vocab={} generation {} | \
         {} connections × {} requests, {}",
        hello.family,
        hello.k,
        hello.vocab,
        hello.generation,
        a.connections,
        a.requests,
        if a.rate > 0.0 {
            format!("open loop @ {:.0} req/s", a.rate)
        } else {
            format!("closed loop, window {}", a.window)
        },
    );
    let report = hplvm::net::loadgen::run(
        &addr,
        &hplvm::net::LoadgenConfig {
            connections: a.connections,
            requests: a.requests,
            rate: a.rate,
            window: a.window,
            vocab: hello.vocab as usize,
            doc_len: a.doc_len,
            seed: a.seed,
            timeout,
            ..hplvm::net::LoadgenConfig::default()
        },
    )?;
    println!("{}", report.render());
    if let Some(server) = local {
        let s = server.stats();
        println!(
            "server: {} accepted | {} frames in | {} served | {} errors | {} reactor(s)",
            s.accepted, s.frames_in, s.served, s.errors, s.reactors,
        );
        server.shutdown();
    }
    Ok(())
}

fn cmd_infer(a: ServeArgs) {
    if a.tokens.is_empty() {
        eprintln!("--tokens \"W W ...\" is required");
        usage()
    }
    let backend = Backend::load(&a);
    let model = backend.primary_model();
    let mut rng = hplvm::util::rng::Rng::new(a.seed);
    let cfg = hplvm::serve::InferConfig::default();
    let res = match &backend {
        Backend::Single(_) => hplvm::serve::infer_doc(&model, &a.tokens, &cfg, &mut rng),
        // Routed: bit-identical θ to the single path at the same seed;
        // the result additionally reports which replicas served.
        Backend::Set(set) => set.infer(&a.tokens, &cfg, &mut rng),
    };
    println!(
        "{} ({}) generation {} | {} tokens | MH acceptance {:.3}",
        model.meta().model,
        model.kind().family_name(),
        backend.generation(),
        res.tokens,
        res.accepted as f64 / res.proposed.max(1) as f64
    );
    if let Backend::Set(set) = &backend {
        println!(
            "served by replicas {:?} of {} (consistent-hash vocabulary partition)",
            res.served_by,
            set.replicas(),
        );
    }
    for (t, weight) in res.top_topics(a.top) {
        println!("topic {t:>4}  θ = {weight:.4}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "train" => {
            let a = parse_args(&args[1..]);
            match cmd_train(a) {
                Ok(_) => {}
                Err(e) => {
                    eprintln!("training failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "serve" => cmd_serve(parse_serve_args(&args[1..])),
        "bench-serve" => {
            let a = parse_bench_serve_args(&args[1..]);
            if let Err(e) = cmd_bench_serve(a) {
                eprintln!("bench-serve failed: {e:#}");
                std::process::exit(1);
            }
        }
        "infer" => cmd_infer(parse_serve_args(&args[1..])),
        "chaos" => {
            let a = parse_chaos_args(&args[1..]);
            if let Err(e) = cmd_chaos(a) {
                eprintln!("chaos drill failed: {e:#}");
                std::process::exit(1);
            }
        }
        "pipeline" => {
            let a = parse_pipeline_args(&args[1..]);
            if let Err(e) = cmd_pipeline(a) {
                eprintln!("pipeline failed: {e:#}");
                std::process::exit(1);
            }
        }
        "eval-engine" => match hplvm::runtime::Engine::load(std::path::Path::new("artifacts")) {
            Ok(Some(engine)) => {
                println!("PJRT platform: {}", engine.platform());
                for (name, meta) in &engine.manifest().entries {
                    println!(
                        "  artifact {name}: file={} batch={} k={} flavor={}",
                        meta.file, meta.batch, meta.k, meta.flavor
                    );
                }
                // Smoke-execute log_dot with known numbers.
                let k = engine.manifest().entries["log_dot"].k.min(8);
                let theta = vec![1.0f32 / k as f32; k];
                let phi = vec![0.5f32; k];
                match engine.log_dot(&theta, &phi, 1, k) {
                    Ok(v) => println!(
                        "log_dot([uniform]·[0.5]) = {} (expect {})",
                        v[0],
                        0.5f32.ln()
                    ),
                    Err(e) => {
                        eprintln!("execution failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            Ok(None) => {
                eprintln!("no artifacts/manifest.json — run `make artifacts` first");
                std::process::exit(1);
            }
            Err(e) => {
                eprintln!("PJRT unavailable: {e:#}");
                std::process::exit(1);
            }
        },
        "info" => {
            let a = parse_args(&args[1..]);
            println!("{}", a.cfg.to_json());
        }
        _ => usage(),
    }
}
