//! `hplvm` — CLI for the High Performance Latent Variable Models system.
//!
//! ```text
//! hplvm train [--model aliaslda|yahoolda|pdp|hdp] [--clients N] [--topics K]
//!             [--iterations N] [--docs N] [--vocab V] [--projection MODE]
//!             [--config file.json] [--out report.json] [--pjrt] [-v|-q]
//! hplvm eval-engine          # check PJRT artifacts load and execute
//! hplvm info                 # print the resolved configuration
//! ```

use hplvm::config::{ModelKind, ProjectionMode, TrainConfig};
use hplvm::coordinator::trainer::Trainer;
use hplvm::util::json::Json;
use hplvm::util::logging::{self, Level};

fn usage() -> ! {
    eprintln!(
        "usage: hplvm <train|eval-engine|info> [options]\n\
         options:\n\
           --model NAME          yahoolda | aliaslda | pdp | hdp\n\
           --clients N           client (worker) count\n\
           --topics K            topic count / HDP truncation\n\
           --iterations N        Gibbs sweeps\n\
           --docs N              synthetic corpus documents\n\
           --vocab V             vocabulary size\n\
           --doc-len L           mean document length\n\
           --projection MODE     off | single | distributed | ondemand\n\
           --seed S              global seed\n\
           --config FILE         JSON config overlay\n\
           --out FILE            write the report JSON here\n\
           --pjrt                evaluate through the PJRT artifacts\n\
           -v / -q               verbose / quiet"
    );
    std::process::exit(2)
}

struct ArgIter<'a> {
    args: &'a [String],
    i: usize,
}

impl<'a> ArgIter<'a> {
    fn next(&mut self) -> Option<&'a str> {
        let v = self.args.get(self.i).map(String::as_str);
        self.i += 1;
        v
    }
    fn value(&mut self, flag: &str) -> &'a str {
        match self.next() {
            Some(v) => v,
            None => {
                eprintln!("missing value for {flag}");
                usage()
            }
        }
    }
}

fn parse_args(args: &[String]) -> (TrainConfig, Option<String>) {
    let mut cfg = TrainConfig::default();
    let mut out = None;
    let mut it = ArgIter { args, i: 0 };
    while let Some(arg) = it.next() {
        match arg {
            "--model" => {
                let v = it.value("--model");
                cfg.model = ModelKind::parse(v).unwrap_or_else(|| usage());
            }
            "--clients" => {
                cfg.cluster.clients = it.value("--clients").parse().unwrap_or_else(|_| usage())
            }
            "--topics" => {
                cfg.params.topics = it.value("--topics").parse().unwrap_or_else(|_| usage());
                cfg.corpus.n_topics = cfg.params.topics.min(64);
            }
            "--iterations" => {
                cfg.iterations = it.value("--iterations").parse().unwrap_or_else(|_| usage())
            }
            "--docs" => {
                cfg.corpus.n_docs = it.value("--docs").parse().unwrap_or_else(|_| usage())
            }
            "--vocab" => {
                cfg.corpus.vocab_size = it.value("--vocab").parse().unwrap_or_else(|_| usage())
            }
            "--doc-len" => {
                cfg.corpus.doc_len_mean =
                    it.value("--doc-len").parse().unwrap_or_else(|_| usage())
            }
            "--projection" => {
                let v = it.value("--projection");
                cfg.projection = ProjectionMode::parse(v).unwrap_or_else(|| usage());
            }
            "--seed" => {
                cfg.seed = it.value("--seed").parse().unwrap_or_else(|_| usage());
                cfg.corpus.seed = cfg.seed;
            }
            "--config" => {
                let path = it.value("--config");
                let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                    eprintln!("cannot read {path}: {e}");
                    std::process::exit(2)
                });
                let j = Json::parse(&text).unwrap_or_else(|e| {
                    eprintln!("bad JSON in {path}: {e}");
                    std::process::exit(2)
                });
                cfg.apply_json(&j).unwrap_or_else(|e| {
                    eprintln!("bad config: {e}");
                    std::process::exit(2)
                });
            }
            "--out" => out = Some(it.value("--out").to_string()),
            "--pjrt" => cfg.use_pjrt_eval = true,
            "-v" => logging::set_level(Level::Debug),
            "-q" => logging::set_level(Level::Warn),
            _ => {
                eprintln!("unknown option {arg}");
                usage()
            }
        }
    }
    (cfg, out)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "train" => {
            let (cfg, out) = parse_args(&args[1..]);
            println!(
                "training {} | K={} clients={} servers={} iterations={} projection={:?}",
                cfg.model.name(),
                cfg.params.topics,
                cfg.cluster.clients,
                cfg.cluster.n_servers(),
                cfg.iterations,
                cfg.projection,
            );
            match Trainer::new(cfg).run() {
                Ok(report) => {
                    report.print_table();
                    if let Some(path) = out {
                        std::fs::write(&path, report.to_json().to_string())
                            .unwrap_or_else(|e| eprintln!("cannot write {path}: {e}"));
                        println!("report written to {path}");
                    }
                }
                Err(e) => {
                    eprintln!("training failed: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "eval-engine" => match hplvm::runtime::Engine::load(std::path::Path::new("artifacts")) {
            Ok(Some(engine)) => {
                println!("PJRT platform: {}", engine.platform());
                for (name, meta) in &engine.manifest().entries {
                    println!(
                        "  artifact {name}: file={} batch={} k={} flavor={}",
                        meta.file, meta.batch, meta.k, meta.flavor
                    );
                }
                // Smoke-execute log_dot with known numbers.
                let k = engine.manifest().entries["log_dot"].k.min(8);
                let theta = vec![1.0f32 / k as f32; k];
                let phi = vec![0.5f32; k];
                match engine.log_dot(&theta, &phi, 1, k) {
                    Ok(v) => println!(
                        "log_dot([uniform]·[0.5]) = {} (expect {})",
                        v[0],
                        0.5f32.ln()
                    ),
                    Err(e) => {
                        eprintln!("execution failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            Ok(None) => {
                eprintln!("no artifacts/manifest.json — run `make artifacts` first");
                std::process::exit(1);
            }
            Err(e) => {
                eprintln!("PJRT unavailable: {e:#}");
                std::process::exit(1);
            }
        },
        "info" => {
            let (cfg, _) = parse_args(&args[1..]);
            println!("{}", cfg.to_json());
        }
        _ => usage(),
    }
}
