//! # hplvm — High Performance Latent Variable Models
//!
//! A reproduction of *"High Performance Latent Variable Models"*
//! (Li, Ahmed, Li, Josifovski, Smola — 2015): a third-generation
//! **parameter server** carrying the sufficient statistics of topic models
//! (LDA, Poisson-Dirichlet-Process, Hierarchical-Dirichlet-Process),
//! combined with the **Metropolis-Hastings-Walker (alias) sampler** for
//! amortized `O(k_d)` collapsed Gibbs sampling, **eventual consistency**
//! with communication filters, and **parameter projection** to repair the
//! constraint violations relaxed consistency causes.
//!
//! ## Layering
//!
//! * **Layer 8 ([`pipeline`])** — streaming ingest + online
//!   train-while-serve: [`pipeline::Pipeline::run`] pulls a corpus
//!   through a bounded-memory [`corpus::CorpusStream`] in chunks,
//!   ingests each chunk into a *live* [`coordinator::TrainSession`]
//!   (park mode: workers idle at their target and resume on a
//!   target-raise control message; lazy sharding: ingested documents
//!   reach workers through per-shard [`coordinator::DocFeed`]s), runs
//!   the decaying sweep schedule of an [`pipeline::OnlinePolicy`]
//!   (`ρ_t = (τ+t)^{−κ}`, the online-learning step-weight analogue),
//!   checkpoints on a cadence, and hot-reloads a
//!   [`serve::ReplicaSet`] over each checkpoint generation under
//!   continuous query load — emitting a [`pipeline::PipelineReport`]
//!   time series of ingest rate, serving-generation freshness lag, and
//!   held-out perplexity.
//! * **Layer 6 ([`net`])** — the wire front-end: a length-prefixed
//!   framed protocol ([`net::proto`]: HELLO/INFER/STATS/PING, versioned
//!   header, explicit error frames) served by a **thread-per-core
//!   reactor** ([`net::WireServer`]): one accept thread round-robins
//!   nonblocking sockets over N reactors, each owning its connections
//!   and feeding decoded INFERs into its own
//!   [`serve::InferenceService`] micro-batch worker over the shared
//!   hot-reloadable backend. Request seeds travel in-band, so wire
//!   answers are bit-identical to in-process answers at the same
//!   service seed; [`net::loadgen`] drives C concurrent connections
//!   (open- or closed-loop) and reports qps/p50/p99/max.
//! * **Layer 5 ([`coordinator`])** — the training *session*: the paper's
//!   long-lived production job as an API. A
//!   [`coordinator::TrainSession`] builds the topology once — corpus via
//!   a pluggable [`corpus::CorpusSource`] (synthetic generator or a
//!   docword file on disk), shards, transport, server group, eval engine
//!   — and drives it in **segments**
//!   ([`coordinator::TrainSession::run_for`] /
//!   [`run_to`](coordinator::TrainSession::run_to) →
//!   [`coordinator::SegmentReport`]) while per-iteration metrics stream
//!   through a [`coordinator::TrainObserver`].
//!   [`checkpoint`](coordinator::TrainSession::checkpoint) snapshots the
//!   *entire cluster* (acknowledged server-slot stores, client states,
//!   session meta) into a directory that is both a
//!   [`resume`](coordinator::TrainSession::resume) target — continuing
//!   in a fresh process under the **same `run_id`**, so the serving
//!   layer's same-run merge check accepts the continuation's snapshots —
//!   and a valid `serve --snapshot` input. The segment control loop
//!   carries the paper's operational story: progress scheduling,
//!   straggler kills, failure injection, heartbeat-driven client
//!   failover, the 90% rule (§5.4, §6). `Trainer::run` remains as a
//!   one-segment wrapper.
//! * **Chaos tier ([`chaos`])** — elastic membership + fault drills over
//!   a *live* cluster: a seeded [`chaos::ChaosPlan`] kills workers,
//!   kills server slots (freeze → snapshot restore → thaw), grows the
//!   server ring `N → N+1` with drain-and-handoff
//!   ([`ps::server::Elastic::grow`]), resizes the serving
//!   [`serve::ReplicaSet`] between generations, and spikes the
//!   simulated transport — while a [`chaos::ChaosHarness`] streams
//!   queries and training continues, reporting a
//!   [`chaos::ChaosReport`] (faults injected, queries dropped,
//!   iterations lost, post-chaos perplexity).
//! * **Layer 4 ([`serve`])** — the family-generic, hot-reloadable,
//!   **model-parallel** inference service: the [`serve::ServingFamily`]
//!   trait abstracts "frozen sufficient statistics + fold-in posterior"
//!   per model family (LDA `n_tw`, PDP customer+table counts with the
//!   PYP predictive, HDP `n_tw` + root sticks), all built from the
//!   self-describing v3 server snapshots. Per-word alias tables are
//!   cached lazily under an LRU byte budget; a generation-numbered
//!   [`serve::ServingHandle`] swaps newer snapshots in atomically
//!   without dropping the in-flight micro-batch queue (pre-warming the
//!   incoming alias cache from the outgoing resident set), and every
//!   answer reports the generation that served it. At scale, a
//!   [`serve::ReplicaSet`] partitions the vocabulary over N replicas
//!   with the same consistent-hash ring training shards by
//!   ([`ps::ring`]): each replica holds only its words' rows plus the
//!   global normalizers and its own lock-free-to-neighbours alias
//!   cache, the [`serve::QueryRouter`] scatters a document's words to
//!   their owners and gathers the `prior_t·φ(w,t)` proposals, and the
//!   routed posterior is bit-identical to the single-replica posterior
//!   at a fixed seed. Reloads build all N next-generation slices in one
//!   shared scan of the decoded stores, prepare per replica, and commit
//!   set-wide.
//! * **Layer 3 ([`ps`] + [`sampler`])** — the parameter server and the
//!   sparse train-side hot path: node topology, simulated cluster
//!   transport, server group / scheduler / server manager, samplers,
//!   projection. Model memory is fully sparse: every word-topic row —
//!   replica, delta record, and server slot store alike — is a
//!   [`sampler::counts::HybridRow`] that climbs a three-stage ladder as
//!   it fills (sorted short list up to 8 cells → open-addressing hash →
//!   dense `i32[K]` only past `~K/4` occupancy), so resident bytes track
//!   `O(nnz)` instead of `O(K)` at K ≥ 10k while `inc`/`get` stay `O(1)`.
//!   [`sampler::counts::CountMatrix`]
//!   keeps an `O(k_w)` delta log and an incremental `1/(n_t+β̄)`
//!   normalizer cache, rows travel as
//!   [`sampler::counts::RowData`] (sparse below the density break-even,
//!   dense above; [`ps::msg`] charges real encoded sizes — hybrid rows
//!   encode to bit-identical wire bytes as the dense era), and the
//!   per-word alias proposals rebuild in place over pooled buffers
//!   ([`sampler::alias::AliasBuilder`]) — so a warm sampling sweep costs
//!   `O(topics actually touched)` per token and allocates nothing.
//!   [`ps::filter::Filter`] can additionally rank individual
//!   `(word, topic)` cells by `|δ|` (`cell_level`) on top of the paper's
//!   row-magnitude priority. Durability is incremental: each server
//!   slot's live store doubles as an LSM *memtable*, and a
//!   [`ps::snapshot::SegmentLog`] seals checkpoint deltas into
//!   immutable, footer-checksummed segment files under an atomically
//!   renamed manifest (v4), compacting at seal time — a torn checkpoint
//!   leaves only unreferenced (inert) files, never a half-read store.
//! * **Layer 2 (python/compile, build-time)** — JAX dense-math graphs
//!   (φ normalization, dense alias proposals, the test-perplexity
//!   estimator), AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels, build-time)** — Pallas kernels for
//!   the L2 hot spots, verified against a pure-jnp oracle.
//! * **Runtime bridge** — [`runtime`] loads `artifacts/*.hlo.txt` through
//!   the PJRT C API (`xla` crate) so the evaluation path runs the compiled
//!   kernels with **no python at training time**.
//!
//! Training hands off to serving through [`ps::snapshot`]: server
//! snapshots carry the hyperparameters (model, K, α, β), the ring
//! geometry, and — for the table-constrained families — the
//! [`ps::snapshot::TableHyper`] section (PDP `a`/`b`/`γ`, HDP `b₀`/`b₁`),
//! so a snapshot directory is all the inference server needs for any
//! family; v1/v2/v3 files still decode. Session checkpoints write the
//! **v4 segmented format**: each slot file is an LSM-style manifest
//! naming immutable, checksummed segment files ([`ps::snapshot::SegmentLog`]
//! seals only the rows dirtied since the last seal and carries the rest
//! forward by hardlink), so a steady-state `checkpoint(dir)` costs
//! O(rows changed) instead of O(model). On the serving side the same
//! structure powers **generation-diff reloads**: a `--watch` reload of a
//! v4 directory replays only the segments newer than the resident
//! generation ([`serve::ResidentStores`]) and is bit-identical to a full
//! decode, with [`serve::ReloadStats`] reporting which path ran.
//!
//! ## Quickstart
//!
//! One-shot (the legacy wrapper):
//!
//! ```no_run
//! use hplvm::config::TrainConfig;
//! use hplvm::coordinator::trainer::Trainer;
//!
//! let mut cfg = TrainConfig::small_lda();
//! cfg.iterations = 20;
//! let report = Trainer::new(cfg).run().expect("training failed");
//! println!("final perplexity: {:.1}", report.final_perplexity());
//! ```
//!
//! Session-based — train, checkpoint, keep training; resume later in a
//! fresh process under the same run id:
//!
//! ```no_run
//! use hplvm::config::TrainConfig;
//! use hplvm::coordinator::TrainSession;
//! use hplvm::corpus::SyntheticSource;
//! use std::path::Path;
//!
//! let cfg = TrainConfig::small_lda();
//! let source = SyntheticSource::new(cfg.corpus.clone());
//! let mut session = TrainSession::start(cfg, &source).expect("start");
//! let seg = session.run_for(10).expect("segment");
//! println!("perplexity after 10: {:.1}", seg.report.final_perplexity());
//! session.checkpoint(Path::new("ckpt")).expect("checkpoint");
//! session.run_for(10).expect("segment 2");
//! let report = session.finish().expect("finish");
//! println!("final: {:.1}", report.final_perplexity());
//!
//! // …days later, possibly on another machine:
//! let mut resumed = TrainSession::resume(Path::new("ckpt")).expect("resume");
//! resumed.run_for(20).expect("more training, same run_id");
//! ```
//!
//! ## Test layout
//!
//! Unit tests live beside the code; the scenario tiers live in
//! `rust/tests/`: `integration_cluster.rs` (end-to-end training),
//! `property_invariants.rs` (samplers), `serving_inference.rs` /
//! `serving_router.rs` (serving), `wire_server.rs` (the network
//! front-end: loadgen vs in-process parity, hot reload under load,
//! malformed-frame robustness), `session_resume.rs`
//! (checkpoint/resume), `snapshot_compat.rs` /
//! `snapshot_incremental.rs` (the on-disk format matrix and the v4
//! segment store: byte-proportional re-checkpoints, torn-checkpoint
//! recovery, diff-reload bit-identity), `online_pipeline.rs` (the
//! streaming train-while-serve loop end-to-end: bounded chunk buffer,
//! live reloads under query load, online-vs-offline perplexity
//! parity), and `chaos_scenarios.rs`
//! (elastic membership + fault drills). Every chaos scenario derives
//! its fault schedule from one seed; set the `CHAOS_SEED` environment
//! variable to replay a failing CI seed locally with one command:
//!
//! ```text
//! CHAOS_SEED=12345 cargo test --release --test chaos_scenarios
//! ```

pub mod bench;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod net;
pub mod pipeline;
pub mod projection;
pub mod ps;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
