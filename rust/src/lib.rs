//! # hplvm — High Performance Latent Variable Models
//!
//! A reproduction of *"High Performance Latent Variable Models"*
//! (Li, Ahmed, Li, Josifovski, Smola — 2015): a third-generation
//! **parameter server** carrying the sufficient statistics of topic models
//! (LDA, Poisson-Dirichlet-Process, Hierarchical-Dirichlet-Process),
//! combined with the **Metropolis-Hastings-Walker (alias) sampler** for
//! amortized `O(k_d)` collapsed Gibbs sampling, **eventual consistency**
//! with communication filters, and **parameter projection** to repair the
//! constraint violations relaxed consistency causes.
//!
//! ## Layering
//!
//! * **Layer 4 ([`serve`])** — the snapshot-backed inference service:
//!   loads the server snapshots a training run wrote, freezes the
//!   word–topic statistics, builds per-word alias tables lazily under an
//!   LRU byte budget, and answers fold-in queries
//!   (`doc → topic mixture`) through a micro-batching worker pool.
//! * **Layer 3 (this crate)** — the distributed coordinator: node topology,
//!   simulated cluster transport, server group / client groups / scheduler /
//!   server manager, samplers, projection, metrics, CLI.
//! * **Layer 2 (python/compile, build-time)** — JAX dense-math graphs
//!   (φ normalization, dense alias proposals, the test-perplexity
//!   estimator), AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels, build-time)** — Pallas kernels for
//!   the L2 hot spots, verified against a pure-jnp oracle.
//! * **Runtime bridge** — [`runtime`] loads `artifacts/*.hlo.txt` through
//!   the PJRT C API (`xla` crate) so the evaluation path runs the compiled
//!   kernels with **no python at training time**.
//!
//! Training hands off to serving through [`ps::snapshot`]: v2 server
//! snapshots carry the hyperparameters (model, K, α, β) and ring
//! geometry, so a snapshot directory is all the inference server needs.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hplvm::config::TrainConfig;
//! use hplvm::coordinator::trainer::Trainer;
//!
//! let mut cfg = TrainConfig::small_lda();
//! cfg.iterations = 20;
//! let report = Trainer::new(cfg).run().expect("training failed");
//! println!("final perplexity: {:.1}", report.final_perplexity());
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod projection;
pub mod ps;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
