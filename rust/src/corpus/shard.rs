//! Corpus sharding: the unit of work assigned to a client node.
//!
//! The paper shards the corpus so each shard has ~50M tokens / ~200k docs
//! and assigns one client machine per shard (§6 Environment). Here a
//! [`ShardSet`] partitions a synthetic corpus the same way (round-robin by
//! document, so shard token counts are balanced) and the scheduler hands
//! shards to clients — including *re*-assignment when a client is killed.

use super::doc::{Corpus, Document};

/// A shard: a contiguous slice of the corpus owned by one client at a time.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Stable shard id (0-based).
    pub id: usize,
    /// Documents in this shard.
    pub docs: Vec<Document>,
    /// Token count (cached).
    pub tokens: usize,
}

impl Shard {
    fn new(id: usize, docs: Vec<Document>) -> Self {
        let tokens = docs.iter().map(|d| d.len()).sum();
        Shard { id, docs, tokens }
    }
}

/// The full partition of a training corpus into shards.
#[derive(Clone, Debug)]
pub struct ShardSet {
    /// All shards.
    pub shards: Vec<Shard>,
    /// Vocabulary size (shared).
    pub vocab_size: usize,
}

impl ShardSet {
    /// Round-robin partition of `corpus` into `n_shards` balanced shards.
    pub fn partition(corpus: &Corpus, n_shards: usize) -> ShardSet {
        assert!(n_shards > 0, "need at least one shard");
        let mut buckets: Vec<Vec<Document>> = (0..n_shards).map(|_| Vec::new()).collect();
        for (i, d) in corpus.docs.iter().enumerate() {
            buckets[i % n_shards].push(d.clone());
        }
        let shards = buckets
            .into_iter()
            .enumerate()
            .map(|(id, docs)| Shard::new(id, docs))
            .collect();
        ShardSet {
            shards,
            vocab_size: corpus.vocab_size,
        }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True iff no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Total tokens across shards.
    pub fn total_tokens(&self) -> usize {
        self.shards.iter().map(|s| s.tokens).sum()
    }

    /// Imbalance ratio: max shard tokens / mean shard tokens.
    pub fn imbalance(&self) -> f64 {
        let mean = self.total_tokens() as f64 / self.len() as f64;
        let max = self.shards.iter().map(|s| s.tokens).max().unwrap_or(0) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::CorpusConfig;

    #[test]
    fn partition_preserves_all_tokens() {
        let (c, _) = CorpusConfig {
            n_docs: 331,
            vocab_size: 500,
            ..Default::default()
        }
        .generate();
        let total = c.total_tokens();
        let s = ShardSet::partition(&c, 7);
        assert_eq!(s.len(), 7);
        assert_eq!(s.total_tokens(), total);
        assert_eq!(
            s.shards.iter().map(|sh| sh.docs.len()).sum::<usize>(),
            331
        );
    }

    #[test]
    fn shards_are_balanced() {
        let (c, _) = CorpusConfig {
            n_docs: 1000,
            vocab_size: 500,
            doc_len_mean: 32.0,
            ..Default::default()
        }
        .generate();
        let s = ShardSet::partition(&c, 8);
        assert!(s.imbalance() < 1.15, "imbalance {}", s.imbalance());
    }

    #[test]
    fn single_shard_is_whole_corpus() {
        let (c, _) = CorpusConfig {
            n_docs: 10,
            vocab_size: 100,
            ..Default::default()
        }
        .generate();
        let s = ShardSet::partition(&c, 1);
        assert_eq!(s.shards[0].docs.len(), 10);
    }
}
