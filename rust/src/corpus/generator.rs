//! Ground-truth generative corpus synthesis.
//!
//! Two modes, matching the two model families the paper evaluates:
//!
//! * [`GenerativeModel::Lda`] — θ_d ~ Dir(α), φ_t ~ Dir(β·ψ₀·V) (the Zipf
//!   base folded into an asymmetric Dirichlet so the corpus-wide marginal is
//!   power-law), z ~ θ_d, w ~ φ_z.
//! * [`GenerativeModel::Pyp`] — per-topic Pitman-Yor predictive rule (a
//!   Chinese-restaurant process with discount `a`, concentration `b`, base
//!   ψ₀ = Zipf): reproduces the heavier-than-Dirichlet power-law tail that
//!   motivates the PDP topic model (§2.2).

use super::doc::{Corpus, Document};
use super::vocab::Vocabulary;
use crate::util::rng::Rng;

/// Which generative process synthesizes the corpus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenerativeModel {
    /// Dirichlet-multinomial topics (classic LDA ground truth).
    Lda,
    /// Pitman-Yor per-topic language models (power-law ground truth).
    Pyp,
}

/// Knobs of the synthetic corpus.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Number of documents to generate.
    pub n_docs: usize,
    /// Vocabulary size (token-types).
    pub vocab_size: usize,
    /// Ground-truth number of topics.
    pub n_topics: usize,
    /// Document-topic Dirichlet concentration (symmetric).
    pub alpha: f64,
    /// Topic-word Dirichlet concentration (LDA mode).
    pub beta: f64,
    /// Zipf exponent of the vocabulary base measure.
    pub zipf_s: f64,
    /// Mean document length (Poisson).
    pub doc_len_mean: f64,
    /// PYP discount `a` (Pyp mode).
    pub pyp_discount: f64,
    /// PYP concentration `b` (Pyp mode).
    pub pyp_concentration: f64,
    /// Generative process.
    pub model: GenerativeModel,
    /// RNG seed.
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_docs: 2_000,
            vocab_size: 10_000,
            n_topics: 20,
            alpha: 0.1,
            beta: 0.01,
            zipf_s: 1.07,
            doc_len_mean: 64.0,
            pyp_discount: 0.1,
            pyp_concentration: 10.0,
            model: GenerativeModel::Lda,
            seed: 42,
        }
    }
}

impl CorpusConfig {
    /// Generate the corpus (and its vocabulary).
    pub fn generate(&self) -> (Corpus, Vocabulary) {
        let vocab = Vocabulary::new(self.vocab_size, self.zipf_s);
        let mut rng = Rng::new(self.seed);
        let corpus = match self.model {
            GenerativeModel::Lda => self.generate_lda(&vocab, &mut rng),
            GenerativeModel::Pyp => self.generate_pyp(&vocab, &mut rng),
        };
        (corpus, vocab)
    }

    fn topic_mixture(&self, rng: &mut Rng) -> Vec<f64> {
        rng.dirichlet(&vec![self.alpha; self.n_topics])
    }

    fn generate_lda(&self, vocab: &Vocabulary, rng: &mut Rng) -> Corpus {
        // φ_t ~ Dir(β·ψ₀·V): asymmetric prior proportional to the Zipf base,
        // scaled so the total concentration is β·V (same as symmetric β).
        let v = self.vocab_size as f64;
        let base_alpha: Vec<f64> = (0..self.vocab_size as u32)
            .map(|w| (self.beta * v * vocab.base_prob(w)).max(1e-4))
            .collect();
        let topics: Vec<crate::sampler::alias::AliasTable> = (0..self.n_topics)
            .map(|_| {
                let phi = rng.dirichlet(&base_alpha);
                crate::sampler::alias::AliasTable::build(&phi)
            })
            .collect();

        let mut docs = Vec::with_capacity(self.n_docs);
        for _ in 0..self.n_docs {
            let theta = self.topic_mixture(rng);
            let theta_alias = crate::sampler::alias::AliasTable::build(&theta);
            let len = rng.poisson(self.doc_len_mean).max(1);
            let mut tokens = Vec::with_capacity(len);
            for _ in 0..len {
                let z = theta_alias.sample(rng);
                let w = topics[z].sample(rng) as u32;
                tokens.push(w);
            }
            docs.push(Document { tokens });
        }
        Corpus {
            docs,
            vocab_size: self.vocab_size,
            true_topics: self.n_topics,
        }
    }

    fn generate_pyp(&self, vocab: &Vocabulary, rng: &mut Rng) -> Corpus {
        // Per-topic Chinese-restaurant state: customers per dish (m_tw)
        // and tables per dish (s_tw), grown lazily.
        struct Crp {
            m_w: std::collections::HashMap<u32, (u64, u64)>, // word -> (customers, tables)
            m_total: u64,
            s_total: u64,
        }
        impl Crp {
            fn draw(
                &mut self,
                a: f64,
                b: f64,
                vocab: &Vocabulary,
                rng: &mut Rng,
            ) -> u32 {
                let new_table_w = b + a * self.s_total as f64;
                let denom = b + self.m_total as f64;
                if rng.f64() * denom < new_table_w {
                    // New table: dish from the Zipf base measure.
                    let w = vocab.base.sample(rng) as u32;
                    let e = self.m_w.entry(w).or_insert((0, 0));
                    e.0 += 1;
                    e.1 += 1;
                    self.m_total += 1;
                    self.s_total += 1;
                    w
                } else {
                    // Sit at an existing table ∝ (m_w − a·s_w).
                    let target = rng.f64() * (self.m_total as f64 - a * self.s_total as f64);
                    let mut acc = 0.0;
                    let mut chosen = None;
                    for (&w, &(m, s)) in self.m_w.iter() {
                        acc += m as f64 - a * s as f64;
                        if acc >= target {
                            chosen = Some(w);
                            break;
                        }
                    }
                    let w = chosen.unwrap_or_else(|| *self.m_w.keys().next().unwrap());
                    self.m_w.get_mut(&w).unwrap().0 += 1;
                    self.m_total += 1;
                    w
                }
            }
        }

        let mut crps: Vec<Crp> = (0..self.n_topics)
            .map(|_| Crp {
                m_w: std::collections::HashMap::new(),
                m_total: 0,
                s_total: 0,
            })
            .collect();

        let mut docs = Vec::with_capacity(self.n_docs);
        for _ in 0..self.n_docs {
            let theta = self.topic_mixture(rng);
            let theta_alias = crate::sampler::alias::AliasTable::build(&theta);
            let len = rng.poisson(self.doc_len_mean).max(1);
            let mut tokens = Vec::with_capacity(len);
            for _ in 0..len {
                let z = theta_alias.sample(rng);
                let w = crps[z].draw(self.pyp_discount, self.pyp_concentration, vocab, rng);
                tokens.push(w);
            }
            docs.push(Document { tokens });
        }
        Corpus {
            docs,
            vocab_size: self.vocab_size,
            true_topics: self.n_topics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lda_corpus_shape() {
        let cfg = CorpusConfig {
            n_docs: 200,
            vocab_size: 500,
            n_topics: 5,
            doc_len_mean: 30.0,
            ..Default::default()
        };
        let (c, v) = cfg.generate();
        assert_eq!(c.docs.len(), 200);
        assert_eq!(v.len(), 500);
        assert!(c.total_tokens() > 200 * 15);
        assert!(c.docs.iter().all(|d| !d.is_empty()));
        assert!(c
            .docs
            .iter()
            .flat_map(|d| d.tokens.iter())
            .all(|&w| (w as usize) < 500));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = CorpusConfig {
            n_docs: 50,
            vocab_size: 200,
            ..Default::default()
        };
        let (a, _) = cfg.generate();
        let (b, _) = cfg.generate();
        assert_eq!(a.docs.len(), b.docs.len());
        for (da, db) in a.docs.iter().zip(&b.docs) {
            assert_eq!(da.tokens, db.tokens);
        }
    }

    #[test]
    fn pyp_has_heavier_tail_than_uniform() {
        let cfg = CorpusConfig {
            n_docs: 400,
            vocab_size: 2000,
            n_topics: 5,
            doc_len_mean: 50.0,
            model: GenerativeModel::Pyp,
            ..Default::default()
        };
        let (c, _) = cfg.generate();
        let mut freq = c.word_frequencies();
        freq.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = freq.iter().sum();
        // Power law: the top 1% of types must carry a large share of mass.
        let head: u64 = freq[..20].iter().sum();
        assert!(
            head as f64 > 0.15 * total as f64,
            "head share {}",
            head as f64 / total as f64
        );
    }

    #[test]
    fn doc_topic_sparsity_holds() {
        // k_d (topics per doc) must stay well below the truth count for
        // small alpha — the property the sparse term of eq. (4) exploits.
        let cfg = CorpusConfig {
            n_docs: 100,
            vocab_size: 1000,
            n_topics: 50,
            alpha: 0.05,
            doc_len_mean: 40.0,
            ..Default::default()
        };
        let (c, _) = cfg.generate();
        // Proxy: distinct words per doc ≪ doc length would not test topics;
        // instead verify doc length distribution is sane and all docs
        // non-empty (topic sparsity itself is verified by sampler tests).
        assert!(c.docs.iter().all(|d| d.len() >= 1));
        let mean_len: f64 =
            c.docs.iter().map(|d| d.len() as f64).sum::<f64>() / c.docs.len() as f64;
        assert!((mean_len - 40.0).abs() < 5.0, "mean len {mean_len}");
    }
}
