//! Pluggable corpus acquisition: where a training session's documents
//! come from.
//!
//! The paper trains on a real production collection fed by a persistent
//! pipeline; our trainer historically synthesized its corpus internally,
//! which made real corpora a second-class citizen. A [`CorpusSource`]
//! moves acquisition behind a trait the
//! [`TrainSession`](crate::coordinator::TrainSession) consumes:
//!
//! * [`SyntheticSource`] wraps the existing ground-truth generator
//!   ([`CorpusConfig::generate`]) unchanged — the default, and what
//!   `Trainer::run` uses.
//! * [`FileSource`] loads a simple *docword* text format (the UCI
//!   bag-of-words layout) plus an optional one-token-per-line vocabulary
//!   file, so a real corpus on disk is a first-class training scenario.
//!
//! The docword format, chosen for hand-editability and `wc`-greppability:
//!
//! ```text
//! D            # number of documents
//! W            # vocabulary size (word ids are 1..=W in the body)
//! NNZ          # number of (doc, word) pairs that follow
//! d w c        # document d contains word w c times (1-based d and w)
//! ```
//!
//! [`write_docword`] emits this layout from any [`Corpus`], giving a
//! lossless* round trip (*token multiset per document; bag-of-words
//! models never observe token order).

use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};

use super::doc::{Corpus, Document};
use super::generator::CorpusConfig;
use crate::Result;

/// A named, hard docword parse failure. Every variant carries the file
/// path, and every body-level variant the 1-based line number, so a bad
/// multi-gigabyte corpus file is diagnosable without bisecting it by
/// hand. Produced by [`read_docword`], [`FileSource::load`], and the
/// streaming reader ([`StreamingSource`](super::stream::StreamingSource)),
/// which all parse through the same helpers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DocwordError {
    /// The file ended before the three-line `D / W / NNZ` header did.
    TruncatedHeader { path: PathBuf, field: &'static str },
    /// A header line that is not a positive integer.
    BadHeader {
        path: PathBuf,
        line: usize,
        field: &'static str,
        text: String,
    },
    /// The header declares zero documents or an empty vocabulary.
    EmptyDeclaration { path: PathBuf, what: &'static str },
    /// A body line that is not three whitespace-separated integers.
    BadTriple {
        path: PathBuf,
        line: usize,
        text: String,
    },
    /// A doc id outside `1..=D`.
    DocIdRange {
        path: PathBuf,
        line: usize,
        doc: usize,
        n_docs: usize,
    },
    /// A word id outside `1..=W`.
    WordIdRange {
        path: PathBuf,
        line: usize,
        word: usize,
        vocab: usize,
    },
    /// A doc id smaller than the one before it. The UCI layout sorts
    /// triples by document; monotonicity is also what lets the streaming
    /// reader emit a document the moment its id stops appearing.
    NonMonotonicDoc {
        path: PathBuf,
        line: usize,
        doc: usize,
        prev: usize,
    },
    /// The body carried a different number of triples than `NNZ` declared.
    NnzMismatch {
        path: PathBuf,
        declared: usize,
        seen: usize,
    },
    /// Every declared document was empty.
    NoTokens { path: PathBuf },
    /// An underlying I/O failure (open or read).
    Io {
        path: PathBuf,
        line: Option<usize>,
        msg: String,
    },
}

impl std::fmt::Display for DocwordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DocwordError::TruncatedHeader { path, field } => write!(
                f,
                "docword file {} truncated before the {field} header",
                path.display()
            ),
            DocwordError::BadHeader {
                path,
                line,
                field,
                text,
            } => write!(
                f,
                "bad {field} header {text:?} at {}:{line}",
                path.display()
            ),
            DocwordError::EmptyDeclaration { path, what } => {
                write!(f, "docword file {} declares {what}", path.display())
            }
            DocwordError::BadTriple { path, line, text } => {
                write!(f, "bad docword triple {text:?} at {}:{line}", path.display())
            }
            DocwordError::DocIdRange {
                path,
                line,
                doc,
                n_docs,
            } => write!(
                f,
                "doc id {doc} outside 1..={n_docs} at {}:{line}",
                path.display()
            ),
            DocwordError::WordIdRange {
                path,
                line,
                word,
                vocab,
            } => write!(
                f,
                "word id {word} outside 1..={vocab} at {}:{line}",
                path.display()
            ),
            DocwordError::NonMonotonicDoc {
                path,
                line,
                doc,
                prev,
            } => write!(
                f,
                "non-monotonic doc id {doc} after {prev} at {}:{line} \
                 (docword triples must be sorted by document)",
                path.display()
            ),
            DocwordError::NnzMismatch {
                path,
                declared,
                seen,
            } => write!(
                f,
                "docword file {} declares {declared} entries but carries {seen}",
                path.display()
            ),
            DocwordError::NoTokens { path } => {
                write!(f, "docword file {} contains no tokens", path.display())
            }
            DocwordError::Io { path, line, msg } => match line {
                Some(line) => {
                    write!(f, "read error at {}:{line}: {msg}", path.display())
                }
                None => write!(f, "cannot read docword file {}: {msg}", path.display()),
            },
        }
    }
}

impl std::error::Error for DocwordError {}

/// The three-line `D / W / NNZ` docword header.
#[derive(Clone, Copy, Debug)]
pub struct DocwordHeader {
    /// Declared document count (`D`).
    pub n_docs: usize,
    /// Declared vocabulary size (`W`; word ids are `1..=W`).
    pub vocab: usize,
    /// Declared triple count (`NNZ`).
    pub nnz: usize,
}

/// Parse the header from an already-opened line iterator, skipping
/// comments and blank lines. Shared by the whole-file and streaming
/// readers so both fail with the same named errors.
pub(crate) fn parse_header(
    path: &Path,
    lines: &mut std::iter::Enumerate<std::io::Lines<std::io::BufReader<std::fs::File>>>,
) -> Result<DocwordHeader> {
    let mut field = |name: &'static str| -> Result<usize> {
        loop {
            let (i, line) = lines.next().ok_or_else(|| DocwordError::TruncatedHeader {
                path: path.to_path_buf(),
                field: name,
            })?;
            let line = line.map_err(|e| DocwordError::Io {
                path: path.to_path_buf(),
                line: Some(i + 1),
                msg: e.to_string(),
            })?;
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            return line.parse().map_err(|_| {
                DocwordError::BadHeader {
                    path: path.to_path_buf(),
                    line: i + 1,
                    field: name,
                    text: line.to_string(),
                }
                .into()
            });
        }
    };
    let n_docs = field("D")?;
    let vocab = field("W")?;
    let nnz = field("NNZ")?;
    if n_docs == 0 {
        return Err(DocwordError::EmptyDeclaration {
            path: path.to_path_buf(),
            what: "zero documents",
        }
        .into());
    }
    if vocab == 0 {
        return Err(DocwordError::EmptyDeclaration {
            path: path.to_path_buf(),
            what: "an empty vocabulary",
        }
        .into());
    }
    Ok(DocwordHeader { n_docs, vocab, nnz })
}

/// Parse one body line into a `(doc, word, count)` triple — `Ok(None)`
/// for comments and blank lines — and validate ids against the header
/// and the previous doc id (monotonicity). 1-based ids, as in the file.
pub(crate) fn parse_triple(
    path: &Path,
    lineno: usize,
    raw: &str,
    header: &DocwordHeader,
    last_doc: usize,
) -> Result<Option<(usize, usize, usize)>> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let bad = || DocwordError::BadTriple {
        path: path.to_path_buf(),
        line: lineno,
        text: line.to_string(),
    };
    let mut it = line.split_whitespace();
    let d: usize = it.next().and_then(|s| s.parse().ok()).ok_or_else(bad)?;
    let w: usize = it.next().and_then(|s| s.parse().ok()).ok_or_else(bad)?;
    let c: usize = it.next().and_then(|s| s.parse().ok()).ok_or_else(bad)?;
    if it.next().is_some() {
        return Err(bad().into());
    }
    if !(1..=header.n_docs).contains(&d) {
        return Err(DocwordError::DocIdRange {
            path: path.to_path_buf(),
            line: lineno,
            doc: d,
            n_docs: header.n_docs,
        }
        .into());
    }
    if !(1..=header.vocab).contains(&w) {
        return Err(DocwordError::WordIdRange {
            path: path.to_path_buf(),
            line: lineno,
            word: w,
            vocab: header.vocab,
        }
        .into());
    }
    if d < last_doc {
        return Err(DocwordError::NonMonotonicDoc {
            path: path.to_path_buf(),
            line: lineno,
            doc: d,
            prev: last_doc,
        }
        .into());
    }
    Ok(Some((d, w, c)))
}

/// Where a training session's corpus comes from.
pub trait CorpusSource {
    /// Load (or synthesize) the corpus. Called once at session start; a
    /// resumed session calls it again and must observe the identical
    /// corpus (the checkpoint's topic assignments index into it).
    fn load(&self) -> Result<Corpus>;

    /// One-line human description for logs and reports.
    fn describe(&self) -> String;

    /// The backing docword file, when there is one. Recorded into the
    /// session checkpoint so [`TrainSession::resume`] can reload the same
    /// corpus without re-specifying the source.
    ///
    /// [`TrainSession::resume`]: crate::coordinator::TrainSession::resume
    fn file(&self) -> Option<PathBuf> {
        None
    }

    /// The companion vocabulary file, when there is one — checkpointed
    /// next to [`file`](Self::file) so a resumed run keeps the same
    /// (possibly widened) effective vocabulary.
    fn vocab_file(&self) -> Option<PathBuf> {
        None
    }
}

/// The ground-truth synthetic generator behind the [`CorpusSource`] trait.
#[derive(Clone, Debug)]
pub struct SyntheticSource {
    /// Generator knobs (deterministic given `cfg.seed`).
    pub cfg: CorpusConfig,
}

impl SyntheticSource {
    /// Wrap a generator configuration.
    pub fn new(cfg: CorpusConfig) -> SyntheticSource {
        SyntheticSource { cfg }
    }
}

impl CorpusSource for SyntheticSource {
    fn load(&self) -> Result<Corpus> {
        let (corpus, _vocab) = self.cfg.generate();
        Ok(corpus)
    }

    fn describe(&self) -> String {
        format!(
            "synthetic {:?} corpus ({} docs, V={}, seed {})",
            self.cfg.model, self.cfg.n_docs, self.cfg.vocab_size, self.cfg.seed
        )
    }
}

/// A docword file on disk (plus an optional vocabulary file).
#[derive(Clone, Debug)]
pub struct FileSource {
    /// Path to the docword file.
    pub docword: PathBuf,
    /// Optional vocabulary file (one surface form per line); only its
    /// line count is consulted, to widen the vocabulary beyond the
    /// docword header's `W` when the two disagree.
    pub vocab: Option<PathBuf>,
}

impl FileSource {
    /// A source reading `docword` (no vocabulary file).
    pub fn new(docword: impl Into<PathBuf>) -> FileSource {
        FileSource {
            docword: docword.into(),
            vocab: None,
        }
    }

    /// Attach a vocabulary file.
    pub fn with_vocab(mut self, vocab: impl Into<PathBuf>) -> FileSource {
        self.vocab = Some(vocab.into());
        self
    }
}

impl CorpusSource for FileSource {
    fn load(&self) -> Result<Corpus> {
        let mut corpus = read_docword(&self.docword)?;
        if let Some(vocab) = &self.vocab {
            let lines = std::io::BufReader::new(std::fs::File::open(vocab).map_err(|e| {
                anyhow::anyhow!("cannot read vocab file {}: {e}", vocab.display())
            })?)
            .lines()
            .count();
            corpus.vocab_size = corpus.vocab_size.max(lines);
        }
        Ok(corpus)
    }

    fn describe(&self) -> String {
        format!("docword file {}", self.docword.display())
    }

    fn file(&self) -> Option<PathBuf> {
        Some(self.docword.clone())
    }

    fn vocab_file(&self) -> Option<PathBuf> {
        self.vocab.clone()
    }
}

/// Read a docword file into a [`Corpus`]. Word ids are 1-based in the
/// file and 0-based in the corpus; a word's `c` occurrences expand into
/// `c` tokens (bag-of-words — the samplers never observe token order).
/// Malformed files fail with a named [`DocwordError`] carrying the path
/// and line number.
pub fn read_docword(path: &Path) -> Result<Corpus> {
    let file = std::fs::File::open(path).map_err(|e| DocwordError::Io {
        path: path.to_path_buf(),
        line: None,
        msg: e.to_string(),
    })?;
    let mut lines = std::io::BufReader::new(file).lines().enumerate();
    let header = parse_header(path, &mut lines)?;

    let mut docs: Vec<Document> = (0..header.n_docs).map(|_| Document::default()).collect();
    let mut seen = 0usize;
    let mut last_doc = 0usize;
    for (i, line) in lines {
        let line = line.map_err(|e| DocwordError::Io {
            path: path.to_path_buf(),
            line: Some(i + 1),
            msg: e.to_string(),
        })?;
        let Some((d, w, c)) = parse_triple(path, i + 1, &line, &header, last_doc)? else {
            continue;
        };
        last_doc = d;
        let tokens = &mut docs[d - 1].tokens;
        for _ in 0..c {
            tokens.push((w - 1) as u32);
        }
        seen += 1;
    }
    if seen != header.nnz {
        return Err(DocwordError::NnzMismatch {
            path: path.to_path_buf(),
            declared: header.nnz,
            seen,
        }
        .into());
    }
    // Empty documents contribute nothing and would break the Gibbs loop's
    // assumption that every doc has at least one token when evaluating;
    // drop them (the paper's pipeline filters them upstream too).
    docs.retain(|d| !d.is_empty());
    if docs.is_empty() {
        return Err(DocwordError::NoTokens {
            path: path.to_path_buf(),
        }
        .into());
    }
    Ok(Corpus {
        docs,
        vocab_size: header.vocab,
        true_topics: 0,
    })
}

/// Write a [`Corpus`] in the docword format (1-based ids, one
/// `(doc, word, count)` triple per distinct word per document, words
/// ascending within a document). Atomic (temp + rename), like snapshots.
pub fn write_docword(path: &Path, corpus: &Corpus) -> Result<()> {
    let mut triples = 0usize;
    let mut body = String::new();
    let mut counts: Vec<u32> = vec![0; corpus.vocab_size];
    let mut touched: Vec<u32> = Vec::new();
    for (d, doc) in corpus.docs.iter().enumerate() {
        for &w in &doc.tokens {
            if counts[w as usize] == 0 {
                touched.push(w);
            }
            counts[w as usize] += 1;
        }
        touched.sort_unstable();
        for &w in &touched {
            body.push_str(&format!("{} {} {}\n", d + 1, w + 1, counts[w as usize]));
            counts[w as usize] = 0;
            triples += 1;
        }
        touched.clear();
    }
    let text = format!(
        "{}\n{}\n{}\n{}",
        corpus.docs.len(),
        corpus.vocab_size,
        triples,
        body
    );
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
        f.write_all(text.as_bytes())
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("cannot rename into {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_source_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Per-document word-count histogram (the bag the models observe).
    fn bags(c: &Corpus) -> Vec<Vec<(u32, u32)>> {
        c.docs
            .iter()
            .map(|d| {
                let mut m = std::collections::BTreeMap::new();
                for &w in &d.tokens {
                    *m.entry(w).or_insert(0u32) += 1;
                }
                m.into_iter().collect()
            })
            .collect()
    }

    #[test]
    fn docword_roundtrip_preserves_bags() {
        let (corpus, _) = CorpusConfig {
            n_docs: 60,
            vocab_size: 200,
            n_topics: 4,
            doc_len_mean: 12.0,
            seed: 5,
            ..Default::default()
        }
        .generate();
        let dir = tmpdir("roundtrip");
        let path = dir.join("docword.txt");
        write_docword(&path, &corpus).unwrap();
        let back = read_docword(&path).unwrap();
        assert_eq!(back.vocab_size, 200);
        assert_eq!(back.docs.len(), corpus.docs.len());
        assert_eq!(back.total_tokens(), corpus.total_tokens());
        assert_eq!(bags(&back), bags(&corpus), "bag-of-words must round-trip");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_source_loads_and_describes() {
        let (corpus, _) = CorpusConfig {
            n_docs: 20,
            vocab_size: 50,
            n_topics: 2,
            doc_len_mean: 8.0,
            seed: 9,
            ..Default::default()
        }
        .generate();
        let dir = tmpdir("filesource");
        let dw = dir.join("docword.txt");
        write_docword(&dw, &corpus).unwrap();
        // A vocab file longer than the docword header widens the corpus.
        let vpath = dir.join("vocab.txt");
        let words: String = (0..60).map(|w| format!("w{w:06}\n")).collect();
        std::fs::write(&vpath, words).unwrap();
        let src = FileSource::new(&dw).with_vocab(&vpath);
        let loaded = src.load().unwrap();
        assert_eq!(loaded.vocab_size, 60, "vocab file must widen V");
        assert_eq!(loaded.total_tokens(), corpus.total_tokens());
        assert!(src.describe().contains("docword"));
        assert_eq!(src.file().as_deref(), Some(dw.as_path()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthetic_source_matches_generator() {
        let cfg = CorpusConfig {
            n_docs: 15,
            vocab_size: 40,
            seed: 3,
            ..Default::default()
        };
        let direct = cfg.generate().0;
        let src = SyntheticSource::new(cfg);
        let via_source = src.load().unwrap();
        assert_eq!(bags(&via_source), bags(&direct));
        assert!(src.file().is_none());
        assert!(src.describe().contains("synthetic"));
    }

    #[test]
    fn read_docword_rejects_malformed_files() {
        let dir = tmpdir("malformed");
        let write = |name: &str, text: &str| {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            p
        };
        // Truncated header.
        assert!(read_docword(&write("t1", "3\n10\n")).is_err());
        // Word id out of range.
        assert!(read_docword(&write("t2", "1\n5\n1\n1 9 2\n")).is_err());
        // Doc id out of range.
        assert!(read_docword(&write("t3", "1\n5\n1\n4 2 2\n")).is_err());
        // NNZ mismatch.
        assert!(read_docword(&write("t4", "1\n5\n3\n1 2 2\n")).is_err());
        // Garbage triple.
        assert!(read_docword(&write("t5", "1\n5\n1\none two 3\n")).is_err());
        // Comments and blank lines are tolerated; 0-count rows are tokens=0.
        let ok = read_docword(&write(
            "t6",
            "# tiny corpus\n2\n5\n2\n\n1 2 3  # three of word 2\n2 5 1\n",
        ))
        .unwrap();
        assert_eq!(ok.docs.len(), 2);
        assert_eq!(ok.total_tokens(), 4);
        assert_eq!(ok.docs[0].tokens, vec![1, 1, 1]);
        assert_eq!(ok.docs[1].tokens, vec![4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite: parse failures are named errors carrying the file path
    /// and the 1-based line number — a bad line in a huge corpus file is
    /// diagnosable from the message alone.
    #[test]
    fn parse_errors_name_the_path_and_line() {
        let dir = tmpdir("named_errors");
        let write = |name: &str, text: &str| {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            p
        };
        let msg = |p: &PathBuf| format!("{}", read_docword(p).unwrap_err());
        // Truncated header names the missing field and the file.
        let p = write("trunc", "3\n10\n");
        let m = msg(&p);
        assert!(m.contains("truncated before the NNZ header"), "{m}");
        assert!(m.contains("trunc"), "{m}");
        // Bad header names the field and the line.
        let m = msg(&write("badhdr", "3\nfoo\n1\n1 1 1\n"));
        assert!(m.contains("bad W header") && m.contains(":2"), "{m}");
        // Out-of-range word id carries the line number.
        let m = msg(&write("wrange", "1\n5\n1\n1 9 2\n"));
        assert!(m.contains("word id 9 outside 1..=5"), "{m}");
        assert!(m.contains(":4"), "{m}");
        // Out-of-range doc id likewise.
        let m = msg(&write("drange", "1\n5\n1\n4 2 2\n"));
        assert!(m.contains("doc id 4 outside 1..=1") && m.contains(":4"), "{m}");
        // Non-monotonic doc ids are a hard error (the UCI layout sorts by
        // document; the streaming reader depends on it).
        let m = msg(&write("mono", "2\n5\n3\n2 1 1\n1 2 1\n2 3 1\n"));
        assert!(m.contains("non-monotonic doc id 1 after 2"), "{m}");
        assert!(m.contains(":5"), "{m}");
        // NNZ mismatch names both counts.
        let m = msg(&write("nnz", "1\n5\n3\n1 2 2\n"));
        assert!(m.contains("declares 3 entries but carries 1"), "{m}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_docs_are_dropped_on_read() {
        let dir = tmpdir("emptydocs");
        let p = dir.join("dw");
        std::fs::write(&p, "3\n4\n2\n1 1 1\n3 2 2\n").unwrap();
        let c = read_docword(&p).unwrap();
        assert_eq!(c.docs.len(), 2, "the empty middle doc must be dropped");
        assert_eq!(c.total_tokens(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
