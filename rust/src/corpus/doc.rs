//! Documents and corpora.

/// A bag-of-words document: the flat token sequence (word ids).
#[derive(Clone, Debug, Default)]
pub struct Document {
    /// Token stream (word ids into the vocabulary).
    pub tokens: Vec<u32>,
}

impl Document {
    /// Number of tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True iff no tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// A corpus: documents + vocabulary size (+ the generator's ground truth
/// when synthetic, for diagnostics).
#[derive(Clone, Debug)]
pub struct Corpus {
    /// All documents.
    pub docs: Vec<Document>,
    /// Number of token-types the ids range over.
    pub vocab_size: usize,
    /// Ground-truth number of topics used by the generator (diagnostics).
    pub true_topics: usize,
}

impl Corpus {
    /// Total token count.
    pub fn total_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }

    /// Number of *distinct* token-types actually present.
    pub fn observed_types(&self) -> usize {
        let mut seen = vec![false; self.vocab_size];
        let mut n = 0usize;
        for d in &self.docs {
            for &w in &d.tokens {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    n += 1;
                }
            }
        }
        n
    }

    /// Split off the last `n_docs` documents as a held-out test set
    /// (the paper evaluates perplexity on a fixed 2000-document test set).
    pub fn split_test(mut self, n_docs: usize) -> (Corpus, Corpus) {
        let n_docs = n_docs.min(self.docs.len().saturating_sub(1));
        let test_docs = self.docs.split_off(self.docs.len() - n_docs);
        let test = Corpus {
            docs: test_docs,
            vocab_size: self.vocab_size,
            true_topics: self.true_topics,
        };
        (self, test)
    }

    /// Per-word frequency histogram (diagnostics: verifying the power law).
    pub fn word_frequencies(&self) -> Vec<u64> {
        let mut freq = vec![0u64; self.vocab_size];
        for d in &self.docs {
            for &w in &d.tokens {
                freq[w as usize] += 1;
            }
        }
        freq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Corpus {
        Corpus {
            docs: vec![
                Document { tokens: vec![0, 1, 2] },
                Document { tokens: vec![1, 1] },
                Document { tokens: vec![3] },
            ],
            vocab_size: 5,
            true_topics: 2,
        }
    }

    #[test]
    fn totals() {
        let c = tiny();
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.observed_types(), 4);
        assert_eq!(c.word_frequencies(), vec![1, 3, 1, 1, 0]);
    }

    #[test]
    fn split_test_partitions() {
        let (train, test) = tiny().split_test(1);
        assert_eq!(train.docs.len(), 2);
        assert_eq!(test.docs.len(), 1);
        assert_eq!(test.docs[0].tokens, vec![3]);
    }

    #[test]
    fn split_test_never_empties_train() {
        let (train, test) = tiny().split_test(100);
        assert_eq!(train.docs.len(), 1);
        assert_eq!(test.docs.len(), 2);
    }
}
