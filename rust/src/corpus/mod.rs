//! Synthetic corpus substrate.
//!
//! The paper trains on an anonymized proprietary collection (~50M tokens,
//! ~200k documents, ~2M token-types *per shard*). We cannot obtain it, so —
//! per the substitution rule — this module generates corpora from a
//! ground-truth generative process with the three properties the samplers'
//! cost model actually depends on:
//!
//! 1. **document-side sparsity** `k_d` (topics per document stays small),
//! 2. **word-side density** (every word can take every topic via the prior,
//!    so `n_tw` becomes dense at scale — the regime where sparse samplers
//!    lose and the alias sampler wins),
//! 3. **power-law vocabulary** (word frequencies Zipf-distributed; the PYP
//!    generator reproduces the natural-language tail the PDP model targets).
//!
//! Corpus *acquisition* is pluggable ([`source::CorpusSource`]): the
//! synthetic generator is one source among others — a docword file on
//! disk ([`source::FileSource`]) trains through the identical path.
//!
//! ## Streaming: the chunk contract
//!
//! Corpora that outgrow RAM stream instead of loading: a
//! [`stream::CorpusStream`] (concretely [`stream::StreamingSource`] over
//! a docword file) hands out documents in bounded chunks of at most
//! `chunk_docs` complete documents per call, retaining only the single
//! document currently being assembled across calls. Chunks **partition**
//! the corpus: concatenated in order they equal exactly what
//! [`read_docword`] returns — same documents, same order, same bags,
//! empty documents dropped — even when a chunk boundary falls inside one
//! document's triple run. Both readers share one parser and fail with
//! the same named [`source::DocwordError`]s (path + line number), and
//! both enforce doc-id monotonicity — the property that lets the
//! streaming reader seal a document the moment its id stops appearing.
//! Lazy sharding assigns streamed document *i* to shard `i % n_shards`,
//! which is precisely [`ShardSet::partition`]'s round-robin rule, so a
//! streamed corpus shards identically to a loaded one.

pub mod doc;
pub mod generator;
pub mod shard;
pub mod source;
pub mod stream;
pub mod vocab;

pub use doc::{Corpus, Document};
pub use generator::{CorpusConfig, GenerativeModel};
pub use shard::{Shard, ShardSet};
pub use source::{
    read_docword, write_docword, CorpusSource, DocwordError, DocwordHeader, FileSource,
    SyntheticSource,
};
pub use stream::{CorpusStream, StreamingSource};
pub use vocab::Vocabulary;
