//! Synthetic corpus substrate.
//!
//! The paper trains on an anonymized proprietary collection (~50M tokens,
//! ~200k documents, ~2M token-types *per shard*). We cannot obtain it, so —
//! per the substitution rule — this module generates corpora from a
//! ground-truth generative process with the three properties the samplers'
//! cost model actually depends on:
//!
//! 1. **document-side sparsity** `k_d` (topics per document stays small),
//! 2. **word-side density** (every word can take every topic via the prior,
//!    so `n_tw` becomes dense at scale — the regime where sparse samplers
//!    lose and the alias sampler wins),
//! 3. **power-law vocabulary** (word frequencies Zipf-distributed; the PYP
//!    generator reproduces the natural-language tail the PDP model targets).
//!
//! Corpus *acquisition* is pluggable ([`source::CorpusSource`]): the
//! synthetic generator is one source among others — a docword file on
//! disk ([`source::FileSource`]) trains through the identical path.

pub mod doc;
pub mod generator;
pub mod shard;
pub mod source;
pub mod vocab;

pub use doc::{Corpus, Document};
pub use generator::{CorpusConfig, GenerativeModel};
pub use shard::{Shard, ShardSet};
pub use source::{read_docword, write_docword, CorpusSource, FileSource, SyntheticSource};
pub use vocab::Vocabulary;
