//! Synthetic vocabulary with a Zipf base measure.

use crate::util::rng::Zipf;

/// A vocabulary of `size` token-types with Zipf(s) base frequencies.
///
/// Word ids are ranks: id 0 is the most frequent type. Surface forms are
/// synthesized on demand (`w000123`) — the samplers never need strings, but
/// the topic-inspection example does.
pub struct Vocabulary {
    size: usize,
    /// Zipf base measure over ranks (also the PYP base distribution ψ₀).
    pub base: Zipf,
}

impl Vocabulary {
    /// Build a vocabulary of `size` types with Zipf exponent `s`
    /// (natural language ≈ 1.0–1.2).
    pub fn new(size: usize, zipf_s: f64) -> Self {
        Vocabulary {
            size,
            base: Zipf::new(size, zipf_s),
        }
    }

    /// Number of token-types.
    pub fn len(&self) -> usize {
        self.size
    }

    /// True iff the vocabulary is empty (it never is in practice).
    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Base probability of a word id under the Zipf measure.
    pub fn base_prob(&self, word: u32) -> f64 {
        self.base.probs[word as usize]
    }

    /// Synthetic surface form for a word id.
    pub fn surface(&self, word: u32) -> String {
        format!("w{word:06}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_is_normalized_and_monotone() {
        let v = Vocabulary::new(5000, 1.07);
        assert_eq!(v.len(), 5000);
        let sum: f64 = (0..5000).map(|w| v.base_prob(w)).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        for w in 1..5000u32 {
            assert!(v.base_prob(w) <= v.base_prob(w - 1));
        }
    }

    #[test]
    fn surface_forms_unique() {
        let v = Vocabulary::new(10, 1.0);
        let mut forms: Vec<String> = (0..10).map(|w| v.surface(w)).collect();
        forms.sort();
        forms.dedup();
        assert_eq!(forms.len(), 10);
    }
}
