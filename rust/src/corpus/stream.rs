//! Streaming corpus acquisition: bounded-memory document chunks.
//!
//! [`CorpusSource::load`](super::source::CorpusSource::load) materializes
//! the whole corpus — fine for benchmarks, wrong for the paper's
//! production shape where corpora outgrow any one machine's RAM. A
//! [`CorpusStream`] reads the same UCI docword layout **incrementally**:
//! each [`next_chunk`](CorpusStream::next_chunk) call returns at most
//! `chunk_docs` complete documents and the reader retains only the one
//! document currently being assembled, so resident memory is bounded by
//! the chunk size regardless of corpus size.
//!
//! ## The stream/chunk contract
//!
//! * Chunks partition the corpus: concatenating every chunk yields
//!   exactly the documents [`read_docword`](super::read_docword) would
//!   return, in the same order, with the same per-document bags —
//!   including when a chunk boundary falls *inside* a document's triple
//!   run (the partial document is carried, never split or duplicated).
//! * Empty documents are dropped, as in the whole-file reader, and do
//!   not consume chunk capacity.
//! * Triples must be sorted by document (the whole-file reader now
//!   enforces the same [`DocwordError::NonMonotonicDoc`] rule) — that is
//!   what lets the reader seal a document the moment its id stops
//!   appearing instead of holding the file in memory.
//! * Malformed input fails with the same named [`DocwordError`]s as
//!   [`read_docword`], carrying path + line number.
//!
//! Downstream, the pipeline tier ([`crate::pipeline`]) feeds chunks into
//! a live [`TrainSession`](crate::coordinator::TrainSession) via
//! `ingest`, where per-shard feeds deliver them lazily to the *workers* —
//! so neither the session nor the spawn path ever holds the whole corpus.

use std::io::BufRead;
use std::path::{Path, PathBuf};

use super::doc::Document;
use super::source::{parse_header, parse_triple, DocwordError, DocwordHeader};
use crate::Result;

/// An incremental corpus: documents arrive in bounded chunks instead of
/// one resident `Corpus`. See the module docs for the chunk contract.
pub trait CorpusStream {
    /// Vocabulary size (word ids in emitted documents are `0..vocab`).
    fn vocab_size(&self) -> usize;

    /// The next chunk of complete documents, `Ok(None)` when exhausted.
    /// Every returned chunk is non-empty.
    fn next_chunk(&mut self) -> Result<Option<Vec<Document>>>;

    /// One-line human description for logs and reports.
    fn describe(&self) -> String;
}

/// Streaming reader over a UCI docword file: constant resident memory
/// (one chunk plus the document under assembly), same named errors and
/// same emitted documents as [`read_docword`](super::read_docword).
pub struct StreamingSource {
    path: PathBuf,
    lines: std::iter::Enumerate<std::io::Lines<std::io::BufReader<std::fs::File>>>,
    header: DocwordHeader,
    chunk_docs: usize,
    /// 1-based id of the last doc row consumed (monotonicity guard).
    last_doc: usize,
    /// The document currently being assembled: `(1-based id, tokens)`.
    /// This is the only cross-chunk state — a chunk boundary that splits
    /// a document's triple run parks the partial document here.
    pending: Option<(usize, Document)>,
    triples_seen: usize,
    docs_emitted: usize,
    exhausted: bool,
    /// Largest chunk handed out (the resident-buffer probe the pipeline
    /// acceptance test pins against the chunk bound).
    peak_chunk_docs: usize,
    peak_chunk_tokens: usize,
}

impl StreamingSource {
    /// Open `path` and parse the `D / W / NNZ` header eagerly (so a
    /// truncated or garbage header fails at open time, not mid-stream).
    /// `chunk_docs` bounds every chunk's document count.
    pub fn open(path: impl Into<PathBuf>, chunk_docs: usize) -> Result<StreamingSource> {
        let path = path.into();
        anyhow::ensure!(chunk_docs >= 1, "chunk_docs must be ≥ 1");
        let file = std::fs::File::open(&path).map_err(|e| DocwordError::Io {
            path: path.clone(),
            line: None,
            msg: e.to_string(),
        })?;
        let mut lines = std::io::BufReader::new(file).lines().enumerate();
        let header = parse_header(&path, &mut lines)?;
        Ok(StreamingSource {
            path,
            lines,
            header,
            chunk_docs,
            last_doc: 0,
            pending: None,
            triples_seen: 0,
            docs_emitted: 0,
            exhausted: false,
            peak_chunk_docs: 0,
            peak_chunk_tokens: 0,
        })
    }

    /// The parsed `D / W / NNZ` header.
    pub fn header(&self) -> DocwordHeader {
        self.header
    }

    /// Non-empty documents emitted so far.
    pub fn docs_emitted(&self) -> usize {
        self.docs_emitted
    }

    /// Largest chunk handed out, in documents — the peak resident corpus
    /// buffer. Never exceeds the configured `chunk_docs`.
    pub fn peak_chunk_docs(&self) -> usize {
        self.peak_chunk_docs
    }

    /// Largest chunk handed out, in tokens.
    pub fn peak_chunk_tokens(&self) -> usize {
        self.peak_chunk_tokens
    }

    /// Seal `out` as a finished chunk: record the resident-buffer peaks.
    fn seal(&mut self, out: Vec<Document>) -> Option<Vec<Document>> {
        if out.is_empty() {
            return None;
        }
        self.peak_chunk_docs = self.peak_chunk_docs.max(out.len());
        self.peak_chunk_tokens = self
            .peak_chunk_tokens
            .max(out.iter().map(|d| d.len()).sum());
        Some(out)
    }
}

impl CorpusStream for StreamingSource {
    fn vocab_size(&self) -> usize {
        self.header.vocab
    }

    fn next_chunk(&mut self) -> Result<Option<Vec<Document>>> {
        if self.exhausted {
            return Ok(None);
        }
        let mut out: Vec<Document> = Vec::new();
        while let Some((i, line)) = self.lines.next() {
            let line = line.map_err(|e| DocwordError::Io {
                path: self.path.clone(),
                line: Some(i + 1),
                msg: e.to_string(),
            })?;
            let Some((d, w, c)) =
                parse_triple(&self.path, i + 1, &line, &self.header, self.last_doc)?
            else {
                continue;
            };
            self.last_doc = d;
            self.triples_seen += 1;
            match &mut self.pending {
                Some((pd, doc)) if *pd == d => {
                    for _ in 0..c {
                        doc.tokens.push((w - 1) as u32);
                    }
                }
                _ => {
                    // A new document id: seal the one under assembly
                    // (empty documents are dropped, like the whole-file
                    // reader) and start the next. When sealing fills the
                    // chunk, the fresh document parks in `pending` and
                    // the chunk returns — the boundary case where one
                    // document's rows span two read calls.
                    if let Some((_, doc)) = self.pending.take() {
                        if !doc.is_empty() {
                            out.push(doc);
                            self.docs_emitted += 1;
                        }
                    }
                    let mut doc = Document::default();
                    for _ in 0..c {
                        doc.tokens.push((w - 1) as u32);
                    }
                    self.pending = Some((d, doc));
                    if out.len() >= self.chunk_docs {
                        return Ok(self.seal(out));
                    }
                }
            }
        }
        // EOF: settle the accounting, seal the trailing document.
        self.exhausted = true;
        if self.triples_seen != self.header.nnz {
            return Err(DocwordError::NnzMismatch {
                path: self.path.clone(),
                declared: self.header.nnz,
                seen: self.triples_seen,
            }
            .into());
        }
        if let Some((_, doc)) = self.pending.take() {
            if !doc.is_empty() {
                out.push(doc);
                self.docs_emitted += 1;
            }
        }
        if out.is_empty() {
            if self.docs_emitted == 0 {
                return Err(DocwordError::NoTokens {
                    path: self.path.clone(),
                }
                .into());
            }
            return Ok(None);
        }
        Ok(self.seal(out))
    }

    fn describe(&self) -> String {
        format!(
            "streaming docword file {} (chunks of ≤{} docs)",
            self.path.display(),
            self.chunk_docs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::doc::Corpus;
    use crate::corpus::generator::CorpusConfig;
    use crate::corpus::shard::ShardSet;
    use crate::corpus::source::{read_docword, write_docword};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hplvm_stream_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn bags(docs: &[Document]) -> Vec<Vec<(u32, u32)>> {
        docs.iter()
            .map(|d| {
                let mut m = std::collections::BTreeMap::new();
                for &w in &d.tokens {
                    *m.entry(w).or_insert(0u32) += 1;
                }
                m.into_iter().collect()
            })
            .collect()
    }

    fn gen_corpus(n_docs: usize, seed: u64) -> Corpus {
        CorpusConfig {
            n_docs,
            vocab_size: 120,
            n_topics: 4,
            doc_len_mean: 9.0,
            seed,
            ..Default::default()
        }
        .generate()
        .0
    }

    /// Satellite: the stream yields bag-identical *shards* to the in-RAM
    /// reader at every chunk size — including sizes that force chunk
    /// boundaries inside a document's triple run (chunk_docs = 1 splits
    /// constantly). Round-robin assignment by emitted-document index is
    /// exactly `ShardSet::partition`'s rule, so lazy sharding agrees
    /// with spawn-time sharding document for document.
    #[test]
    fn streaming_shards_match_in_ram_shards_at_every_chunk_size() {
        let corpus = gen_corpus(37, 11);
        let dir = tmpdir("equiv");
        let path = dir.join("docword.txt");
        write_docword(&path, &corpus).unwrap();
        let whole = read_docword(&path).unwrap();
        let n_shards = 3;
        let in_ram = ShardSet::partition(&whole, n_shards);
        for chunk_docs in 1..=whole.docs.len() + 2 {
            let mut stream = StreamingSource::open(&path, chunk_docs).unwrap();
            assert_eq!(stream.vocab_size(), whole.vocab_size);
            let mut streamed: Vec<Document> = Vec::new();
            while let Some(chunk) = stream.next_chunk().unwrap() {
                assert!(!chunk.is_empty(), "chunks are never empty");
                assert!(
                    chunk.len() <= chunk_docs,
                    "chunk of {} exceeds bound {chunk_docs}",
                    chunk.len()
                );
                streamed.extend(chunk);
            }
            assert!(stream.peak_chunk_docs() <= chunk_docs);
            assert_eq!(
                bags(&streamed),
                bags(&whole.docs),
                "chunk_docs={chunk_docs}: stream must equal the in-RAM read"
            );
            // Lazy round-robin sharding over the stream order.
            let mut lazy: Vec<Vec<Document>> = (0..n_shards).map(|_| Vec::new()).collect();
            for (i, d) in streamed.into_iter().enumerate() {
                lazy[i % n_shards].push(d);
            }
            for (s, shard) in in_ram.shards.iter().enumerate() {
                assert_eq!(
                    bags(&lazy[s]),
                    bags(&shard.docs),
                    "chunk_docs={chunk_docs}: shard {s} must match"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A document split across a chunk boundary is carried, not
    /// duplicated: with one triple per line and chunk_docs=1, a 3-row
    /// document must still come out whole.
    #[test]
    fn chunk_boundary_inside_a_document_carries_the_partial_doc() {
        let dir = tmpdir("boundary");
        let path = dir.join("dw");
        // Doc 1: words 1,2 · doc 2: words 1,2,3 · doc 3: word 4.
        std::fs::write(&path, "3\n5\n6\n1 1 1\n1 2 1\n2 1 2\n2 2 1\n2 3 1\n3 4 1\n").unwrap();
        let mut s = StreamingSource::open(&path, 1).unwrap();
        let c1 = s.next_chunk().unwrap().unwrap();
        assert_eq!(bags(&c1), vec![vec![(0, 1), (1, 1)]]);
        let c2 = s.next_chunk().unwrap().unwrap();
        assert_eq!(bags(&c2), vec![vec![(0, 2), (1, 1), (2, 1)]]);
        let c3 = s.next_chunk().unwrap().unwrap();
        assert_eq!(bags(&c3), vec![vec![(3, 1)]]);
        assert!(s.next_chunk().unwrap().is_none());
        assert_eq!(s.docs_emitted(), 3);
        assert_eq!(s.peak_chunk_docs(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Streaming reads fail with the same named errors as the whole-file
    /// reader: bad ids mid-stream, non-monotonic docs, NNZ mismatches at
    /// EOF — all carrying path and line.
    #[test]
    fn streaming_errors_are_named_and_positioned() {
        let dir = tmpdir("errors");
        let write = |name: &str, text: &str| {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            p
        };
        // Header failures surface at open.
        assert!(StreamingSource::open(write("trunc", "3\n10\n"), 4).is_err());
        assert!(StreamingSource::open(write("zero", "0\n10\n0\n"), 4).is_err());
        // Body failures surface on the chunk that reads the bad line.
        let mut s = StreamingSource::open(write("mono", "2\n5\n3\n2 1 1\n1 2 1\n2 3 1\n"), 8)
            .unwrap();
        let m = format!("{}", s.next_chunk().unwrap_err());
        assert!(m.contains("non-monotonic doc id 1 after 2") && m.contains(":5"), "{m}");
        let mut s =
            StreamingSource::open(write("nnz", "1\n5\n3\n1 2 2\n"), 8).unwrap();
        let m = format!("{}", s.next_chunk().unwrap_err());
        assert!(m.contains("declares 3 entries but carries 1"), "{m}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
