//! The training coordinator (Layer 3): wires corpus shards, the parameter
//! server, worker clients, the scheduler, failure injection and metrics
//! into the paper's full training loop (§5.2, §6).

pub mod metrics;
pub mod model;
pub mod trainer;
pub mod worker;

pub use metrics::{IterRecord, IterStats, TrainReport};
pub use model::ModelSampler;
pub use trainer::Trainer;
