//! The training coordinator (Layer 5): wires corpus shards, the parameter
//! server, worker clients, the scheduler, failure injection and metrics
//! into the paper's full training loop (§5.2, §6) — exposed as a
//! long-lived, resumable [`TrainSession`] (segments, cluster checkpoints,
//! streaming [`TrainObserver`] metrics) with the one-shot
//! [`Trainer::run`] kept as a single-segment wrapper. Online mode adds
//! lazy sharding ([`DocFeed`]) and parked workers, the substrate the
//! [`pipeline`](crate::pipeline) tier drives.

pub mod feed;
pub mod metrics;
pub mod model;
pub mod session;
pub mod trainer;
pub mod worker;

pub use feed::DocFeed;
pub use metrics::{IterRecord, IterStats, RecordFold, TrainReport};
pub use model::ModelSampler;
pub use session::{
    NullObserver, PrintObserver, SegmentReport, TrainObserver, TrainSession,
};
pub use trainer::Trainer;
