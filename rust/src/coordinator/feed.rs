//! Lazy shard delivery: the per-shard document feed.
//!
//! Spawn-time sharding hands every worker its whole shard up front —
//! fine for segment training, wrong for the online loop where documents
//! arrive continuously and the corpus may never be resident at once. A
//! [`DocFeed`] is the pull side of lazy sharding: the session appends
//! newly ingested documents per shard
//! ([`TrainSession::ingest`](super::TrainSession::ingest)), and the live
//! worker drains the feed at iteration boundaries (and while parked),
//! absorbing the new documents into its sampler without a respawn.
//!
//! Ordering is the correctness contract: documents enter the feed in the
//! same order the session appends them to `Shard::docs`, and the worker
//! appends drained documents to its sampler in feed order — so the
//! barrier-free disk snapshots' `z` rows stay index-aligned with the
//! shard, and a failover respawn (which reads `Shard::docs` directly and
//! [`clear_pending`](DocFeed::clear_pending)s the feed) resumes the
//! identical document list.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::corpus::doc::Document;

/// A per-shard queue of freshly ingested documents plus the ingest
/// accounting the pipeline's freshness metric reads.
#[derive(Default)]
pub struct DocFeed {
    q: Mutex<VecDeque<Document>>,
    pushed_docs: AtomicU64,
    pushed_tokens: AtomicU64,
    absorbed_docs: AtomicU64,
}

impl DocFeed {
    /// An empty feed.
    pub fn new() -> DocFeed {
        DocFeed::default()
    }

    /// Append one document (session side). Callers push in `Shard::docs`
    /// order — see the module docs.
    pub fn push(&self, doc: Document) {
        self.pushed_tokens.fetch_add(doc.len() as u64, Ordering::Relaxed);
        self.pushed_docs.fetch_add(1, Ordering::Relaxed);
        self.q.lock().unwrap().push_back(doc);
    }

    /// Documents queued but not yet taken by the worker.
    pub fn pending_docs(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    /// Drain everything queued (worker side), in push order. The drained
    /// documents count as absorbed — they become part of the live
    /// sampler immediately after this call.
    pub fn take_pending(&self) -> Vec<Document> {
        let docs: Vec<Document> = self.q.lock().unwrap().drain(..).collect();
        self.absorbed_docs.fetch_add(docs.len() as u64, Ordering::Relaxed);
        docs
    }

    /// Discard everything queued without handing it to a worker — the
    /// respawn path, where the replacement worker reads the full
    /// `Shard::docs` (which already contains these documents) instead.
    /// They count as absorbed: the new incarnation samples them.
    pub fn clear_pending(&self) {
        let mut q = self.q.lock().unwrap();
        self.absorbed_docs.fetch_add(q.len() as u64, Ordering::Relaxed);
        q.clear();
    }

    /// Total documents ever pushed.
    pub fn pushed_docs(&self) -> u64 {
        self.pushed_docs.load(Ordering::Relaxed)
    }

    /// Total tokens ever pushed.
    pub fn pushed_tokens(&self) -> u64 {
        self.pushed_tokens.load(Ordering::Relaxed)
    }

    /// Total documents taken (or cleared) off the feed.
    pub fn absorbed_docs(&self) -> u64 {
        self.absorbed_docs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(words: &[u32]) -> Document {
        Document {
            tokens: words.to_vec(),
        }
    }

    #[test]
    fn feed_preserves_order_and_counts() {
        let f = DocFeed::new();
        f.push(doc(&[1, 2]));
        f.push(doc(&[3]));
        assert_eq!(f.pending_docs(), 2);
        assert_eq!((f.pushed_docs(), f.pushed_tokens()), (2, 3));
        assert_eq!(f.absorbed_docs(), 0);
        let got = f.take_pending();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].tokens, vec![1, 2], "FIFO order");
        assert_eq!(got[1].tokens, vec![3]);
        assert_eq!(f.absorbed_docs(), 2);
        assert_eq!(f.pending_docs(), 0);
        assert!(f.take_pending().is_empty());

        f.push(doc(&[4]));
        f.clear_pending();
        assert_eq!(f.pending_docs(), 0);
        assert_eq!(f.absorbed_docs(), 3, "cleared docs count as absorbed");
    }
}
