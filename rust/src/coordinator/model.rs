//! Model-agnostic sampler dispatch: one enum the worker drives, hiding
//! which of the four samplers (and which set of shared matrices) is
//! underneath.

use crate::config::{ModelKind, TrainConfig};
use crate::corpus::doc::Document;
use crate::eval::perplexity::TopicModelView;
use crate::ps::snapshot::ClientSnapshot;
use crate::sampler::alias_lda::AliasLda;
use crate::sampler::counts::CountMatrix;
use crate::sampler::hdp::AliasHdp;
use crate::sampler::pdp::AliasPdp;
use crate::sampler::sparse_lda::SparseLda;
use crate::sampler::DocSampler;
use crate::util::rng::Rng;

/// Matrix-id layout shared with the servers:
/// * LDA (both samplers): `0 = n_tw`
/// * PDP: `0 = m_tw`, `1 = s_tw`
/// * HDP: `0 = n_tw`, `1 = root tables (row 0)`
pub const MATRIX_PRIMARY: u8 = 0;
/// Secondary matrix id (tables).
pub const MATRIX_TABLES: u8 = 1;

/// The dispatching sampler.
pub enum ModelSampler {
    /// YahooLDA baseline.
    Yahoo(SparseLda),
    /// AliasLDA.
    Alias(AliasLda),
    /// AliasPDP.
    Pdp(AliasPdp),
    /// AliasHDP.
    Hdp(AliasHdp),
}

impl ModelSampler {
    /// Build the configured sampler over a shard, optionally restoring
    /// topic assignments from a client snapshot (failover path).
    pub fn build(
        cfg: &TrainConfig,
        docs: Vec<Document>,
        vocab: usize,
        resume: Option<&ClientSnapshot>,
        rng: &mut Rng,
    ) -> ModelSampler {
        let p = &cfg.params;
        let init = resume.map(|s| s.z.as_slice());
        match cfg.model {
            ModelKind::YahooLda => ModelSampler::Yahoo(SparseLda::new_with_init(
                docs, vocab, p.topics, p.alpha, p.beta, init, rng,
            )),
            ModelKind::AliasLda => {
                let mut s = AliasLda::new_with_init(
                    docs, vocab, p.topics, p.alpha, p.beta, init, rng,
                );
                s.mh_steps = p.mh_steps;
                ModelSampler::Alias(s)
            }
            ModelKind::AliasPdp => {
                let mut s = AliasPdp::new_with_init(
                    docs,
                    vocab,
                    p.topics,
                    p.alpha,
                    p.pdp_discount,
                    p.pdp_concentration,
                    p.pdp_gamma,
                    init,
                    rng,
                );
                s.mh_steps = p.mh_steps;
                // Fig 8 semantics: "without projection" means the raw,
                // unrepaired statistics drive the sampler.
                s.raw_mode = cfg.projection == crate::config::ProjectionMode::Off;
                ModelSampler::Pdp(s)
            }
            ModelKind::AliasHdp => {
                let mut s = AliasHdp::new_with_init(
                    docs,
                    vocab,
                    p.topics,
                    p.hdp_b0,
                    p.hdp_b1,
                    p.beta,
                    init,
                    rng,
                );
                s.mh_steps = p.mh_steps;
                ModelSampler::Hdp(s)
            }
        }
    }

    /// Resample one document.
    pub fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize {
        match self {
            ModelSampler::Yahoo(s) => s.sample_doc(d, rng),
            ModelSampler::Alias(s) => s.sample_doc(d, rng),
            ModelSampler::Pdp(s) => s.sample_doc(d, rng),
            ModelSampler::Hdp(s) => s.sample_doc(d, rng),
        }
    }

    /// Shard documents.
    pub fn docs(&self) -> &[Document] {
        match self {
            ModelSampler::Yahoo(s) => &s.docs,
            ModelSampler::Alias(s) => &s.docs,
            ModelSampler::Pdp(s) => &s.docs,
            ModelSampler::Hdp(s) => &s.docs,
        }
    }

    /// Latent assignments (for snapshots / log-likelihood).
    pub fn assignments(&self) -> (&[Vec<u32>], &[Vec<bool>]) {
        match self {
            ModelSampler::Yahoo(s) => (&s.state.z, &s.state.r),
            ModelSampler::Alias(s) => (&s.state.z, &s.state.r),
            ModelSampler::Pdp(s) => (&s.state.z, &s.state.r),
            ModelSampler::Hdp(s) => (&s.state.z, &s.state.r),
        }
    }

    /// The shared matrices this model synchronizes, as `(id, replica)`.
    pub fn matrices(&mut self) -> Vec<(u8, &mut CountMatrix)> {
        match self {
            ModelSampler::Yahoo(s) => vec![(MATRIX_PRIMARY, &mut s.nwt)],
            ModelSampler::Alias(s) => vec![(MATRIX_PRIMARY, &mut s.nwt)],
            ModelSampler::Pdp(s) => {
                vec![(MATRIX_PRIMARY, &mut s.m), (MATRIX_TABLES, &mut s.s)]
            }
            ModelSampler::Hdp(s) => {
                vec![(MATRIX_PRIMARY, &mut s.nwt), (MATRIX_TABLES, &mut s.tables)]
            }
        }
    }

    /// Export every replica's non-empty rows in wire form, keyed by the
    /// same matrix ids [`matrices`] announces. Worker checkpoints carry
    /// this so a segment resume restores the *pulled* replica state (which
    /// includes other shards' contributions) instead of rebuilding from
    /// local `z` alone.
    ///
    /// [`matrices`]: ModelSampler::matrices
    pub fn export_replicas(&self) -> Vec<(u8, Vec<(u32, crate::ps::msg::RowData)>)> {
        match self {
            ModelSampler::Yahoo(s) => vec![(MATRIX_PRIMARY, s.nwt.export_rows())],
            ModelSampler::Alias(s) => vec![(MATRIX_PRIMARY, s.nwt.export_rows())],
            ModelSampler::Pdp(s) => vec![
                (MATRIX_PRIMARY, s.m.export_rows()),
                (MATRIX_TABLES, s.s.export_rows()),
            ],
            ModelSampler::Hdp(s) => vec![
                (MATRIX_PRIMARY, s.nwt.export_rows()),
                (MATRIX_TABLES, s.tables.export_rows()),
            ],
        }
    }

    /// Fold pulled rows (sparse or dense wire form) into a replica +
    /// invalidate stale caches (§3.3).
    pub fn apply_rows(&mut self, matrix: u8, rows: &[(u32, crate::ps::msg::RowData)]) {
        match self {
            ModelSampler::Yahoo(s) => {
                for (w, row) in rows {
                    s.nwt.apply_pull_row(*w, row);
                    s.refresh_word(*w);
                }
            }
            ModelSampler::Alias(s) => {
                for (w, row) in rows {
                    s.nwt.apply_pull_row(*w, row);
                    s.invalidate_word(*w);
                }
            }
            ModelSampler::Pdp(s) => {
                for (w, row) in rows {
                    match matrix {
                        MATRIX_PRIMARY => s.m.apply_pull_row(*w, row),
                        _ => s.s.apply_pull_row(*w, row),
                    }
                    s.invalidate_word(*w);
                }
            }
            ModelSampler::Hdp(s) => {
                for (w, row) in rows {
                    match matrix {
                        MATRIX_PRIMARY => {
                            s.nwt.apply_pull_row(*w, row);
                            s.invalidate_word(*w);
                        }
                        _ => {
                            s.tables.apply_pull_row(*w, row);
                            // θ₀ changed for every word's dense proposal.
                            s.invalidate_all();
                        }
                    }
                }
            }
        }
    }

    /// Re-log the statistics contributions of documents `from..` as
    /// fresh, *pushable* deltas — the appended-document announce used by
    /// online ingest.
    ///
    /// Precondition: the caller rebuilt this sampler over old+new docs,
    /// **drained** the rebuild's init delta log, and applied the
    /// pre-append exported replica rows (`have` is their row keyset per
    /// matrix, each sorted ascending). After that overwrite, rows the new
    /// documents touch fall in two classes: rows *in* the export now
    /// carry the pre-append value and just need the new tokens added;
    /// rows *absent* from it still carry the rebuild's raw counts and
    /// must be zeroed first, or the logged increments below would double
    /// them locally. (A row any *old* document touches is always in the
    /// export — its counts are ≥ 1 and non-negative — so zeroing absent
    /// rows never erases old contributions.) Both classes end with
    /// `local = pre-append value + new tokens` and a delta log carrying
    /// exactly the new documents' counts, which the next `push_matrix`
    /// ships to the servers.
    pub fn announce_appended(&mut self, from: usize, have: &[(u8, Vec<u32>)]) {
        use crate::ps::msg::RowData;
        let has = |m: u8, w: u32| {
            have.iter()
                .any(|(mm, ws)| *mm == m && ws.binary_search(&w).is_ok())
        };
        // Token events for the appended documents: every token adds one
        // count to the primary matrix; table-opening tokens (`r`) add one
        // to the tables matrix — per word for PDP, the shared root row 0
        // for HDP.
        let tables_row_is_root = matches!(self, ModelSampler::Hdp(_));
        let has_tables = matches!(self, ModelSampler::Pdp(_) | ModelSampler::Hdp(_));
        let mut events: Vec<(u8, u32, u32)> = Vec::new();
        {
            let (z, r) = self.assignments();
            let docs = self.docs();
            for d in from..docs.len() {
                for (j, &w) in docs[d].tokens.iter().enumerate() {
                    let t = z[d][j];
                    events.push((MATRIX_PRIMARY, w, t));
                    if has_tables && r.get(d).and_then(|rd| rd.get(j)).copied().unwrap_or(false)
                    {
                        let row = if tables_row_is_root { 0 } else { w };
                        events.push((MATRIX_TABLES, row, t));
                    }
                }
            }
        }
        // Zero the touched rows the export did not carry.
        let mut zero: Vec<(u8, u32)> = events
            .iter()
            .map(|&(m, w, _)| (m, w))
            .filter(|&(m, w)| !has(m, w))
            .collect();
        zero.sort_unstable();
        zero.dedup();
        for &(m, w) in &zero {
            self.apply_rows(m, &[(w, RowData::Sparse(Vec::new()))]);
        }
        // Replay through the delta-*logging* increment path (`inc`, not
        // `inc_local`), then refresh the alias/normalizer caches for
        // every word whose row moved.
        for &(m, w, t) in &events {
            let t = t as usize;
            match self {
                ModelSampler::Yahoo(s) => s.nwt.inc(w, t, 1),
                ModelSampler::Alias(s) => s.nwt.inc(w, t, 1),
                ModelSampler::Pdp(s) => {
                    if m == MATRIX_PRIMARY {
                        s.m.inc(w, t, 1)
                    } else {
                        s.s.inc(w, t, 1)
                    }
                }
                ModelSampler::Hdp(s) => {
                    if m == MATRIX_PRIMARY {
                        s.nwt.inc(w, t, 1)
                    } else {
                        s.tables.inc(w, t, 1)
                    }
                }
            }
        }
        let mut words: Vec<u32> = events
            .iter()
            .filter(|&&(m, _, _)| m == MATRIX_PRIMARY)
            .map(|&(_, w, _)| w)
            .collect();
        words.sort_unstable();
        words.dedup();
        let tables_moved = events.iter().any(|&(m, _, _)| m == MATRIX_TABLES);
        match self {
            ModelSampler::Yahoo(s) => {
                for &w in &words {
                    s.refresh_word(w);
                }
            }
            ModelSampler::Alias(s) => {
                for &w in &words {
                    s.invalidate_word(w);
                }
            }
            ModelSampler::Pdp(s) => {
                for &w in &words {
                    s.invalidate_word(w);
                }
            }
            ModelSampler::Hdp(s) => {
                if tables_moved {
                    // θ₀ changed for every word's dense proposal.
                    s.invalidate_all();
                } else {
                    for &w in &words {
                        s.invalidate_word(w);
                    }
                }
            }
        }
    }

    /// Evaluation view.
    pub fn view(&self) -> &dyn TopicModelView {
        match self {
            ModelSampler::Yahoo(s) => s,
            ModelSampler::Alias(s) => s,
            ModelSampler::Pdp(s) => s,
            ModelSampler::Hdp(s) => s,
        }
    }

    /// Average non-zero topics per word (figure panel).
    pub fn topics_per_word(&self) -> f64 {
        match self {
            ModelSampler::Yahoo(s) => s.nwt.avg_topics_per_word(),
            ModelSampler::Alias(s) => s.nwt.avg_topics_per_word(),
            ModelSampler::Pdp(s) => s.m.avg_topics_per_word(),
            ModelSampler::Hdp(s) => s.nwt.avg_topics_per_word(),
        }
    }

    /// Primary count matrix (read-only; topic inspection).
    pub fn primary(&self) -> &CountMatrix {
        match self {
            ModelSampler::Yahoo(s) => &s.nwt,
            ModelSampler::Alias(s) => &s.nwt,
            ModelSampler::Pdp(s) => &s.m,
            ModelSampler::Hdp(s) => &s.nwt,
        }
    }

    /// Model display name.
    pub fn name(&self) -> &'static str {
        match self {
            ModelSampler::Yahoo(s) => s.name(),
            ModelSampler::Alias(s) => s.name(),
            ModelSampler::Pdp(s) => s.name(),
            ModelSampler::Hdp(s) => s.name(),
        }
    }

    /// End-of-iteration client-side projection (Algorithms 1/2). Returns
    /// corrections performed.
    pub fn project(
        &mut self,
        mode: crate::config::ProjectionMode,
        client_idx: usize,
        n_clients: usize,
        salt: u64,
    ) -> u64 {
        use crate::config::ProjectionMode as PM;
        use crate::projection::{DistributedProjection, SingleMachineProjection};
        match self {
            // LDA statistics have no pairwise polytope; totals are
            // re-derived continuously. Nothing to do.
            ModelSampler::Yahoo(_) | ModelSampler::Alias(_) => 0,
            ModelSampler::Pdp(s) => match mode {
                PM::Off | PM::OnDemandServer => 0,
                PM::SingleMachine => {
                    if client_idx == 0 {
                        SingleMachineProjection::default().project_all(&mut s.s, &mut s.m)
                    } else {
                        0
                    }
                }
                PM::Distributed => DistributedProjection::new(client_idx, n_clients, salt)
                    .project_owned(&mut s.s, &mut s.m),
            },
            ModelSampler::Hdp(s) => match mode {
                PM::Off | PM::OnDemandServer => 0,
                PM::SingleMachine | PM::Distributed => {
                    // Root constraint t_k ∈ [min(1, n_k), n_k]: the sweep
                    // is tiny (one row), so the designated owner of key 0
                    // performs it.
                    let owner = if mode == PM::SingleMachine {
                        client_idx == 0
                    } else {
                        DistributedProjection::new(client_idx, n_clients, salt).owns(0)
                    };
                    if !owner {
                        return 0;
                    }
                    let mut corrections = 0u64;
                    for t in 0..s.tables.k() {
                        let tk = s.tables.get(0, t);
                        let nk = s.nwt.total(t).clamp(0, i32::MAX as i64) as i32;
                        let (tk1, _) =
                            crate::projection::project_pair(
                                crate::projection::PairRule::TablePolytope,
                                tk,
                                nk,
                            );
                        if tk1 != tk {
                            s.tables.inc(0, t, tk1 - tk);
                            corrections += 1;
                        }
                    }
                    corrections
                }
            },
        }
    }

    /// MH acceptance-rate diagnostic (1.0 for the exact sparse sampler).
    pub fn acceptance_rate(&self) -> f64 {
        match self {
            ModelSampler::Yahoo(_) => 1.0,
            ModelSampler::Alias(s) => s.acceptance_rate(),
            ModelSampler::Pdp(s) => s.acceptance_rate(),
            ModelSampler::Hdp(s) => s.acceptance_rate(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::CorpusConfig;

    fn docs() -> Vec<Document> {
        let (c, _) = CorpusConfig {
            n_docs: 30,
            vocab_size: 120,
            n_topics: 4,
            doc_len_mean: 15.0,
            ..Default::default()
        }
        .generate();
        c.docs
    }

    #[test]
    fn builds_all_four_models() {
        for model in [
            ModelKind::YahooLda,
            ModelKind::AliasLda,
            ModelKind::AliasPdp,
            ModelKind::AliasHdp,
        ] {
            let mut cfg = TrainConfig::default();
            cfg.model = model;
            cfg.params.topics = 8;
            let mut rng = Rng::new(1);
            let mut s = ModelSampler::build(&cfg, docs(), 120, None, &mut rng);
            assert_eq!(s.view().k(), 8);
            let acc = s.sample_doc(0, &mut rng);
            assert!(acc <= s.docs()[0].tokens.len() * cfg.params.mh_steps.max(1));
            assert!(!s.matrices().is_empty());
            assert!(s.topics_per_word() > 0.0);
        }
    }

    #[test]
    fn snapshot_restore_reproduces_assignments() {
        let mut cfg = TrainConfig::default();
        cfg.model = ModelKind::AliasLda;
        cfg.params.topics = 6;
        let d = docs();
        let mut rng = Rng::new(2);
        let s = ModelSampler::build(&cfg, d.clone(), 120, None, &mut rng);
        let (z, r) = s.assignments();
        let snap = crate::ps::snapshot::ClientSnapshot {
            shard: 0,
            iteration: 5,
            z: z.to_vec(),
            r: r.to_vec(),
            replicas: Vec::new(),
        };
        let mut rng2 = Rng::new(99);
        let restored = ModelSampler::build(&cfg, d, 120, Some(&snap), &mut rng2);
        assert_eq!(restored.assignments().0, snap.z.as_slice());
    }

    /// Satellite: resume-state *shape mismatches* inside
    /// `ModelSampler::build` — a snapshot from a different corpus (fewer
    /// docs, shorter docs, out-of-range topics) must degrade per-token to
    /// fresh random init, never panic, and always leave the local
    /// statistics consistent with the shard.
    #[test]
    fn resume_shape_mismatches_fall_back_per_token() {
        let d = docs();
        let total_tokens: i64 = d.iter().map(|doc| doc.tokens.len() as i64).sum();
        let k = 6usize;
        let mut cfg = TrainConfig::default();
        cfg.model = ModelKind::AliasLda;
        cfg.params.topics = k;

        // A deliberately malformed snapshot: one doc missing entirely,
        // one z-row too short, one too long, and an out-of-range topic.
        let mut z: Vec<Vec<u32>> = d.iter().map(|doc| vec![1; doc.tokens.len()]).collect();
        z.pop(); // fewer docs than the shard
        z[0].pop(); // short row: last token falls back
        z[1].push(3); // long row: extra entry ignored
        z[2][0] = 999; // topic ≥ k: falls back
        let snap = crate::ps::snapshot::ClientSnapshot {
            shard: 0,
            iteration: 7,
            z,
            r: Vec::new(),
            replicas: Vec::new(),
        };
        let mut rng = Rng::new(5);
        let s = ModelSampler::build(&cfg, d.clone(), 120, Some(&snap), &mut rng);
        let (z_out, _) = s.assignments();
        assert_eq!(z_out.len(), d.len(), "one z row per shard doc");
        for (doc, zd) in d.iter().zip(z_out) {
            assert_eq!(zd.len(), doc.tokens.len(), "z row matches doc length");
            assert!(zd.iter().all(|&t| (t as usize) < k), "topics within K");
        }
        // Restored entries that *were* valid survive verbatim.
        assert!(z_out[0][..z_out[0].len() - 1].iter().all(|&t| t == 1));
        assert_ne!(z_out[2][0], 999);
        // Statistics rebuilt from the final assignments account for every
        // token exactly once.
        assert_eq!(s.primary().grand_total(), total_tokens);
    }

    /// Resume restores PDP and HDP through the same path: assignments are
    /// taken from the snapshot, table indicators are re-derived by the
    /// CRP rule, and the rebuilt statistics stay shard-consistent.
    #[test]
    fn resume_restores_table_models() {
        let d = docs();
        for (kind, k) in [(ModelKind::AliasPdp, 6), (ModelKind::AliasHdp, 8)] {
            let mut cfg = TrainConfig::default();
            cfg.model = kind;
            cfg.params.topics = k;
            let mut rng = Rng::new(11);
            let fresh = ModelSampler::build(&cfg, d.clone(), 120, None, &mut rng);
            let (z, r) = fresh.assignments();
            let snap = crate::ps::snapshot::ClientSnapshot {
                shard: 0,
                iteration: 3,
                z: z.to_vec(),
                r: r.to_vec(),
                replicas: Vec::new(),
            };
            let mut rng2 = Rng::new(77);
            let restored = ModelSampler::build(&cfg, d.clone(), 120, Some(&snap), &mut rng2);
            let (z2, r2) = restored.assignments();
            assert_eq!(z2, snap.z.as_slice(), "{kind:?} z restored verbatim");
            // Table indicators are re-derived (not copied), but shaped
            // per token like the originals.
            assert_eq!(r2.len(), d.len());
            for (doc, rd) in d.iter().zip(r2) {
                assert_eq!(rd.len(), doc.tokens.len(), "{kind:?} r row shape");
            }
            assert_eq!(
                restored.primary().grand_total(),
                fresh.primary().grand_total(),
                "{kind:?} restored statistics must cover the same tokens"
            );
        }
    }

    /// Online ingest's appended-document announce: after a rebuild over
    /// old+new docs, drain → apply pre-append export → announce_appended
    /// must leave (a) local statistics equal to the pre-append values
    /// plus exactly the new documents' tokens — including rows the
    /// export never carried — and (b) a delta log carrying exactly the
    /// new documents' counts, so the next push ships them once.
    #[test]
    fn announce_appended_logs_exactly_the_new_docs() {
        let mk = |words: &[u32]| Document {
            tokens: words.to_vec(),
        };
        // Old docs touch words {0,1,2}; new docs touch {2,3,4} — rows 3
        // and 4 are absent from the pre-append export (the zeroing path).
        let old = vec![mk(&[0, 1]), mk(&[1, 2])];
        let new = vec![mk(&[2, 3]), mk(&[3, 3, 4])];
        let mut cfg = TrainConfig::default();
        cfg.model = ModelKind::AliasLda;
        cfg.params.topics = 4;

        let mut rng = Rng::new(21);
        let s1 = ModelSampler::build(&cfg, old.clone(), 10, None, &mut rng);
        let (z1, r1) = s1.assignments();
        let snap = crate::ps::snapshot::ClientSnapshot {
            shard: 0,
            iteration: 1,
            z: z1.to_vec(),
            r: r1.to_vec(),
            replicas: s1.export_replicas(),
        };
        let old_counts: Vec<Vec<i32>> = (0..5)
            .map(|w| (0..4).map(|t| s1.primary().get(w, t)).collect())
            .collect();

        let mut all = old.clone();
        all.extend(new.clone());
        let mut rng2 = Rng::new(77);
        let mut s2 = ModelSampler::build(&cfg, all, 10, Some(&snap), &mut rng2);
        for (_m, rep) in s2.matrices() {
            let _ = rep.drain_deltas();
        }
        for (m, rows) in &snap.replicas {
            s2.apply_rows(*m, rows);
        }
        let have: Vec<(u8, Vec<u32>)> = snap
            .replicas
            .iter()
            .map(|(m, rows)| {
                let mut ws: Vec<u32> = rows.iter().map(|&(w, _)| w).collect();
                ws.sort_unstable();
                (*m, ws)
            })
            .collect();
        s2.announce_appended(old.len(), &have);

        // (a) Locals: pre-append value + one per appended token at its
        // assignment.
        let (z2, _) = s2.assignments();
        let mut expect = old_counts.clone();
        for (d, doc) in new.iter().enumerate() {
            for (j, &w) in doc.tokens.iter().enumerate() {
                expect[w as usize][z2[old.len() + d][j] as usize] += 1;
            }
        }
        for w in 0..5u32 {
            for t in 0..4 {
                assert_eq!(
                    s2.primary().get(w, t),
                    expect[w as usize][t],
                    "cell ({w},{t})"
                );
            }
        }
        assert_eq!(
            s2.primary().grand_total(),
            4 + 5,
            "old tokens + appended tokens"
        );

        // (b) The delta log drains to exactly the new docs' counts.
        let mut mats = s2.matrices();
        let (_, rep) = &mut mats[0];
        let mut logged = 0i64;
        let mut logged_words = Vec::new();
        for (w, row) in rep.drain_deltas() {
            logged_words.push(w);
            logged += match row {
                crate::ps::msg::RowData::Sparse(cells) => {
                    cells.iter().map(|&(_, c)| c as i64).sum::<i64>()
                }
                crate::ps::msg::RowData::Dense(cells) => {
                    cells.iter().map(|&c| c as i64).sum::<i64>()
                }
            };
        }
        assert_eq!(logged, 5, "delta log carries exactly the appended tokens");
        assert_eq!(logged_words, vec![2, 3, 4], "only rows the new docs touch");
    }

    #[test]
    fn projection_dispatch_counts_corrections() {
        let mut cfg = TrainConfig::small_pdp();
        cfg.params.topics = 4;
        let mut rng = Rng::new(3);
        let mut s = ModelSampler::build(&cfg, docs(), 120, None, &mut rng);
        // Wreck the polytope deliberately.
        if let ModelSampler::Pdp(p) = &mut s {
            p.s.inc_local(0, 0, 100);
        }
        let fixed = s.project(crate::config::ProjectionMode::SingleMachine, 0, 1, 7);
        assert!(fixed > 0);
    }
}
