//! The trainer: builds the whole topology (corpus → shards → server group
//! → client workers → scheduler), drives the control loop (progress,
//! stragglers, failure injection, client failover, the 90% rule), and
//! aggregates the report.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::{IterRecord, TrainReport};
use super::worker::{spawn_worker, WorkerCtx, WorkerExit};
use crate::config::{ProjectionMode, TrainConfig};
use crate::corpus::shard::ShardSet;
use crate::ps::msg::{Control, NodeId, Payload};
use crate::ps::network::SimNet;
use crate::ps::scheduler::{Scheduler, SchedulerConfig};
use crate::ps::server::{ServerConfig, ServerGroup};
use crate::ps::snapshot::{self, ClientSnapshot};
use crate::Result;

struct LiveWorker {
    shard: usize,
    client_idx: usize,
    node: NodeId,
    handle: std::thread::JoinHandle<WorkerExit>,
}

/// The top-level training driver.
pub struct Trainer {
    cfg: TrainConfig,
}

impl Trainer {
    /// New trainer for a config.
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg }
    }

    /// Run training to completion and return the aggregated report.
    pub fn run(self) -> Result<TrainReport> {
        let cfg = Arc::new(self.cfg);
        let t0 = Instant::now();

        // 1. Corpus + shards + held-out test set.
        let (corpus, _vocab) = cfg.corpus.generate();
        let (train, test) = corpus.split_test(cfg.test_docs);
        let shards = ShardSet::partition(&train, cfg.cluster.clients);
        let test = Arc::new(test);

        // 2. Transport + server group (+ Algorithm-3 hook when selected).
        let net = SimNet::new(0, cfg.cluster.net.clone());
        let scheduler_node = net.add_node();
        let projection_hook = if cfg.projection == ProjectionMode::OnDemandServer
            && cfg.model.has_table_constraints()
        {
            Some(Arc::new(crate::projection::OnDemandProjection::pdp()))
        } else {
            None
        };
        let snapshot_dir = cfg.cluster.snapshot_dir.clone().or_else(|| {
            cfg.cluster
                .snapshot_every
                .map(|_| std::env::temp_dir().join(format!("hplvm_run_{}", std::process::id())))
        });
        let group = ServerGroup::spawn(
            &net,
            ServerConfig {
                n_servers: cfg.cluster.n_servers(),
                vnodes: cfg.cluster.vnodes,
                row_width: cfg.params.topics,
                snapshot_every: cfg.cluster.snapshot_every,
                snapshot_dir: snapshot_dir.clone(),
                projection: projection_hook,
                heartbeat_every: Duration::from_millis(10),
                // Oversubscribed hosts starve threads for long stretches;
                // silent-slot failover is a last resort. Explicit kills
                // (failure injection) are detected immediately either way.
                liveness_timeout: Duration::from_secs(10),
                // Stamped into every server snapshot so a snapshot
                // directory is self-describing for the serving layer. The
                // v3 table section carries the hyperparameters that give
                // the matrix-1 table counts meaning (PDP/HDP serving).
                meta: snapshot::SnapshotMeta {
                    model: cfg.model.name().to_string(),
                    k: cfg.params.topics as u32,
                    alpha: cfg.params.alpha,
                    beta: cfg.params.beta,
                    vocab_size: cfg.corpus.vocab_size as u32,
                    slot: 0,
                    n_servers: cfg.cluster.n_servers() as u32,
                    vnodes: cfg.cluster.vnodes as u32,
                    iterations: cfg.iterations,
                    // Fresh nonce per run: slot files from different runs
                    // must never merge at serving time, even when every
                    // configured hyperparameter matches.
                    run_id: {
                        let nanos = std::time::SystemTime::now()
                            .duration_since(std::time::UNIX_EPOCH)
                            .map(|d| d.as_nanos() as u64)
                            .unwrap_or(0);
                        nanos ^ ((std::process::id() as u64) << 32)
                    },
                    tables: match cfg.model {
                        crate::config::ModelKind::AliasPdp => Some(snapshot::TableHyper {
                            discount: cfg.params.pdp_discount,
                            concentration: cfg.params.pdp_concentration,
                            root: cfg.params.pdp_gamma,
                        }),
                        crate::config::ModelKind::AliasHdp => Some(snapshot::TableHyper {
                            discount: 0.0,
                            concentration: cfg.params.hdp_b1,
                            root: cfg.params.hdp_b0,
                        }),
                        _ => None,
                    },
                },
            },
        );

        // 3. Optional PJRT evaluation service (shared by all workers; the
        // engine itself lives on its own thread — the xla client is !Send).
        let engine = if cfg.use_pjrt_eval {
            match crate::runtime::EvalService::spawn(std::path::Path::new("artifacts")) {
                Ok(Some(e)) => Some(Arc::new(e)),
                Ok(None) => {
                    crate::warn!("trainer", "no PJRT artifacts; using pure-rust eval");
                    None
                }
                Err(e) => {
                    crate::warn!("trainer", "PJRT unavailable ({e:#}); using pure-rust eval");
                    None
                }
            }
        } else {
            None
        };

        // 4. Workers.
        let records: Arc<Mutex<Vec<IterRecord>>> = Arc::new(Mutex::new(Vec::new()));
        let mut live: Vec<LiveWorker> = Vec::new();
        let spawn = |shard_idx: usize,
                     resume: Option<ClientSnapshot>,
                     slowdown: Duration,
                     net: &SimNet|
         -> LiveWorker {
            let node = net.add_node();
            let ctx = WorkerCtx {
                cfg: cfg.clone(),
                shard: shards.shards[shard_idx].clone(),
                client_idx: shard_idx,
                n_clients: cfg.cluster.clients,
                net: net.clone(),
                node,
                ring: group.ring.clone(),
                slots: group.slots.clone(),
                frozen: group.frozen.clone(),
                scheduler: scheduler_node,
                test: test.clone(),
                records: records.clone(),
                engine: engine.clone(),
                resume,
                snapshot_dir: snapshot_dir.clone(),
                slowdown,
            };
            LiveWorker {
                shard: shard_idx,
                client_idx: shard_idx,
                node,
                handle: spawn_worker(ctx),
            }
        };
        for s in 0..shards.len() {
            let mut slowdown = cfg.cluster.worker_slowdown;
            if cfg.cluster.slow_clients.contains(&s) {
                slowdown = (slowdown * 10).max(Duration::from_millis(2));
            }
            live.push(spawn(s, None, slowdown, &net));
        }

        // 5. Control loop: the scheduler node.
        let mut scheduler = Scheduler::new(
            SchedulerConfig::default(),
            cfg.iterations,
            live.iter().map(|w| w.node).collect(),
        );
        let mut pending_client_kills = cfg.failures.kill_clients.clone();
        let mut pending_server_kills = cfg.failures.kill_servers.clone();
        let mut reassignments = 0u64;
        // Generous watchdog: covers oversubscribed single-core hosts; a
        // healthy run terminates via the 90% quorum long before this.
        let hard_deadline = t0
            + Duration::from_secs(120)
            + Duration::from_millis(cfg.iterations as u64 * shards.total_tokens() as u64 / 500);

        loop {
            // Drain progress reports.
            while let Some(env) = net.recv_timeout(scheduler_node, Duration::from_millis(5)) {
                if let Payload::Progress {
                    shard,
                    iteration,
                    tokens,
                } = env.payload
                {
                    scheduler.record(shard, env.from, iteration, tokens);
                }
            }
            // Backstop for lossy transports: a worker thread that exited
            // normally (node still alive) reached its target even if its
            // final Progress report was dropped.
            for w in &live {
                if w.handle.is_finished() && !net.is_dead(w.node) {
                    scheduler.record(w.shard, w.node, cfg.iterations, 0);
                }
            }
            let median = scheduler.median_progress();

            // Failure injection.
            pending_client_kills.retain(|&(iter, client)| {
                if median >= iter {
                    if let Some(w) = live.iter().find(|w| w.client_idx == client) {
                        net.kill(w.node);
                    }
                    false
                } else {
                    true
                }
            });
            pending_server_kills.retain(|&(iter, slot)| {
                if median >= iter {
                    group.kill_slot(slot);
                    false
                } else {
                    true
                }
            });

            // Straggler policy: kill + reassign (§5.4). Bounded per shard
            // so a host-wide slowdown can't put a shard into a respawn
            // loop that never finishes.
            for shard_idx in scheduler.stragglers() {
                if scheduler.shards()[shard_idx].reassignments >= 2 {
                    continue;
                }
                if let Some(pos) = live.iter().position(|w| w.shard == shard_idx) {
                    let w = &live[pos];
                    net.kill(w.node);
                    // fallthrough: the failover scan below respawns it.
                }
            }

            // Client failover: respawn any dead worker from its snapshot.
            for i in 0..live.len() {
                if net.is_dead(live[i].node)
                    && scheduler.shards()[live[i].shard].iteration < cfg.iterations
                {
                    let shard_idx = live[i].shard;
                    let resume = snapshot_dir
                        .as_ref()
                        .map(|d| d.join(format!("client_shard{shard_idx}.snap")))
                        .and_then(|p| snapshot::read_snapshot(&p))
                        .and_then(|b| snapshot::decode_client(&b))
                        .filter(|s| s.shard == shard_idx);
                    let old = std::mem::replace(
                        &mut live[i],
                        spawn(shard_idx, resume, Duration::ZERO, &net),
                    );
                    let _ = old.handle.join();
                    scheduler.reassign(shard_idx, live[i].node);
                    reassignments += 1;
                }
            }

            if scheduler.quorum_reached() {
                // 90% rule: stop everyone (§6).
                for w in &live {
                    net.send(
                        scheduler_node,
                        w.node,
                        Payload::Control(Control::Terminate),
                    );
                    net.kill(w.node);
                }
                break;
            }
            if Instant::now() > hard_deadline {
                crate::warn!("trainer", "hard deadline hit; terminating run");
                for w in &live {
                    net.kill(w.node);
                }
                break;
            }
        }

        for w in live {
            let _ = w.handle.join();
        }
        let server_corrections = group.total_corrections();
        let net_stats = net.stats();
        group.shutdown();
        if let (Some(dir), None) = (&snapshot_dir, &cfg.cluster.snapshot_dir) {
            // Clean up the auto-created temp dir (keep user-specified ones).
            let _ = std::fs::remove_dir_all(dir);
        }

        let records = records.lock().unwrap();
        Ok(TrainReport::from_records(
            cfg.model.name(),
            &records,
            t0.elapsed().as_secs_f64(),
            net_stats,
            server_corrections,
            reassignments,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;

    fn tiny_cfg(model: ModelKind) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.model = model;
        cfg.params.topics = 8;
        cfg.corpus.n_docs = 120;
        cfg.corpus.vocab_size = 300;
        cfg.corpus.n_topics = 8;
        cfg.corpus.doc_len_mean = 15.0;
        cfg.cluster.clients = 2;
        cfg.cluster.net.base_latency = Duration::from_micros(50);
        cfg.cluster.net.jitter = Duration::from_micros(50);
        cfg.iterations = 4;
        cfg.eval_every = 2;
        cfg.test_docs = 20;
        cfg
    }

    #[test]
    fn lda_end_to_end_converges() {
        let rep = Trainer::new(tiny_cfg(ModelKind::AliasLda)).run().unwrap();
        assert!(rep.per_iteration.len() >= 3, "rows: {}", rep.per_iteration.len());
        assert!(rep.final_perplexity().is_finite());
        assert!(rep.total_tokens > 0);
        // Log-likelihood improves from iteration 1 to the end.
        let first = rep.per_iteration.first().unwrap().log_lik.mean();
        let last = rep.final_log_lik();
        assert!(last > first, "ll {first} -> {last}");
    }

    #[test]
    fn yahoo_end_to_end_runs() {
        let rep = Trainer::new(tiny_cfg(ModelKind::YahooLda)).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn pdp_end_to_end_runs_with_projection() {
        let mut cfg = tiny_cfg(ModelKind::AliasPdp);
        cfg.corpus.model = crate::corpus::generator::GenerativeModel::Pyp;
        cfg.projection = ProjectionMode::Distributed;
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn hdp_end_to_end_runs() {
        let mut cfg = tiny_cfg(ModelKind::AliasHdp);
        cfg.params.topics = 16;
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn client_failure_is_survived() {
        let mut cfg = tiny_cfg(ModelKind::AliasLda);
        cfg.iterations = 6;
        cfg.failures.kill_clients = vec![(2, 1)];
        cfg.cluster.snapshot_every = Some(Duration::from_millis(20));
        // Slow the workers enough that the kill lands mid-training.
        cfg.cluster.worker_slowdown = Duration::from_micros(500);
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.reassignments >= 1, "no failover happened");
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn straggler_is_killed_and_reassigned() {
        let mut cfg = tiny_cfg(ModelKind::AliasLda);
        cfg.iterations = 8;
        cfg.cluster.clients = 3;
        cfg.cluster.snapshot_every = Some(Duration::from_millis(20));
        cfg.cluster.slow_clients = vec![2]; // deterministic straggler
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(
            rep.reassignments >= 1,
            "straggler was never killed/reassigned"
        );
        assert!(rep.final_perplexity().is_finite());
    }
}
