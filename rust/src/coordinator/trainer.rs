//! The one-shot trainer: a thin wrapper over [`TrainSession`] that runs a
//! single segment to `cfg.iterations` and tears the topology down — the
//! legacy entry point every example, bench, and test drives.
//!
//! Everything the trainer used to own (topology build, the control loop,
//! stragglers, failure injection, client failover, the 90% rule) lives in
//! [`super::session`] now; `Trainer::run(cfg)` is exactly
//! `TrainSession::start(cfg, SyntheticSource) → run_to(iterations) →
//! finish()`.

use super::metrics::TrainReport;
use super::session::TrainSession;
use crate::config::TrainConfig;
use crate::corpus::source::SyntheticSource;
use crate::Result;

/// The top-level one-shot training driver.
pub struct Trainer {
    cfg: TrainConfig,
}

impl Trainer {
    /// New trainer for a config.
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg }
    }

    /// Run training to completion and return the aggregated report.
    pub fn run(self) -> Result<TrainReport> {
        let target = self.cfg.iterations;
        let source = SyntheticSource::new(self.cfg.corpus.clone());
        let mut session = TrainSession::start(self.cfg, &source)?;
        session.run_to(target)?;
        session.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, ProjectionMode};
    use std::time::Duration;

    fn tiny_cfg(model: ModelKind) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.model = model;
        cfg.params.topics = 8;
        cfg.corpus.n_docs = 120;
        cfg.corpus.vocab_size = 300;
        cfg.corpus.n_topics = 8;
        cfg.corpus.doc_len_mean = 15.0;
        cfg.cluster.clients = 2;
        cfg.cluster.net.base_latency = Duration::from_micros(50);
        cfg.cluster.net.jitter = Duration::from_micros(50);
        cfg.iterations = 4;
        cfg.eval_every = 2;
        cfg.test_docs = 20;
        cfg
    }

    #[test]
    fn lda_end_to_end_converges() {
        let rep = Trainer::new(tiny_cfg(ModelKind::AliasLda)).run().unwrap();
        assert!(rep.per_iteration.len() >= 3, "rows: {}", rep.per_iteration.len());
        assert!(rep.final_perplexity().is_finite());
        assert!(rep.total_tokens > 0);
        // Log-likelihood improves from iteration 1 to the end.
        let first = rep.per_iteration.first().unwrap().log_lik.mean();
        let last = rep.final_log_lik();
        assert!(last > first, "ll {first} -> {last}");
    }

    #[test]
    fn yahoo_end_to_end_runs() {
        let rep = Trainer::new(tiny_cfg(ModelKind::YahooLda)).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn pdp_end_to_end_runs_with_projection() {
        let mut cfg = tiny_cfg(ModelKind::AliasPdp);
        cfg.corpus.model = crate::corpus::generator::GenerativeModel::Pyp;
        cfg.projection = ProjectionMode::Distributed;
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn hdp_end_to_end_runs() {
        let mut cfg = tiny_cfg(ModelKind::AliasHdp);
        cfg.params.topics = 16;
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn client_failure_is_survived() {
        let mut cfg = tiny_cfg(ModelKind::AliasLda);
        cfg.iterations = 6;
        cfg.failures.kill_clients = vec![(2, 1)];
        cfg.cluster.snapshot_every = Some(Duration::from_millis(20));
        // Slow the workers enough that the kill lands mid-training.
        cfg.cluster.worker_slowdown = Duration::from_micros(500);
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(rep.reassignments >= 1, "no failover happened");
        assert!(rep.final_perplexity().is_finite());
    }

    #[test]
    fn straggler_is_killed_and_reassigned() {
        let mut cfg = tiny_cfg(ModelKind::AliasLda);
        cfg.iterations = 8;
        cfg.cluster.clients = 3;
        cfg.cluster.snapshot_every = Some(Duration::from_millis(20));
        cfg.cluster.slow_clients = vec![2]; // deterministic straggler
        let rep = Trainer::new(cfg).run().unwrap();
        assert!(
            rep.reassignments >= 1,
            "straggler was never killed/reassigned"
        );
        assert!(rep.final_perplexity().is_finite());
    }

    /// The wrapper's degenerate-config path surfaces `validate()` errors
    /// instead of dividing by zero deep in the worker loop.
    #[test]
    fn run_refuses_invalid_configs() {
        let mut cfg = tiny_cfg(ModelKind::AliasLda);
        cfg.cluster.sync_every_docs = 0;
        let err = match Trainer::new(cfg).run() {
            Ok(_) => panic!("invalid config must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("sync_every_docs"), "{err}");
    }
}
