//! The client worker: one thread per shard running the full §5.2 loop —
//! sample documents with the configured sampler, push delta batches
//! through the communication filter, pull fresh rows without blocking
//! (eventual consistency), run client-side projection at the end of each
//! iteration, evaluate perplexity on the paper's cadence, snapshot, and
//! obey the scheduler's control messages.
//!
//! Workers are *segment-scoped* by default: a
//! [`TrainSession`](super::TrainSession) spawns them with a target
//! iteration, and a cleanly exiting worker hands its final sampler state
//! back ([`WorkerOutcome`]) so the next segment — or a checkpoint —
//! continues exactly where it stopped.
//!
//! In **park mode** (the online loop) a worker that reaches its target
//! does not exit: it flushes, writes its barrier-free disk snapshot, and
//! idles on the control channel until the session raises the target
//! ([`Control::RaiseTarget`]) — amortizing the respawn + sampler rebuild
//! over the online loop's many short segments. Parked or running, a
//! worker with a [`DocFeed`](super::feed::DocFeed) absorbs freshly
//! ingested documents at iteration boundaries (lazy sharding): it
//! self-snapshots, rebuilds over old+new docs, restores the pulled
//! replica rows, and re-logs exactly the new documents' counts as
//! pushable deltas ([`ModelSampler::announce_appended`]).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::IterRecord;
use super::model::ModelSampler;
use super::session::TrainObserver;
use crate::config::TrainConfig;
use crate::corpus::doc::Corpus;
use crate::corpus::shard::Shard;
use crate::eval::perplexity::perplexity;
use crate::ps::client::{ClientEvent, PsClient};
use crate::ps::msg::{Control, NodeId};
use crate::ps::network::SimNet;
use crate::ps::snapshot::{self, ClientSnapshot};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Why a worker exited.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerExit {
    /// Reached the target iteration count.
    Finished,
    /// Killed (failure injection or straggler policy).
    Killed,
    /// Told to stop by the scheduler's Terminate broadcast.
    Terminated,
}

/// What a worker thread hands back when it exits.
pub struct WorkerOutcome {
    /// Why it exited.
    pub exit: WorkerExit,
    /// Final sampler state for clean exits — the segment handoff the
    /// session resumes the next segment (or a checkpoint) from. `None`
    /// when the node was killed: the failover path restores from the
    /// barrier-free disk snapshot instead.
    pub state: Option<ClientSnapshot>,
}

/// Everything a worker thread needs.
pub struct WorkerCtx {
    /// Full config (shared).
    pub cfg: Arc<TrainConfig>,
    /// The shard to work.
    pub shard: Shard,
    /// Stable client index (shard index).
    pub client_idx: usize,
    /// Total clients.
    pub n_clients: usize,
    /// Transport handle.
    pub net: SimNet,
    /// This worker's node id.
    pub node: NodeId,
    /// Server ring (shared — an elastic grow re-routes live workers).
    pub ring: crate::ps::ring::SharedRing,
    /// Slot → node binding (shared with the manager).
    pub slots: Arc<std::sync::RwLock<Vec<NodeId>>>,
    /// Freeze flag (server failover in progress).
    pub frozen: Arc<std::sync::atomic::AtomicBool>,
    /// Scheduler node for progress reports.
    pub scheduler: NodeId,
    /// Held-out test corpus.
    pub test: Arc<Corpus>,
    /// Per-iteration metric stream (the session's recording observer,
    /// which forwards to whatever the caller installed).
    pub observer: Arc<dyn TrainObserver>,
    /// Optional PJRT evaluation service (shared; the engine itself lives
    /// on a dedicated thread).
    pub engine: Option<Arc<crate::runtime::EvalService>>,
    /// Resume state (segment handoff or client failover).
    pub resume: Option<ClientSnapshot>,
    /// Client snapshot directory (barrier-free failover snapshots).
    pub snapshot_dir: Option<std::path::PathBuf>,
    /// Artificial per-document slowdown (straggler injection; 0 = none).
    pub slowdown: Duration,
    /// Effective vocabulary size (the loaded corpus's, which may differ
    /// from `cfg.corpus.vocab_size` for file-backed sources).
    pub vocab: usize,
    /// Train until this (absolute) iteration count is completed.
    pub target_iter: u64,
    /// Evaluate test perplexity every this many iterations (the session
    /// can retune it between segments).
    pub eval_every: u64,
    /// Push the (re)initialization deltas so global counts include this
    /// replica. True for fresh starts and failover respawns; false for
    /// segment/checkpoint resumes, where the servers already carry this
    /// shard's counts and re-pushing would double them.
    pub announce_init: bool,
    /// Per-segment RNG salt: a resumed run must not replay segment 1's
    /// random streams.
    pub rng_salt: u64,
    /// Park at the target instead of exiting: idle on the control
    /// channel until [`Control::RaiseTarget`] raises it (or Terminate /
    /// Kill arrives). The session reads segment-end state from the disk
    /// snapshots while a worker is parked, so park mode requires a
    /// `snapshot_dir`.
    pub park: bool,
    /// Lazy-sharding document feed: freshly ingested documents this
    /// worker absorbs at iteration boundaries (and while parked).
    /// `None` = static shard.
    pub feed: Option<Arc<super::feed::DocFeed>>,
}

/// Spawn a worker thread.
pub fn spawn_worker(ctx: WorkerCtx) -> std::thread::JoinHandle<WorkerOutcome> {
    std::thread::Builder::new()
        .name(format!("worker-{}", ctx.client_idx))
        .spawn(move || run_worker(ctx))
        .expect("spawn worker")
}

/// Package an exit: clean exits carry the sampler state for the segment
/// handoff, killed workers carry nothing (disk snapshots cover failover).
fn outcome(
    exit: WorkerExit,
    sampler: &ModelSampler,
    shard: usize,
    iteration: u64,
) -> WorkerOutcome {
    let state = match exit {
        WorkerExit::Killed => None,
        WorkerExit::Finished | WorkerExit::Terminated => {
            let (z, r) = sampler.assignments();
            Some(ClientSnapshot {
                shard,
                iteration,
                z: z.to_vec(),
                r: r.to_vec(),
                replicas: sampler.export_replicas(),
            })
        }
    };
    WorkerOutcome { exit, state }
}

/// Barrier-free client snapshot (§5.4): overwrite this shard's disk
/// snapshot with the sampler's current state.
fn write_disk_snapshot(sampler: &ModelSampler, ctx: &WorkerCtx, iteration: u64) {
    if let Some(dir) = &ctx.snapshot_dir {
        let (z, r) = sampler.assignments();
        let snap = ClientSnapshot {
            shard: ctx.shard.id,
            iteration,
            z: z.to_vec(),
            r: r.to_vec(),
            replicas: sampler.export_replicas(),
        };
        let path = dir.join(format!("client_shard{}.snap", ctx.shard.id));
        let _ = snapshot::write_atomic(&path, &snapshot::encode_client(&snap));
    }
}

/// The per-matrix sorted row keysets of an exported replica set — the
/// `have` argument [`ModelSampler::announce_appended`] consumes.
fn exported_keys(
    replicas: &[(u8, Vec<(u32, crate::ps::msg::RowData)>)],
) -> Vec<(u8, Vec<u32>)> {
    replicas
        .iter()
        .map(|(m, rows)| {
            let mut ws: Vec<u32> = rows.iter().map(|&(w, _)| w).collect();
            ws.sort_unstable();
            (*m, ws)
        })
        .collect()
}

/// Drain the feed and fold the new documents into the live sampler
/// (lazy sharding). Returns the number of documents absorbed.
///
/// The sequence is the appended-document announce: flush outstanding
/// deltas (the rebuild below would discard the log), self-snapshot the
/// assignments and pulled replica rows, rebuild over old+new documents
/// (old `z` survives verbatim, new docs get fresh init), drain the
/// rebuild's init log, restore the pulled rows, then re-log exactly the
/// new documents' counts ([`ModelSampler::announce_appended`]) and push
/// them — so the servers see each ingested token exactly once and the
/// serving tier's freshness doesn't wait for the next sync point.
#[allow(clippy::too_many_arguments)]
fn absorb_feed(
    sampler: &mut ModelSampler,
    client: &mut PsClient,
    ctx: &WorkerCtx,
    seen: &mut [bool],
    shard_words: &mut Vec<u32>,
    iteration: u64,
    rng: &mut Rng,
) -> usize {
    let Some(feed) = &ctx.feed else { return 0 };
    if feed.pending_docs() == 0 {
        return 0;
    }
    for (m, replica) in sampler.matrices() {
        client.push_matrix(m, replica);
    }
    let new_docs = feed.take_pending();
    if new_docs.is_empty() {
        return 0;
    }
    let absorbed = new_docs.len();
    let (z, r) = sampler.assignments();
    let snap = ClientSnapshot {
        shard: ctx.shard.id,
        iteration,
        z: z.to_vec(),
        r: r.to_vec(),
        replicas: sampler.export_replicas(),
    };
    let mut docs = sampler.docs().to_vec();
    for d in &new_docs {
        for &w in &d.tokens {
            if let Some(s) = seen.get_mut(w as usize) {
                if !*s {
                    *s = true;
                    shard_words.push(w);
                }
            }
        }
    }
    docs.extend(new_docs);
    shard_words.sort_unstable();
    *sampler = ModelSampler::build(&ctx.cfg, docs, ctx.vocab, Some(&snap), rng);
    for (_m, replica) in sampler.matrices() {
        let _ = replica.drain_deltas();
    }
    for (m, rows) in &snap.replicas {
        sampler.apply_rows(*m, rows);
    }
    sampler.announce_appended(snap.z.len(), &exported_keys(&snap.replicas));
    for (m, replica) in sampler.matrices() {
        client.push_matrix(m, replica);
    }
    absorbed
}

fn run_worker(ctx: WorkerCtx) -> WorkerOutcome {
    let cfg = &*ctx.cfg;
    let mut rng = Rng::new(cfg.seed)
        .derive(1000 + ctx.node as u64)
        .derive(ctx.rng_salt);
    let start_iteration = ctx.resume.as_ref().map(|s| s.iteration).unwrap_or(0);
    let mut target = ctx.target_iter;
    let mut sampler = ModelSampler::build(
        cfg,
        ctx.shard.docs.clone(),
        ctx.vocab,
        ctx.resume.as_ref(),
        &mut rng,
    );
    let mut client = PsClient::new(
        ctx.net.clone(),
        ctx.node,
        ctx.ring.clone(),
        ctx.slots.clone(),
        ctx.frozen.clone(),
        cfg.cluster.filter,
        cfg.seed ^ (0xF117E8 + ctx.node as u64),
    );

    // The words this shard touches (plus the tables row for HDP) — the
    // pull set. `seen` is kept: absorbed documents extend it in place.
    let mut seen = vec![false; ctx.vocab];
    for d in &ctx.shard.docs {
        for &w in &d.tokens {
            seen[w as usize] = true;
        }
    }
    let mut shard_words: Vec<u32> = (0..ctx.vocab as u32)
        .filter(|&w| seen[w as usize])
        .collect();

    let mut n_docs = ctx.shard.docs.len();
    let mut iteration = start_iteration;
    if ctx.announce_init {
        // Push the (re)initialization deltas so global counts include us.
        for (m, replica) in sampler.matrices() {
            client.push_matrix(m, replica);
        }
    } else {
        // Segment resume: the servers already carry this shard's counts;
        // discard the local rebuild's delta log instead of double-pushing
        // it. Subsequent sampling moves are genuine deltas again.
        for (_m, replica) in sampler.matrices() {
            let _ = replica.drain_deltas();
        }
    }
    // Restore pulled replica rows from the checkpoint, if it carries any.
    // `ModelSampler::build` rebuilds replicas from local `z` alone, which
    // drops the other shards' contributions that earlier pulls had folded
    // in (and that the first post-resume sweeps would otherwise sample
    // against). `apply_rows` overwrites row-wise, so this is exact: the
    // announce path has already pushed its init deltas and the resume
    // path has drained its delta log, so no pending delta is clobbered.
    if let Some(snap) = ctx.resume.as_ref() {
        for (m, rows) in &snap.replicas {
            sampler.apply_rows(*m, rows);
        }
        // Documents appended to the shard since that snapshot was taken
        // (online ingest between segments): their counts are neither on
        // the servers nor in the restored rows — announce exactly them.
        // The announce path above already pushed *every* document's init
        // deltas, so this applies to resumes only.
        if !ctx.announce_init && snap.z.len() < sampler.docs().len() {
            sampler.announce_appended(snap.z.len(), &exported_keys(&snap.replicas));
            for (m, replica) in sampler.matrices() {
                client.push_matrix(m, replica);
            }
        }
    }

    loop {
        if ctx.net.is_dead(ctx.node) {
            return outcome(WorkerExit::Killed, &sampler, ctx.shard.id, iteration);
        }
        // Iteration boundary: absorb freshly ingested documents.
        if absorb_feed(
            &mut sampler,
            &mut client,
            &ctx,
            &mut seen,
            &mut shard_words,
            iteration,
            &mut rng,
        ) > 0
        {
            n_docs = sampler.docs().len();
        }
        if iteration >= target {
            if !ctx.park {
                break;
            }
            // Park at the target (§5.4 online): flush, leave the disk
            // snapshot the session reads segment-end state from, then
            // idle on the control channel. Progress re-announces double
            // as liveness beats *and* cover a final report the lossy
            // transport dropped; a stale raise (target ≤ completed) is
            // ignored.
            for (m, replica) in sampler.matrices() {
                client.push_matrix(m, replica);
            }
            write_disk_snapshot(&sampler, &ctx, iteration);
            let mut raised = false;
            let mut last_report = Instant::now() - Duration::from_secs(1);
            while !raised {
                if ctx.net.is_dead(ctx.node) {
                    return outcome(WorkerExit::Killed, &sampler, ctx.shard.id, iteration);
                }
                if absorb_feed(
                    &mut sampler,
                    &mut client,
                    &ctx,
                    &mut seen,
                    &mut shard_words,
                    iteration,
                    &mut rng,
                ) > 0
                {
                    n_docs = sampler.docs().len();
                    write_disk_snapshot(&sampler, &ctx, iteration);
                }
                for ev in client.drain_responses(Duration::from_millis(2)) {
                    match ev {
                        ClientEvent::Rows(m, rows) => sampler.apply_rows(m, &rows),
                        ClientEvent::Control(Control::Kill) => {
                            return outcome(
                                WorkerExit::Killed,
                                &sampler,
                                ctx.shard.id,
                                iteration,
                            )
                        }
                        ClientEvent::Control(Control::Terminate) => {
                            return outcome(
                                WorkerExit::Terminated,
                                &sampler,
                                ctx.shard.id,
                                iteration,
                            )
                        }
                        ClientEvent::Control(Control::RaiseTarget(t)) => {
                            if t > iteration {
                                target = target.max(t);
                                raised = true;
                            }
                        }
                        ClientEvent::Control(Control::Reroute) => {}
                    }
                }
                if last_report.elapsed() >= Duration::from_millis(25) {
                    client.report_progress(ctx.scheduler, ctx.shard.id, iteration, 0);
                    last_report = Instant::now();
                }
                if !raised {
                    std::thread::sleep(Duration::from_millis(3));
                }
            }
            continue;
        }
        let iter_watch = Instant::now();
        let mut sample_watch = Stopwatch::new();
        let mut tokens = 0u64;

        for d in 0..n_docs {
            sample_watch.start();
            sampler.sample_doc(d, &mut rng);
            sample_watch.stop();
            tokens += sampler.docs()[d].tokens.len() as u64;
            if !ctx.slowdown.is_zero() {
                std::thread::sleep(ctx.slowdown);
            }
            // Eventual-consistency sync point.
            if (d + 1) % cfg.cluster.sync_every_docs == 0 || d + 1 == n_docs {
                if ctx.net.is_dead(ctx.node) {
                    return outcome(WorkerExit::Killed, &sampler, ctx.shard.id, iteration);
                }
                for (m, replica) in sampler.matrices() {
                    client.push_matrix(m, replica);
                }
                // Best-effort drain of anything that already arrived.
                for ev in client.drain_responses(Duration::ZERO) {
                    match ev {
                        ClientEvent::Rows(m, rows) => sampler.apply_rows(m, &rows),
                        ClientEvent::Control(Control::Kill) => {
                            return outcome(
                                WorkerExit::Killed,
                                &sampler,
                                ctx.shard.id,
                                iteration,
                            )
                        }
                        ClientEvent::Control(Control::Terminate) => {
                            return outcome(
                                WorkerExit::Terminated,
                                &sampler,
                                ctx.shard.id,
                                iteration,
                            )
                        }
                        ClientEvent::Control(Control::RaiseTarget(t)) => {
                            target = target.max(t);
                        }
                        ClientEvent::Control(Control::Reroute) => {}
                    }
                }
                // Liveness heartbeat: the session's missed-beat detector
                // declares this worker lost if sync points stop arriving
                // (heartbeat-driven failure detection, not test-code
                // bookkeeping).
                ctx.net
                    .send(ctx.node, ctx.scheduler, crate::ps::msg::Payload::Heartbeat);
            }
        }

        // End-of-iteration: request fresh rows for the shard vocabulary
        // (and the tables row), give them one latency window to arrive.
        client.request_rows(super::model::MATRIX_PRIMARY, &shard_words);
        if matches!(
            cfg.model,
            crate::config::ModelKind::AliasPdp | crate::config::ModelKind::AliasHdp
        ) {
            let secondary: Vec<u32> = match cfg.model {
                crate::config::ModelKind::AliasHdp => vec![0],
                _ => shard_words.clone(),
            };
            client.request_rows(super::model::MATRIX_TABLES, &secondary);
        }
        let wait = cfg.cluster.net.base_latency * 4 + Duration::from_millis(2);
        for ev in client.drain_responses(wait) {
            match ev {
                ClientEvent::Rows(m, rows) => sampler.apply_rows(m, &rows),
                ClientEvent::Control(Control::Kill) => {
                    return outcome(WorkerExit::Killed, &sampler, ctx.shard.id, iteration)
                }
                ClientEvent::Control(Control::Terminate) => {
                    return outcome(WorkerExit::Terminated, &sampler, ctx.shard.id, iteration)
                }
                ClientEvent::Control(Control::RaiseTarget(t)) => {
                    target = target.max(t);
                }
                ClientEvent::Control(Control::Reroute) => {}
            }
        }

        // Client-side projection (Algorithms 1/2) + push the corrections.
        let corrections = sampler.project(
            cfg.projection,
            ctx.client_idx,
            ctx.n_clients,
            cfg.seed ^ 0x9909,
        );
        if corrections > 0 {
            for (m, replica) in sampler.matrices() {
                client.push_matrix(m, replica);
            }
        }

        iteration += 1;

        // Metrics: perplexity every `eval_every`, log-lik every iteration.
        // Segment ends always evaluate, so every SegmentReport carries a
        // final perplexity.
        let perp = if iteration % ctx.eval_every == 0 || iteration == target {
            let rep = perplexity(
                sampler.view(),
                &ctx.test,
                3,
                ctx.engine
                    .as_deref()
                    .map(|e| e as &dyn crate::runtime::DenseEval),
            );
            Some(rep.perplexity)
        } else {
            None
        };
        let (z, _) = sampler.assignments();
        let avg_ll = crate::eval::loglik::mean_token_log_likelihood(
            sampler.view(),
            sampler.docs(),
            z,
        );
        ctx.observer.on_iteration(&IterRecord {
            shard: ctx.shard.id,
            client_idx: ctx.client_idx,
            iteration,
            secs: iter_watch.elapsed().as_secs_f64(),
            sample_secs: sample_watch.elapsed().as_secs_f64(),
            tokens,
            perplexity: perp,
            avg_ll,
            topics_per_word: sampler.topics_per_word(),
            acceptance: sampler.acceptance_rate(),
            corrections,
        });
        client.report_progress(ctx.scheduler, ctx.shard.id, iteration, tokens);

        // Barrier-free client snapshot (§5.4).
        write_disk_snapshot(&sampler, &ctx, iteration);
    }

    // Flush remaining deltas before leaving.
    for (m, replica) in sampler.matrices() {
        client.push_matrix(m, replica);
    }
    outcome(WorkerExit::Finished, &sampler, ctx.shard.id, iteration)
}
