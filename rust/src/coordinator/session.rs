//! The resumable training session: the paper's long-lived production job
//! as an API.
//!
//! The one-shot `Trainer::run(cfg)` entry point builds a whole cluster,
//! trains to a fixed iteration count, and throws the topology away. Real
//! runs are different: hundreds of billions of tokens over days, driven
//! by a persistent pipeline that pauses, inspects, checkpoints, restarts
//! on fresh machines, and keeps serving snapshots flowing to the
//! inference tier. A [`TrainSession`] is that shape:
//!
//! * [`TrainSession::start`] builds the topology **once** — corpus (via
//!   any [`CorpusSource`]), shards, [`SimNet`], server group, eval
//!   engine — and keeps it alive across **segments**.
//! * [`TrainSession::run_for`] / [`TrainSession::run_to`] drive training
//!   in segments; each returns a [`SegmentReport`] and leaves the cluster
//!   hot. Between segments the caller can inspect metrics, retune the
//!   eval cadence ([`TrainSession::set_eval_every`]), or checkpoint.
//! * [`TrainSession::checkpoint`] snapshots the *entire cluster* into a
//!   directory: every server slot store (acknowledged
//!   [`Payload::SnapshotReq`] round-trips), every shard's client state,
//!   and a [`SessionMeta`] record (run id, iteration, RNG epoch, config).
//! * [`TrainSession::resume`] rebuilds a session from such a directory in
//!   a fresh process and keeps training **under the same `run_id`** — so
//!   the serving layer's same-run merge check accepts the resumed run's
//!   snapshots as continuations, not strangers.
//! * Per-iteration metrics stream through a [`TrainObserver`] as they
//!   happen (replacing the old shared-`Vec` sink), so a CLI can print
//!   live progress and embedders can watch convergence without polling.
//! * **Online mode** ([`TrainSession::set_park_workers`] +
//!   [`TrainSession::ingest`] + [`TrainSession::run_online`]): workers
//!   park at the segment target instead of exiting, the next segment
//!   raises their target in place, and freshly arrived documents are
//!   round-robined onto shards and absorbed at iteration boundaries —
//!   the substrate the [`pipeline`](crate::pipeline) tier drives.
//!
//! `Trainer::run` survives as a one-segment wrapper over this API.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::feed::DocFeed;
use super::metrics::{IterRecord, RecordFold, TrainReport};
use super::worker::{spawn_worker, WorkerCtx, WorkerExit};
use crate::config::{ProjectionMode, TrainConfig};
use crate::corpus::doc::Corpus;
use crate::corpus::shard::ShardSet;
use crate::corpus::source::{CorpusSource, FileSource, SyntheticSource};
use crate::ps::msg::{Control, NodeId, Payload};
use crate::ps::network::SimNet;
use crate::ps::scheduler::{Scheduler, SchedulerConfig};
use crate::ps::server::{Elastic, HandoffStats, ServerConfig, ServerGroup};
use crate::ps::snapshot::{self, ClientSnapshot, SessionMeta, Store};
use crate::util::json::Json;
use crate::Result;

/// Streaming consumer of training metrics. Implementations must be cheap
/// and non-blocking — callbacks run on the worker threads' hot path.
pub trait TrainObserver: Send + Sync {
    /// One worker completed one iteration.
    fn on_iteration(&self, rec: &IterRecord) {
        let _ = rec;
    }

    /// One segment completed (fires on the thread driving the session).
    fn on_segment(&self, seg: &SegmentReport) {
        let _ = seg;
    }
}

/// The default observer: ignores everything.
pub struct NullObserver;

impl TrainObserver for NullObserver {}

/// An observer printing live progress to stdout — eval-iteration
/// perplexities as they stream in, and a summary line per segment (the
/// CLI's `train` live view).
pub struct PrintObserver;

impl TrainObserver for PrintObserver {
    fn on_iteration(&self, rec: &IterRecord) {
        if let Some(p) = rec.perplexity {
            println!(
                "  iter {:>4} shard {:>2}: perplexity {:>9.1} | {:>6} tokens | {:.3}s",
                rec.iteration, rec.shard, p, rec.tokens, rec.secs
            );
        }
    }

    fn on_segment(&self, seg: &SegmentReport) {
        println!(
            "segment {}..{} done: final perplexity {:.1}, {:.0} tokens/s",
            seg.start_iteration,
            seg.end_iteration,
            seg.report.final_perplexity(),
            seg.report.tokens_per_sec,
        );
    }
}

/// The session's internal metric sink: folds every record into bounded
/// running aggregates — one cumulative [`RecordFold`], one for the
/// current segment — and forwards each record to the user's observer.
/// It retains **no** raw records (the old shared-`Vec` sink grew by
/// `clients × iterations` records); a long chaos soak stays
/// O(distinct iterations) in memory no matter how long it runs.
struct RecordingObserver {
    total: Mutex<RecordFold>,
    segment: Mutex<RecordFold>,
    user: Arc<dyn TrainObserver>,
}

impl RecordingObserver {
    fn new(user: Arc<dyn TrainObserver>) -> RecordingObserver {
        RecordingObserver {
            total: Mutex::new(RecordFold::new()),
            segment: Mutex::new(RecordFold::new()),
            user,
        }
    }

    /// Reset the per-segment fold (start of every live segment).
    fn begin_segment(&self) {
        *self.segment.lock().unwrap() = RecordFold::new();
    }

    fn segment_fold(&self) -> RecordFold {
        self.segment.lock().unwrap().clone()
    }

    fn total_fold(&self) -> RecordFold {
        self.total.lock().unwrap().clone()
    }

    /// Raw records currently buffered — identically zero: records fold
    /// into aggregates on arrival and are never retained. The probe the
    /// bounded-memory test pins.
    fn records_held(&self) -> usize {
        0
    }
}

impl TrainObserver for RecordingObserver {
    fn on_iteration(&self, rec: &IterRecord) {
        self.total.lock().unwrap().push(rec);
        self.segment.lock().unwrap().push(rec);
        self.user.on_iteration(rec);
    }
}

/// What one [`TrainSession::run_for`] / [`run_to`](TrainSession::run_to)
/// call produced.
#[derive(Clone, Debug)]
pub struct SegmentReport {
    /// Completed iterations when the segment started.
    pub start_iteration: u64,
    /// Iterations completed when it ended — the target when the 90%
    /// quorum fired, the honest median progress if the hard-deadline
    /// watchdog terminated the segment early.
    pub end_iteration: u64,
    /// Metrics aggregated over this segment only (net/corrections are
    /// segment deltas).
    pub report: TrainReport,
}

struct LiveWorker {
    shard: usize,
    node: NodeId,
    handle: std::thread::JoinHandle<super::worker::WorkerOutcome>,
}

/// State restored from a checkpoint directory (the resume path).
struct Restored {
    run_id: u64,
    iteration: u64,
    epoch: u64,
    stores: Vec<Store>,
    states: Vec<Option<ClientSnapshot>>,
    corpus_file: Option<String>,
    vocab_file: Option<String>,
}

/// A long-lived training run: topology built once, driven in segments,
/// checkpointable and resumable. See the module docs for the lifecycle.
pub struct TrainSession {
    cfg: Arc<TrainConfig>,
    /// Effective vocabulary (the loaded corpus's — may exceed
    /// `cfg.corpus.vocab_size` for file sources).
    vocab: usize,
    corpus_file: Option<String>,
    vocab_file: Option<String>,
    net: SimNet,
    scheduler_node: NodeId,
    group: Option<ServerGroup>,
    engine: Option<Arc<crate::runtime::EvalService>>,
    shards: ShardSet,
    test: Arc<Corpus>,
    snapshot_dir: Option<PathBuf>,
    /// The snapshot dir was auto-created under the temp dir (cleanup
    /// candidate at [`finish`](Self::finish)).
    auto_snapshot_dir: bool,
    /// Directories an explicit checkpoint was written into — never
    /// deleted by the cleanup, even when one of them *is* the
    /// auto-created temp dir.
    checkpoint_dirs: Vec<PathBuf>,
    run_id: u64,
    /// Completed (quorum) iterations.
    iteration: u64,
    /// Segment counter — salts per-segment worker RNG streams.
    epoch: u64,
    /// Checkpoint counter — each [`checkpoint`](Self::checkpoint) call
    /// gets a fresh epoch, and only `SnapshotAck`s echoing it count
    /// toward that checkpoint's quorum (a duplicate or stale ack can
    /// never satisfy the quorum for a slot that didn't serialize).
    snapshot_epoch: u64,
    eval_every: u64,
    /// Per-shard sampler state carried across segments.
    states: Vec<Option<ClientSnapshot>>,
    sink: Arc<RecordingObserver>,
    user_observer: Arc<dyn TrainObserver>,
    pending_client_kills: Vec<(u64, usize)>,
    pending_server_kills: Vec<(u64, usize)>,
    reassignments: u64,
    /// Live `(shard, node)` pairs, refreshed at spawn and failover —
    /// the chaos harness's kill-target directory. Empty between
    /// segments.
    live_workers: Arc<RwLock<Vec<(usize, NodeId)>>>,
    /// Median completed-iteration probe, stored by the control loop so
    /// observers on other threads can pace fault injection.
    progress: Arc<AtomicU64>,
    /// Park workers at the segment target instead of terminating them
    /// (the online loop) — see [`set_park_workers`](Self::set_park_workers).
    park_workers: bool,
    /// Workers parked at the previous segment's target, reusable by the
    /// next [`run_to`](Self::run_to) via a target raise.
    parked: Vec<LiveWorker>,
    /// Per-shard lazy-sharding feeds: [`ingest`](Self::ingest) pushes
    /// here, live workers drain at iteration boundaries.
    feeds: Vec<Arc<DocFeed>>,
    /// Documents the session started with (before any online ingest).
    base_docs: u64,
    /// Round-robin shard cursor for ingested documents — continues
    /// [`ShardSet::partition`]'s `i % n_shards` rule across the
    /// startup/online boundary.
    ingest_cursor: usize,
    t0: Instant,
}

fn fresh_run_id() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    nanos ^ ((std::process::id() as u64) << 32)
}

impl TrainSession {
    /// Build the topology and return an idle session at iteration 0.
    /// Validates `cfg` ([`TrainConfig::validate`]) before anything is
    /// spawned.
    pub fn start(cfg: TrainConfig, source: &dyn CorpusSource) -> Result<TrainSession> {
        Self::start_with_observer(cfg, source, Arc::new(NullObserver))
    }

    /// [`start`](Self::start) with a streaming [`TrainObserver`].
    pub fn start_with_observer(
        cfg: TrainConfig,
        source: &dyn CorpusSource,
        observer: Arc<dyn TrainObserver>,
    ) -> Result<TrainSession> {
        Self::build(cfg, source, observer, None)
    }

    /// Rebuild a session from a [`checkpoint`](Self::checkpoint)
    /// directory — in this or a fresh process — and continue training
    /// under the **same `run_id`**: snapshots the resumed run writes
    /// still merge as the same run at serving time. The corpus is
    /// reacquired from the checkpoint's recorded source (the docword file
    /// for [`FileSource`] runs, the regenerated synthetic corpus
    /// otherwise) and must be unchanged — the checkpointed topic
    /// assignments index into its documents.
    pub fn resume(dir: &Path) -> Result<TrainSession> {
        Self::resume_with_observer(dir, Arc::new(NullObserver))
    }

    /// [`resume`](Self::resume) with a streaming [`TrainObserver`].
    pub fn resume_with_observer(
        dir: &Path,
        observer: Arc<dyn TrainObserver>,
    ) -> Result<TrainSession> {
        let meta_path = dir.join(snapshot::SESSION_META_NAME);
        let bytes = snapshot::read_snapshot(&meta_path).ok_or_else(|| {
            anyhow::anyhow!(
                "no session checkpoint at {} — was this directory written by \
                 TrainSession::checkpoint?",
                meta_path.display()
            )
        })?;
        let sm = snapshot::decode_session(&bytes)
            .ok_or_else(|| anyhow::anyhow!("corrupt session meta {}", meta_path.display()))?;
        let json = Json::parse(&sm.config_json)
            .map_err(|e| anyhow::anyhow!("corrupt checkpoint config JSON: {e}"))?;
        let mut cfg = TrainConfig::default();
        cfg.apply_json(&json)
            .map_err(|e| anyhow::anyhow!("bad checkpoint config: {e}"))?;
        cfg.seed = sm.seed;

        // Server slot stores: every slot of the checkpointed ring must be
        // present and carry the session's run id.
        let n_servers = cfg.cluster.n_servers();
        let mut stores = Vec::with_capacity(n_servers);
        for slot in 0..n_servers {
            let name = snapshot::slot_snapshot_name(slot);
            anyhow::ensure!(
                dir.join(&name).exists(),
                "partial checkpoint: missing {}",
                dir.join(&name).display()
            );
            // Any format v1–v4: full dumps load directly, a v4 manifest
            // replays its segment set (torn segments are hard errors).
            let (meta, store, _generation) = snapshot::load_slot_file(dir, &name)?;
            if let Some(meta) = meta {
                anyhow::ensure!(
                    meta.run_id == sm.run_id,
                    "checkpoint mixes runs: slot {slot} carries run {:#x}, session \
                     meta says {:#x}",
                    meta.run_id,
                    sm.run_id
                );
            }
            stores.push(store);
        }

        // Client states: best-effort — a missing shard file resumes that
        // shard from scratch (the paper's roll-only-yourself-back).
        let states = (0..cfg.cluster.clients)
            .map(|i| {
                snapshot::read_snapshot(&dir.join(format!("client_shard{i}.snap")))
                    .and_then(|b| snapshot::decode_client(&b))
                    .filter(|s| s.shard == i)
            })
            .collect();

        let source: Box<dyn CorpusSource> = match &sm.corpus_file {
            Some(p) => {
                let mut src = FileSource::new(p);
                if let Some(v) = &sm.vocab_file {
                    src = src.with_vocab(v);
                }
                Box::new(src)
            }
            None => Box::new(SyntheticSource::new(cfg.corpus.clone())),
        };
        Self::build(
            cfg,
            source.as_ref(),
            observer,
            Some(Restored {
                run_id: sm.run_id,
                iteration: sm.iteration,
                epoch: sm.epoch,
                stores,
                states,
                corpus_file: sm.corpus_file,
                vocab_file: sm.vocab_file,
            }),
        )
    }

    fn build(
        cfg: TrainConfig,
        source: &dyn CorpusSource,
        observer: Arc<dyn TrainObserver>,
        restored: Option<Restored>,
    ) -> Result<TrainSession> {
        cfg.validate().map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
        let (corpus_file, vocab_file) = match &restored {
            Some(r) => (r.corpus_file.clone(), r.vocab_file.clone()),
            None => (
                source.file().map(|p| p.display().to_string()),
                source.vocab_file().map(|p| p.display().to_string()),
            ),
        };
        let corpus = source.load()?;
        let vocab = corpus.vocab_size;
        let (train, test) = corpus.split_test(cfg.test_docs);
        anyhow::ensure!(
            !train.docs.is_empty(),
            "corpus from {} has no training documents left after the \
             {}-document test split",
            source.describe(),
            cfg.test_docs
        );
        let shards = ShardSet::partition(&train, cfg.cluster.clients);
        let test = Arc::new(test);
        let run_id = restored.as_ref().map(|r| r.run_id).unwrap_or_else(fresh_run_id);

        let net = SimNet::new(0, cfg.cluster.net.clone());
        let scheduler_node = net.add_node();
        let projection_hook = if cfg.projection == ProjectionMode::OnDemandServer
            && cfg.model.has_table_constraints()
        {
            Some(Arc::new(crate::projection::OnDemandProjection::pdp()))
        } else {
            None
        };
        let auto_snapshot_dir =
            cfg.cluster.snapshot_dir.is_none() && cfg.cluster.snapshot_every.is_some();
        let snapshot_dir = cfg.cluster.snapshot_dir.clone().or_else(|| {
            cfg.cluster.snapshot_every.map(|_| {
                std::env::temp_dir().join(format!(
                    "hplvm_run_{}_{run_id:016x}",
                    std::process::id()
                ))
            })
        });
        let (stores, states, iteration, epoch) = match restored {
            Some(r) => (r.stores, r.states, r.iteration, r.epoch),
            None => (Vec::new(), vec![None; shards.len()], 0, 0),
        };
        anyhow::ensure!(
            states.len() == shards.len(),
            "checkpoint has {} client shards but the config builds {}",
            states.len(),
            shards.len()
        );
        let group = ServerGroup::spawn_with_stores(
            &net,
            ServerConfig {
                n_servers: cfg.cluster.n_servers(),
                vnodes: cfg.cluster.vnodes,
                row_width: cfg.params.topics,
                snapshot_every: cfg.cluster.snapshot_every,
                snapshot_dir: snapshot_dir.clone(),
                projection: projection_hook,
                heartbeat_every: Duration::from_millis(10),
                // Oversubscribed hosts starve threads for long stretches;
                // silent-slot failover is a last resort. Explicit kills
                // (failure injection) are detected immediately either way.
                liveness_timeout: Duration::from_secs(10),
                // Stamped into every server snapshot so a snapshot
                // directory is self-describing for the serving layer. The
                // v3 table section carries the table-side hyperparameters
                // (PDP/HDP serving); the run_id is the session's — stable
                // across checkpoint/resume, fresh per started session.
                meta: snapshot::SnapshotMeta {
                    model: cfg.model.name().to_string(),
                    k: cfg.params.topics as u32,
                    alpha: cfg.params.alpha,
                    beta: cfg.params.beta,
                    vocab_size: vocab as u32,
                    slot: 0,
                    n_servers: cfg.cluster.n_servers() as u32,
                    vnodes: cfg.cluster.vnodes as u32,
                    iterations: cfg.iterations,
                    run_id,
                    tables: match cfg.model {
                        crate::config::ModelKind::AliasPdp => Some(snapshot::TableHyper {
                            discount: cfg.params.pdp_discount,
                            concentration: cfg.params.pdp_concentration,
                            root: cfg.params.pdp_gamma,
                        }),
                        crate::config::ModelKind::AliasHdp => Some(snapshot::TableHyper {
                            discount: 0.0,
                            concentration: cfg.params.hdp_b1,
                            root: cfg.params.hdp_b0,
                        }),
                        _ => None,
                    },
                },
            },
            stores,
        );

        // Optional PJRT evaluation service (shared by all workers; the
        // engine itself lives on its own thread — the xla client is !Send).
        let engine = if cfg.use_pjrt_eval {
            match crate::runtime::EvalService::spawn(std::path::Path::new("artifacts")) {
                Ok(Some(e)) => Some(Arc::new(e)),
                Ok(None) => {
                    crate::warn!("session", "no PJRT artifacts; using pure-rust eval");
                    None
                }
                Err(e) => {
                    crate::warn!("session", "PJRT unavailable ({e:#}); using pure-rust eval");
                    None
                }
            }
        } else {
            None
        };

        let eval_every = cfg.eval_every;
        let pending_client_kills = cfg.failures.kill_clients.clone();
        let pending_server_kills = cfg.failures.kill_servers.clone();
        let feeds = (0..shards.len()).map(|_| Arc::new(DocFeed::new())).collect();
        let base_docs = shards.shards.iter().map(|s| s.docs.len() as u64).sum();
        Ok(TrainSession {
            sink: Arc::new(RecordingObserver::new(observer.clone())),
            user_observer: observer,
            cfg: Arc::new(cfg),
            vocab,
            corpus_file,
            vocab_file,
            net,
            scheduler_node,
            group: Some(group),
            engine,
            shards,
            test,
            snapshot_dir,
            auto_snapshot_dir,
            checkpoint_dirs: Vec::new(),
            run_id,
            iteration,
            epoch,
            snapshot_epoch: 0,
            eval_every,
            states,
            pending_client_kills,
            pending_server_kills,
            reassignments: 0,
            live_workers: Arc::new(RwLock::new(Vec::new())),
            progress: Arc::new(AtomicU64::new(iteration)),
            park_workers: false,
            parked: Vec::new(),
            feeds,
            base_docs,
            // Continue the round-robin where the startup partition left
            // off: ingested doc j lands on shard (base + j) % n, exactly
            // where `ShardSet::partition` over the concatenated corpus
            // would have put it.
            ingest_cursor: base_docs as usize,
            t0: Instant::now(),
        })
    }

    /// Completed (quorum) iterations.
    pub fn iteration(&self) -> u64 {
        self.iteration
    }

    /// The run nonce stamped into every snapshot of this session —
    /// preserved across [`checkpoint`](Self::checkpoint) /
    /// [`resume`](Self::resume).
    pub fn run_id(&self) -> u64 {
        self.run_id
    }

    /// Effective vocabulary size (the loaded corpus's).
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The directory periodic barrier-free snapshots go to (configured,
    /// or the auto-created temp directory when only a cadence was set).
    pub fn snapshot_dir(&self) -> Option<&Path> {
        self.snapshot_dir.as_deref()
    }

    /// The session configuration.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Retune the evaluation cadence for subsequent segments.
    pub fn set_eval_every(&mut self, every: u64) -> Result<()> {
        anyhow::ensure!(every >= 1, "eval_every must be ≥ 1");
        self.eval_every = every;
        Ok(())
    }

    /// A clone of the simulated transport — chaos threads kill nodes and
    /// spike latency/loss ([`SimNet::set_degraded`]) through it while a
    /// segment runs.
    pub fn sim_net(&self) -> SimNet {
        self.net.clone()
    }

    /// Live worker `(shard, node)` pairs, refreshed as segments spawn
    /// workers and failovers rebind shards — the chaos harness picks
    /// worker kill targets here. Empty between segments.
    pub fn worker_nodes(&self) -> Arc<RwLock<Vec<(usize, NodeId)>>> {
        self.live_workers.clone()
    }

    /// Median-progress probe (completed iterations across shards),
    /// updated live by the segment control loop — chaos schedules pace
    /// their faults against it instead of wall-clock guesses.
    pub fn progress_probe(&self) -> Arc<AtomicU64> {
        self.progress.clone()
    }

    /// A cloneable elastic-membership handle over the server group:
    /// grow the ring or kill slots from another thread mid-segment.
    pub fn elastic(&self) -> Result<Elastic> {
        match &self.group {
            Some(g) => Ok(g.elastic()),
            None => anyhow::bail!("session already finished"),
        }
    }

    /// Grow the server ring `N → N+1` with drain-and-handoff (live
    /// clients re-route on their next push/pull) — see [`Elastic::grow`]
    /// for the protocol and the returned accounting.
    pub fn grow_servers(&self) -> Result<HandoffStats> {
        Ok(self.elastic()?.grow())
    }

    /// Worker reassignments so far (failovers + straggler kills).
    pub fn reassignments(&self) -> u64 {
        self.reassignments
    }

    /// Park worker threads at the segment target instead of terminating
    /// them (the online train-while-serve loop). Parked workers idle on
    /// the control channel; the next segment raises their target
    /// ([`Control::RaiseTarget`]) instead of paying a thread respawn and
    /// sampler rebuild — which matters when segments are one or two
    /// sweeps long. Requires a snapshot directory: with no thread join
    /// at segment end, the session reads each shard's segment-end state
    /// from its barrier-free disk snapshot. Turning park mode *off*
    /// retires any currently parked workers (terminate + join).
    pub fn set_park_workers(&mut self, park: bool) -> Result<()> {
        if park {
            anyhow::ensure!(
                self.snapshot_dir.is_some(),
                "park mode requires a snapshot directory (set \
                 cluster.snapshot_every or cluster.snapshot_dir): parked \
                 workers hand segment-end state back via disk snapshots"
            );
        }
        self.park_workers = park;
        if !park {
            self.retire_parked();
        }
        Ok(())
    }

    /// Ingest freshly arrived documents into the live session (online
    /// training). Documents are validated against the session vocabulary,
    /// then round-robined onto shards continuing the startup partition's
    /// `i % n_shards` rule; live (or parked) workers absorb them at their
    /// next iteration boundary, and the counts reach the servers without
    /// waiting for a segment boundary. Empty documents are dropped, like
    /// the corpus readers drop them. Returns the number accepted.
    pub fn ingest(&mut self, docs: &[crate::corpus::doc::Document]) -> Result<usize> {
        anyhow::ensure!(self.group.is_some(), "session already finished");
        for d in docs {
            for &w in &d.tokens {
                anyhow::ensure!(
                    (w as usize) < self.vocab,
                    "ingested document carries word id {w} outside the \
                     session vocabulary ({})",
                    self.vocab
                );
            }
        }
        let n_shards = self.shards.len();
        let mut accepted = 0usize;
        for d in docs {
            if d.tokens.is_empty() {
                continue;
            }
            let s = self.ingest_cursor % n_shards;
            self.ingest_cursor += 1;
            self.shards.shards[s].docs.push(d.clone());
            self.shards.shards[s].tokens += d.tokens.len();
            self.feeds[s].push(d.clone());
            accepted += 1;
        }
        Ok(accepted)
    }

    /// One online mini-batch step: ensure park mode, then run a short
    /// segment of `sweeps` Gibbs sweeps (at least one) over everything
    /// ingested so far. The pipeline driver alternates
    /// [`ingest`](Self::ingest) and `run_online`.
    pub fn run_online(&mut self, sweeps: u64) -> Result<SegmentReport> {
        if !self.park_workers {
            self.set_park_workers(true)?;
        }
        self.run_for(sweeps.max(1))
    }

    /// Total documents this session has ever been given: the startup
    /// corpus plus everything [`ingest`](Self::ingest)ed.
    pub fn docs_ingested(&self) -> u64 {
        self.base_docs + self.feeds.iter().map(|f| f.pushed_docs()).sum::<u64>()
    }

    /// Documents workers have actually absorbed into their samplers (≤
    /// [`docs_ingested`](Self::docs_ingested); the difference is queued
    /// on feeds).
    pub fn docs_absorbed(&self) -> u64 {
        self.base_docs + self.feeds.iter().map(|f| f.absorbed_docs()).sum::<u64>()
    }

    /// Re-read every shard's barrier-free disk snapshot into the
    /// session's carried states (the park-mode segment handoff).
    fn refresh_states_from_disk(&mut self) {
        let Some(dir) = self.snapshot_dir.clone() else { return };
        for i in 0..self.states.len() {
            if let Some(s) = snapshot::read_snapshot(&dir.join(format!("client_shard{i}.snap")))
                .and_then(|b| snapshot::decode_client(&b))
                .filter(|s| s.shard == i)
            {
                self.states[i] = Some(s);
            }
        }
    }

    /// Terminate and join any parked workers, folding their final state
    /// into the session (the join-based handoff — fresher than the last
    /// disk snapshot if the worker absorbed documents while parked).
    fn retire_parked(&mut self) {
        let live = std::mem::take(&mut self.parked);
        if live.is_empty() {
            return;
        }
        let grace = Instant::now() + Duration::from_secs(15);
        let mut next_send = Instant::now();
        while !live.iter().all(|w| w.handle.is_finished()) {
            if Instant::now() > grace {
                for w in &live {
                    self.net.kill(w.node);
                }
                break;
            }
            if Instant::now() >= next_send {
                // Re-sent on a cadence: the transport may drop any copy.
                for w in &live {
                    if !w.handle.is_finished() {
                        self.net.send(
                            self.scheduler_node,
                            w.node,
                            Payload::Control(Control::Terminate),
                        );
                    }
                }
                next_send = Instant::now() + Duration::from_millis(200);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        for w in live {
            if let Ok(out) = w.handle.join() {
                if let Some(state) = out.state {
                    self.states[w.shard] = Some(state);
                }
            }
        }
        self.live_workers.write().unwrap().clear();
    }

    /// Train `n` more iterations (one segment).
    pub fn run_for(&mut self, n: u64) -> Result<SegmentReport> {
        self.run_to(self.iteration.saturating_add(n))
    }

    /// Train until `target` iterations are completed (90% quorum, like
    /// the one-shot trainer). A target at or below the current iteration
    /// is a no-op segment.
    pub fn run_to(&mut self, target: u64) -> Result<SegmentReport> {
        anyhow::ensure!(
            self.group.is_some(),
            "session already finished — start or resume a new one"
        );
        let start_iteration = self.iteration;
        if target <= start_iteration {
            let seg = SegmentReport {
                start_iteration,
                end_iteration: start_iteration,
                report: TrainReport::from_records(
                    self.cfg.model.name(),
                    &[],
                    0.0,
                    (0, 0, 0, 0),
                    0,
                    0,
                ),
            };
            // Observers hear about every segment, no-ops included, so
            // their segment counts match the reports the caller received.
            self.user_observer.on_segment(&seg);
            return Ok(seg);
        }
        self.epoch += 1;
        let seg_start = Instant::now();
        self.sink.begin_segment();
        let net0 = self.net.stats();
        let corr0 = self.group.as_ref().unwrap().total_corrections();
        let reassign0 = self.reassignments;

        let cfg = self.cfg.clone();
        let group = self.group.as_ref().unwrap();
        let observer: Arc<dyn TrainObserver> = self.sink.clone();
        let spawn = |shard_idx: usize,
                     resume: Option<ClientSnapshot>,
                     slowdown: Duration,
                     announce_init: bool,
                     net: &SimNet|
         -> LiveWorker {
            let node = net.add_node();
            let ctx = WorkerCtx {
                cfg: cfg.clone(),
                shard: self.shards.shards[shard_idx].clone(),
                client_idx: shard_idx,
                n_clients: cfg.cluster.clients,
                net: net.clone(),
                node,
                ring: group.ring.clone(),
                slots: group.slots.clone(),
                frozen: group.frozen.clone(),
                scheduler: self.scheduler_node,
                test: self.test.clone(),
                observer: observer.clone(),
                engine: self.engine.clone(),
                resume,
                snapshot_dir: self.snapshot_dir.clone(),
                slowdown,
                vocab: self.vocab,
                target_iter: target,
                eval_every: self.eval_every,
                announce_init,
                rng_salt: self.epoch,
                park: self.park_workers,
                feed: Some(self.feeds[shard_idx].clone()),
            };
            LiveWorker {
                shard: shard_idx,
                node,
                handle: spawn_worker(ctx),
            }
        };

        // Fresh runs announce their replica-initialization deltas; a
        // resumed segment must not — the servers already carry every
        // shard's counts (double-pushing would double the statistics).
        let announce = start_iteration == 0;
        let mut live: Vec<LiveWorker> = std::mem::take(&mut self.parked);
        let reused = !live.is_empty();
        if reused {
            // Parked workers resume in place: raise their target instead
            // of paying a respawn + sampler rebuild per segment. The
            // raise is re-sent on a cadence in the control loop below —
            // the lossy transport may drop any single copy.
            for w in &live {
                self.net.send(
                    self.scheduler_node,
                    w.node,
                    Payload::Control(Control::RaiseTarget(target)),
                );
            }
        } else {
            // A fresh spawn reads the full `Shard::docs` — which already
            // contains everything ingested so far — so the queued feed
            // copies must be dropped or the worker would absorb them
            // twice.
            for f in &self.feeds {
                f.clear_pending();
            }
            for s in 0..self.shards.len() {
                let mut slowdown = cfg.cluster.worker_slowdown;
                if cfg.cluster.slow_clients.contains(&s) {
                    slowdown = (slowdown * 10).max(Duration::from_millis(2));
                }
                live.push(spawn(s, self.states[s].clone(), slowdown, announce, &self.net));
            }
        }
        *self.live_workers.write().unwrap() =
            live.iter().map(|w| (w.shard, w.node)).collect();

        // The segment control loop (progress, stragglers, failure
        // injection, client failover, the 90% rule).
        let mut scheduler = Scheduler::new(
            SchedulerConfig::default(),
            target,
            live.iter().map(|w| w.node).collect(),
        );
        // Seed real per-shard progress: a resumed segment's median starts
        // at the checkpoint iteration, so "no report yet" is not mistaken
        // for "stuck at iteration 0" by the straggler policy.
        for w in &live {
            let start = self.states[w.shard]
                .as_ref()
                .map(|s| s.iteration)
                .unwrap_or(start_iteration)
                .min(target);
            scheduler.record(w.shard, w.node, start, 0);
        }
        // Worker liveness: every sync point sends a heartbeat (and every
        // progress report counts as one); a shard silent past the
        // liveness window is declared lost below even when nothing ever
        // explicitly killed its node.
        let worker_liveness = cfg.cluster.worker_liveness;
        let mut last_beat: Vec<Instant> = vec![Instant::now(); live.len()];
        // Generous watchdog: covers oversubscribed single-core hosts; a
        // healthy segment terminates via the 90% quorum long before this.
        let span = target - start_iteration;
        let hard_deadline = seg_start
            + Duration::from_secs(120)
            + Duration::from_millis(span * self.shards.total_tokens() as u64 / 500);
        let mut deadline_hit = false;
        let mut next_raise = Instant::now() + Duration::from_millis(200);

        loop {
            // Drain progress reports.
            while let Some(env) = self
                .net
                .recv_timeout(self.scheduler_node, Duration::from_millis(5))
            {
                match env.payload {
                    Payload::Progress {
                        shard,
                        iteration,
                        tokens,
                    } => {
                        scheduler.record(shard, env.from, iteration, tokens);
                        if let Some(b) = last_beat.get_mut(shard) {
                            *b = Instant::now();
                        }
                    }
                    Payload::Heartbeat => {
                        if let Some(w) = live.iter().find(|w| w.node == env.from) {
                            last_beat[w.shard] = Instant::now();
                        }
                    }
                    _ => {}
                }
            }
            // Backstop for lossy transports: a worker thread that exited
            // normally (node still alive) reached its target even if its
            // final Progress report was dropped. (Terminated workers only
            // exist after the quorum branch below breaks the loop.)
            for w in &live {
                if w.handle.is_finished() && !self.net.is_dead(w.node) {
                    scheduler.record(w.shard, w.node, target, 0);
                }
            }
            let median = scheduler.median_progress();
            self.progress.store(median, Ordering::Relaxed);

            // Reused parked workers learned the new target via a
            // RaiseTarget message — which the lossy transport may have
            // dropped. Re-send on a cadence to any shard still short of
            // the target (a duplicate raise is idempotent: workers take
            // `max`).
            if reused && Instant::now() >= next_raise {
                for w in &live {
                    if scheduler.shards()[w.shard].iteration < target
                        && !self.net.is_dead(w.node)
                    {
                        self.net.send(
                            self.scheduler_node,
                            w.node,
                            Payload::Control(Control::RaiseTarget(target)),
                        );
                    }
                }
                next_raise = Instant::now() + Duration::from_millis(200);
            }

            // Failure injection (absolute iterations, so a plan spanning
            // segment boundaries still fires exactly once).
            let net = &self.net;
            self.pending_client_kills.retain(|&(iter, client)| {
                if median >= iter {
                    if let Some(w) = live.iter().find(|w| w.shard == client) {
                        net.kill(w.node);
                    }
                    false
                } else {
                    true
                }
            });
            let group = self.group.as_ref().unwrap();
            self.pending_server_kills.retain(|&(iter, slot)| {
                if median >= iter {
                    group.kill_slot(slot);
                    false
                } else {
                    true
                }
            });

            // Straggler policy: kill + reassign (§5.4). Bounded per shard
            // so a host-wide slowdown can't put a shard into a respawn
            // loop that never finishes.
            for shard_idx in scheduler.stragglers() {
                if scheduler.shards()[shard_idx].reassignments >= 2 {
                    continue;
                }
                if let Some(pos) = live.iter().position(|w| w.shard == shard_idx) {
                    let w = &live[pos];
                    self.net.kill(w.node);
                    // fallthrough: the failover scan below respawns it.
                }
            }

            // Client failover: respawn any *lost* worker from its
            // snapshot. Lost = its node is dead (explicit kill, straggler
            // policy, chaos injection) — or it went silent: no sync-point
            // heartbeat within the liveness window, the wedged-thread /
            // stalled-host case where nothing ever recorded a kill. A
            // silent worker's node is killed first so the old incarnation
            // cannot keep pushing after its replacement starts.
            for i in 0..live.len() {
                let shard_idx = live[i].shard;
                if scheduler.shards()[shard_idx].iteration >= target {
                    continue;
                }
                let dead = self.net.is_dead(live[i].node);
                let silent = !dead
                    && !live[i].handle.is_finished()
                    && last_beat[shard_idx].elapsed() > worker_liveness;
                if !dead && !silent {
                    continue;
                }
                if silent {
                    crate::warn!(
                        "session",
                        "shard {shard_idx} missed heartbeats for {worker_liveness:?}; \
                         declaring it lost and reassigning"
                    );
                    self.net.kill(live[i].node);
                }
                let resume = self
                    .snapshot_dir
                    .as_ref()
                    .map(|d| d.join(format!("client_shard{shard_idx}.snap")))
                    .and_then(|p| snapshot::read_snapshot(&p))
                    .and_then(|b| snapshot::decode_client(&b))
                    .filter(|s| s.shard == shard_idx);
                // The replacement reads the full `Shard::docs` (ingested
                // documents included); drop the dead incarnation's
                // undrained feed copies so they aren't absorbed twice.
                self.feeds[shard_idx].clear_pending();
                let old = std::mem::replace(
                    &mut live[i],
                    spawn(shard_idx, resume, Duration::ZERO, true, &self.net),
                );
                let _ = old.handle.join();
                scheduler.reassign(shard_idx, live[i].node);
                self.live_workers.write().unwrap()[i] = (shard_idx, live[i].node);
                last_beat[shard_idx] = Instant::now();
                self.reassignments += 1;
            }

            if scheduler.quorum_reached() {
                // 90% rule (§6): tell everyone to stop at their next sync
                // point. Workers exit carrying their sampler state — the
                // segment handoff — so Terminate replaces the old
                // kill-on-quorum (which destroyed the state). In park
                // mode nobody is told to stop: workers idle at the
                // target and the session reads their state from disk.
                if !self.park_workers {
                    for w in &live {
                        self.net.send(
                            self.scheduler_node,
                            w.node,
                            Payload::Control(Control::Terminate),
                        );
                    }
                }
                break;
            }
            if Instant::now() > hard_deadline {
                crate::warn!("session", "hard deadline hit; terminating segment");
                for w in &live {
                    self.net.kill(w.node);
                }
                deadline_hit = true;
                break;
            }
        }

        if self.park_workers && !deadline_hit {
            // Park handoff: workers stay alive at the target. Give the
            // sub-quorum stragglers a grace window to catch up (parked
            // workers re-announce progress, so a lost final report heals
            // here), then read every shard's segment-end state from its
            // barrier-free disk snapshot — the join-based handoff below
            // only exists for exiting workers.
            let grace = Instant::now() + Duration::from_secs(15);
            while scheduler.shards().iter().any(|sh| sh.iteration < target) {
                if Instant::now() > grace {
                    break;
                }
                while let Some(env) = self
                    .net
                    .recv_timeout(self.scheduler_node, Duration::from_millis(5))
                {
                    if let Payload::Progress {
                        shard,
                        iteration,
                        tokens,
                    } = env.payload
                    {
                        scheduler.record(shard, env.from, iteration, tokens);
                    }
                }
            }
            self.refresh_states_from_disk();
            self.parked = live;
        } else {
            // Grace period for Terminated workers to reach a sync point
            // and hand their state back; anything still running past it
            // is killed (its shard keeps the previous segment's state).
            if !deadline_hit {
                let grace = Instant::now() + Duration::from_secs(15);
                while !live.iter().all(|w| w.handle.is_finished()) {
                    if Instant::now() > grace {
                        for w in &live {
                            self.net.kill(w.node);
                        }
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            for w in live {
                if let Ok(out) = w.handle.join() {
                    if let Some(state) = out.state {
                        debug_assert_ne!(out.exit, WorkerExit::Killed);
                        self.states[w.shard] = Some(state);
                    }
                }
            }
        }
        // A watchdog-terminated segment must not pretend it reached the
        // target: record the honest (median) progress, or a later
        // checkpoint would durably skip the never-trained iterations.
        let reached = if deadline_hit {
            scheduler
                .median_progress()
                .clamp(start_iteration, target)
        } else {
            target
        };
        self.iteration = reached;
        self.progress.store(reached, Ordering::Relaxed);
        self.live_workers.write().unwrap().clear();

        let net1 = self.net.stats();
        let corr1 = self.group.as_ref().unwrap().total_corrections();
        let seg = SegmentReport {
            start_iteration,
            end_iteration: reached,
            report: TrainReport::from_fold(
                self.cfg.model.name(),
                &self.sink.segment_fold(),
                seg_start.elapsed().as_secs_f64(),
                (
                    net1.0.saturating_sub(net0.0),
                    net1.1.saturating_sub(net0.1),
                    net1.2.saturating_sub(net0.2),
                    net1.3.saturating_sub(net0.3),
                ),
                corr1.saturating_sub(corr0),
                self.reassignments - reassign0,
            ),
        };
        self.user_observer.on_segment(&seg);
        Ok(seg)
    }

    /// Checkpoint the entire cluster into `dir`: every server slot's
    /// store (written by the live servers and acknowledged), every
    /// shard's client state, and the session meta. The directory is a
    /// complete [`resume`](Self::resume) target *and* a valid serving
    /// snapshot directory (`hplvm serve --snapshot DIR`).
    pub fn checkpoint(&mut self, dir: &Path) -> Result<()> {
        anyhow::ensure!(self.group.is_some(), "session already finished");
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", dir.display()))?;
        // Let in-flight end-of-segment pushes land before the servers
        // serialize their stores (messages to one node deliver in
        // latency order, so a request sent after this window serializes
        // after the flushed deltas).
        let settle = self.cfg.cluster.net.base_latency * 4
            + self.cfg.cluster.net.jitter * 4
            + Duration::from_millis(2);
        std::thread::sleep(settle);

        // Parked workers never hand state back through a join — pull
        // their freshest barrier-free disk snapshots (they re-snapshot
        // whenever they absorb documents while parked).
        if !self.parked.is_empty() {
            self.refresh_states_from_disk();
        }

        // Client states (barrier-free: whatever each shard last reached).
        for state in self.states.iter().flatten() {
            let path = dir.join(format!("client_shard{}.snap", state.shard));
            snapshot::write_atomic(&path, &snapshot::encode_client(state))
                .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))?;
        }

        // Discard anything still queued at the coordinator (stale progress
        // reports, duplicate acks from an earlier checkpoint's retries) so
        // an old ack can never satisfy *this* checkpoint — everything
        // in flight landed during the settle window above.
        let _ = self.net.drain_ready(self.scheduler_node);

        // Server slot stores, with acknowledged round-trips (re-requested
        // on a cadence: the transport may drop either direction). Each
        // checkpoint runs under a fresh epoch; quorum counts (slot,
        // epoch) pairs, so a duplicated or stale ack can't stand in for
        // a slot that never serialized this time.
        self.snapshot_epoch += 1;
        let epoch = self.snapshot_epoch;
        let group = self.group.as_ref().unwrap();
        let n_slots = self.cfg.cluster.n_servers();
        let mut acked = vec![false; n_slots];
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut next_send = Instant::now();
        while acked.iter().any(|a| !a) {
            anyhow::ensure!(
                Instant::now() <= deadline,
                "checkpoint timed out waiting for server slots {:?}",
                acked
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| !a)
                    .map(|(s, _)| s)
                    .collect::<Vec<_>>()
            );
            if Instant::now() >= next_send {
                for (slot, &done) in acked.iter().enumerate() {
                    if !done {
                        self.net.send(
                            self.scheduler_node,
                            group.node_for_slot(slot as u32),
                            Payload::SnapshotReq {
                                dir: dir.to_path_buf(),
                                epoch,
                            },
                        );
                    }
                }
                next_send = Instant::now() + Duration::from_millis(200);
            }
            while let Some(env) = self
                .net
                .recv_timeout(self.scheduler_node, Duration::from_millis(5))
            {
                if let Payload::SnapshotAck {
                    slot,
                    ok,
                    dir: acked_dir,
                    epoch: acked_epoch,
                } = env.payload
                {
                    // Only acks for *this* checkpoint count: the epoch is
                    // the dedup key (a stale ack from an earlier
                    // checkpoint — even into the same directory — must
                    // not mark a slot done it never wrote here), the
                    // directory check stays as defense in depth.
                    if acked_epoch != epoch || acked_dir != dir {
                        continue;
                    }
                    anyhow::ensure!(
                        ok,
                        "server slot {slot} failed to write its checkpoint snapshot \
                         into {}",
                        dir.display()
                    );
                    if let Some(a) = acked.get_mut(slot as usize) {
                        *a = true;
                    }
                }
            }
        }

        // Session meta last: its presence marks the checkpoint complete.
        let sm = SessionMeta {
            run_id: self.run_id,
            iteration: self.iteration,
            epoch: self.epoch,
            seed: self.cfg.seed,
            config_json: self.cfg.to_json().to_string(),
            corpus_file: self.corpus_file.clone(),
            vocab_file: self.vocab_file.clone(),
        };
        let meta_path = dir.join(snapshot::SESSION_META_NAME);
        snapshot::write_atomic(&meta_path, &snapshot::encode_session(&sm))
            .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", meta_path.display()))?;

        // A directory a checkpoint went into is never cleaned up — even
        // when it is the auto-created periodic-snapshot temp dir.
        if !self.checkpoint_dirs.iter().any(|d| d == dir) {
            self.checkpoint_dirs.push(dir.to_path_buf());
        }
        Ok(())
    }

    /// The cumulative report over every segment so far (the session keeps
    /// running).
    pub fn report(&self) -> TrainReport {
        let (corr, net) = match &self.group {
            Some(g) => (g.total_corrections(), self.net.stats()),
            None => (0, self.net.stats()),
        };
        TrainReport::from_fold(
            self.cfg.model.name(),
            &self.sink.total_fold(),
            self.t0.elapsed().as_secs_f64(),
            net,
            corr,
            self.reassignments,
        )
    }

    /// Shut the cluster down and return the cumulative report. Periodic
    /// server snapshots flush once more on shutdown (into the configured
    /// snapshot dir); the auto-created temp snapshot dir is removed
    /// *unless* a [`checkpoint`](Self::checkpoint) was written into it.
    pub fn finish(mut self) -> Result<TrainReport> {
        self.retire_parked();
        let report = self.report();
        if let Some(group) = self.group.take() {
            group.shutdown();
        }
        if let (Some(dir), true) = (&self.snapshot_dir, self.auto_snapshot_dir) {
            if !self.checkpoint_dirs.iter().any(|d| d == dir) {
                let _ = std::fs::remove_dir_all(dir);
            }
        }
        Ok(report)
    }
}

impl Drop for TrainSession {
    fn drop(&mut self) {
        // A dropped (not finished) session still stops its server threads
        // and parked worker threads; the auto temp dir is left behind
        // for post-mortems. Parked nodes are killed (not joined — a Drop
        // must not block): the park loop polls `is_dead` and exits.
        for w in &self.parked {
            self.net.kill(w.node);
        }
        if let Some(group) = self.group.take() {
            group.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;

    fn tiny_cfg() -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.model = ModelKind::AliasLda;
        cfg.params.topics = 6;
        cfg.corpus.n_docs = 80;
        cfg.corpus.vocab_size = 200;
        cfg.corpus.n_topics = 6;
        cfg.corpus.doc_len_mean = 10.0;
        cfg.cluster.clients = 2;
        cfg.cluster.net.base_latency = Duration::from_micros(50);
        cfg.cluster.net.jitter = Duration::from_micros(50);
        cfg.iterations = 4;
        cfg.eval_every = 2;
        cfg.test_docs = 10;
        cfg
    }

    /// The record sink folds iterations into running aggregates: after
    /// 10k iterations × 3 shards it buffers **zero** raw records (O(1)
    /// in records held) and only per-iteration aggregate rows, and the
    /// folded report still carries the full accounting.
    #[test]
    fn sink_stays_bounded_over_10k_iterations() {
        let sink = RecordingObserver::new(Arc::new(NullObserver));
        for iter in 1..=10_000u64 {
            for shard in 0..3usize {
                sink.on_iteration(&IterRecord {
                    shard,
                    client_idx: shard,
                    iteration: iter,
                    secs: 0.01,
                    sample_secs: 0.008,
                    tokens: 100,
                    perplexity: if iter % 100 == 0 { Some(800.0) } else { None },
                    avg_ll: -7.0,
                    topics_per_word: 3.0,
                    acceptance: 0.9,
                    corrections: 0,
                });
            }
        }
        assert_eq!(sink.records_held(), 0, "records fold on arrival, never buffer");
        let total = sink.total_fold();
        assert_eq!(total.records_seen(), 30_000);
        assert_eq!(total.rows_held(), 10_000, "one aggregate row per iteration");
        let rep = TrainReport::from_fold("t", &total, 1.0, (0, 0, 0, 0), 0, 0);
        assert_eq!(rep.per_iteration.len(), 10_000);
        assert_eq!(rep.per_iteration[0].datapoints, 3);
        assert_eq!(rep.total_tokens, 3_000_000);
        assert!((rep.final_perplexity() - 800.0).abs() < 1e-9);
    }

    #[test]
    fn start_validates_config_before_building() {
        let mut cfg = tiny_cfg();
        cfg.eval_every = 0;
        let src = SyntheticSource::new(cfg.corpus.clone());
        let err = match TrainSession::start(cfg, &src) {
            Ok(_) => panic!("degenerate config must be refused"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("eval_every"), "{err}");
    }

    #[test]
    fn noop_segment_and_eval_cadence_guard() {
        let cfg = tiny_cfg();
        let src = SyntheticSource::new(cfg.corpus.clone());
        let mut s = TrainSession::start(cfg, &src).unwrap();
        assert_eq!(s.iteration(), 0);
        let seg = s.run_to(0).unwrap();
        assert_eq!(
            (seg.start_iteration, seg.end_iteration),
            (0, 0),
            "target 0 is a no-op segment"
        );
        assert!(seg.report.per_iteration.is_empty());
        assert!(s.set_eval_every(0).is_err());
        s.set_eval_every(7).unwrap();
        assert!(s.run_id() != 0);
        let _ = s.finish().unwrap();
    }

    /// Two segments through the session equal one longer run structurally,
    /// metrics stream through the observer, and the segment boundary
    /// leaves the cluster hot.
    #[test]
    fn segments_stream_observer_and_accumulate() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Counting {
            iters: AtomicUsize,
            segs: AtomicUsize,
        }
        impl TrainObserver for Counting {
            fn on_iteration(&self, _rec: &IterRecord) {
                self.iters.fetch_add(1, Ordering::Relaxed);
            }
            fn on_segment(&self, seg: &SegmentReport) {
                self.segs.fetch_add(1, Ordering::Relaxed);
                assert!(seg.report.final_perplexity().is_finite());
            }
        }
        let obs = Arc::new(Counting {
            iters: AtomicUsize::new(0),
            segs: AtomicUsize::new(0),
        });
        let cfg = tiny_cfg();
        let src = SyntheticSource::new(cfg.corpus.clone());
        let mut s =
            TrainSession::start_with_observer(cfg, &src, obs.clone()).unwrap();
        let seg1 = s.run_for(2).unwrap();
        assert_eq!((seg1.start_iteration, seg1.end_iteration), (0, 2));
        let seg2 = s.run_for(2).unwrap();
        assert_eq!((seg2.start_iteration, seg2.end_iteration), (2, 4));
        // Segment 2 rows start after segment 1 (no leading empties).
        assert!(seg2.report.per_iteration[0].iteration >= 3);
        let total = s.finish().unwrap();
        assert_eq!(obs.segs.load(Ordering::Relaxed), 2);
        assert!(obs.iters.load(Ordering::Relaxed) >= 4);
        assert!(total.per_iteration.len() >= seg2.report.per_iteration.len());
        assert!(total.final_perplexity().is_finite());
    }

    /// Online mode: workers park at the target, the next segment raises
    /// it in place, and documents ingested between segments are absorbed
    /// mid-run — counters and segment boundaries stay coherent across
    /// the park boundary.
    #[test]
    fn parked_workers_absorb_ingested_docs_across_segments() {
        use crate::corpus::doc::Document;
        let mut cfg = tiny_cfg();
        cfg.cluster.snapshot_every = Some(Duration::from_millis(50));
        let src = SyntheticSource::new(cfg.corpus.clone());
        let mut s = TrainSession::start(cfg, &src).unwrap();
        s.set_park_workers(true).unwrap();
        let base = s.docs_ingested();
        assert_eq!(base, s.docs_absorbed());
        let seg1 = s.run_online(2).unwrap();
        assert_eq!((seg1.start_iteration, seg1.end_iteration), (0, 2));

        let vocab = s.vocab() as u32;
        let docs: Vec<Document> = (0..6u32)
            .map(|i| Document {
                tokens: vec![i % vocab, (i + 1) % vocab, (i + 2) % vocab],
            })
            .collect();
        assert_eq!(s.ingest(&docs).unwrap(), 6);
        assert_eq!(s.docs_ingested(), base + 6);

        let seg2 = s.run_online(2).unwrap();
        assert_eq!(
            (seg2.start_iteration, seg2.end_iteration),
            (2, 4),
            "the raised target continues the same iteration line"
        );
        assert_eq!(s.docs_absorbed(), base + 6, "workers drained the feeds");
        assert!(seg2.report.final_perplexity().is_finite());

        // An out-of-vocabulary ingest is refused before any mutation.
        let bad = vec![Document {
            tokens: vec![vocab + 7],
        }];
        assert!(s.ingest(&bad).is_err());
        assert_eq!(s.docs_ingested(), base + 6);
        let _ = s.finish().unwrap();
    }

    /// Park mode without a snapshot directory is refused: the session
    /// reads parked workers' segment-end state from disk.
    #[test]
    fn park_mode_requires_a_snapshot_dir() {
        let cfg = tiny_cfg();
        assert!(cfg.cluster.snapshot_every.is_none());
        let src = SyntheticSource::new(cfg.corpus.clone());
        let mut s = TrainSession::start(cfg, &src).unwrap();
        let err = format!("{:#}", s.set_park_workers(true).unwrap_err());
        assert!(err.contains("snapshot"), "{err}");
        let _ = s.finish().unwrap();
    }
}
