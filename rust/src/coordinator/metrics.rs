//! Per-iteration metrics, aggregated exactly the way the paper's figures
//! report them: for each iteration, across clients — max, min, mean,
//! ±1 std-dev and the **number of data points** (which shrinks as fast
//! workers finish and the 90% rule fires).

use crate::util::json::Json;
use crate::util::stats::RunningStats;

/// One worker's record for one completed iteration.
#[derive(Clone, Debug)]
pub struct IterRecord {
    /// Shard (stable identity across reassignments).
    pub shard: usize,
    /// Client index within the group.
    pub client_idx: usize,
    /// Iteration number (1-based).
    pub iteration: u64,
    /// Wall-clock seconds for the iteration (sampling + sync).
    pub secs: f64,
    /// Seconds spent in sampling only.
    pub sample_secs: f64,
    /// Tokens resampled.
    pub tokens: u64,
    /// Test perplexity, when this iteration was an eval iteration.
    pub perplexity: Option<f64>,
    /// Mean per-token train log-likelihood.
    pub avg_ll: f64,
    /// Average non-zero topics per word in the local replica.
    pub topics_per_word: f64,
    /// MH acceptance rate.
    pub acceptance: f64,
    /// Projection corrections performed this iteration.
    pub corrections: u64,
}

/// Cross-client aggregates for one iteration — one row of a paper figure.
#[derive(Clone, Debug)]
pub struct IterStats {
    /// Iteration number.
    pub iteration: u64,
    /// Running-time panel.
    pub time: RunningStats,
    /// Perplexity panel (empty between eval iterations).
    pub perplexity: RunningStats,
    /// Log-likelihood panel (Fig 6).
    pub log_lik: RunningStats,
    /// Topics-per-word panel.
    pub topics_per_word: RunningStats,
    /// Number of clients reporting — the data-points panel.
    pub datapoints: u64,
}

impl IterStats {
    fn empty(iteration: u64) -> IterStats {
        IterStats {
            iteration,
            time: RunningStats::new(),
            perplexity: RunningStats::new(),
            log_lik: RunningStats::new(),
            topics_per_word: RunningStats::new(),
            datapoints: 0,
        }
    }
}

/// Bounded-memory record accumulator: folds [`IterRecord`]s into the
/// per-iteration aggregates [`TrainReport`] is built from, retaining
/// **no** raw records. Memory is O(distinct iterations) — independent
/// of client count and of how many records stream through — so a
/// long-running session (or a chaos soak) can observe millions of
/// records without growing an unbounded `Vec<IterRecord>`.
#[derive(Clone, Debug, Default)]
pub struct RecordFold {
    rows: std::collections::BTreeMap<u64, IterStats>,
    total_tokens: u64,
    sample_secs: f64,
    corrections: u64,
    records_seen: u64,
}

impl RecordFold {
    /// Empty accumulator.
    pub fn new() -> RecordFold {
        RecordFold::default()
    }

    /// Fold one record in; the record itself is not retained.
    pub fn push(&mut self, r: &IterRecord) {
        let row = self
            .rows
            .entry(r.iteration)
            .or_insert_with(|| IterStats::empty(r.iteration));
        row.time.push(r.secs);
        row.log_lik.push(r.avg_ll);
        row.topics_per_word.push(r.topics_per_word);
        if let Some(p) = r.perplexity {
            row.perplexity.push(p);
        }
        row.datapoints += 1;
        self.total_tokens += r.tokens;
        self.sample_secs += r.sample_secs;
        self.corrections += r.corrections;
        self.records_seen += 1;
    }

    /// Records folded so far (a counter — none are held).
    pub fn records_seen(&self) -> u64 {
        self.records_seen
    }

    /// Aggregate rows currently held — bounded by distinct iterations.
    pub fn rows_held(&self) -> usize {
        self.rows.len()
    }
}

/// The full training outcome.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Model display name.
    pub model: String,
    /// Aggregated per-iteration rows.
    pub per_iteration: Vec<IterStats>,
    /// Total tokens sampled across the run.
    pub total_tokens: u64,
    /// Wall-clock of the whole run (seconds).
    pub wall_secs: f64,
    /// Aggregate sampling throughput (tokens/second across clients).
    pub tokens_per_sec: f64,
    /// Transport stats `(sent, dropped, dead_letters, bytes)`.
    pub net: (u64, u64, u64, u64),
    /// Total projection corrections (client + server side).
    pub corrections: u64,
    /// Worker reassignments (failovers + straggler kills).
    pub reassignments: u64,
}

impl TrainReport {
    /// Aggregate raw records (folds them through a [`RecordFold`]).
    pub fn from_records(
        model: &str,
        records: &[IterRecord],
        wall_secs: f64,
        net: (u64, u64, u64, u64),
        server_corrections: u64,
        reassignments: u64,
    ) -> TrainReport {
        let mut fold = RecordFold::new();
        for r in records {
            fold.push(r);
        }
        Self::from_fold(model, &fold, wall_secs, net, server_corrections, reassignments)
    }

    /// Aggregate a pre-folded accumulator — the session sink's bounded
    /// path. Rows span the fold's first recorded iteration to its last
    /// (a segment over iterations 41..=60 yields 20 rows, not 40 empty
    /// ones followed by 20); interior iterations nobody reported still
    /// get an empty row, matching [`from_records`](Self::from_records).
    pub fn from_fold(
        model: &str,
        fold: &RecordFold,
        wall_secs: f64,
        net: (u64, u64, u64, u64),
        server_corrections: u64,
        reassignments: u64,
    ) -> TrainReport {
        let max_iter = fold.rows.keys().next_back().copied().unwrap_or(0);
        let min_iter = fold.rows.keys().next().copied().unwrap_or(1).max(1);
        let mut per_iteration =
            Vec::with_capacity((max_iter.saturating_sub(min_iter) + 1) as usize);
        for it in min_iter..=max_iter {
            per_iteration.push(
                fold.rows
                    .get(&it)
                    .cloned()
                    .unwrap_or_else(|| IterStats::empty(it)),
            );
        }
        TrainReport {
            model: model.to_string(),
            per_iteration,
            total_tokens: fold.total_tokens,
            wall_secs,
            tokens_per_sec: if fold.sample_secs > 0.0 {
                fold.total_tokens as f64 / fold.sample_secs
            } else {
                0.0
            },
            net,
            corrections: fold.corrections + server_corrections,
            reassignments,
        }
    }

    /// Last measured mean perplexity (NaN if never evaluated).
    pub fn final_perplexity(&self) -> f64 {
        self.per_iteration
            .iter()
            .rev()
            .find(|r| r.perplexity.count() > 0)
            .map(|r| r.perplexity.mean())
            .unwrap_or(f64::NAN)
    }

    /// Last mean log-likelihood.
    pub fn final_log_lik(&self) -> f64 {
        self.per_iteration
            .iter()
            .rev()
            .find(|r| r.log_lik.count() > 0)
            .map(|r| r.log_lik.mean())
            .unwrap_or(f64::NAN)
    }

    /// Mean per-iteration wall time over the last half of training.
    pub fn steady_state_iter_secs(&self) -> f64 {
        let n = self.per_iteration.len();
        if n == 0 {
            return 0.0;
        }
        let tail = &self.per_iteration[n / 2..];
        let mut s = RunningStats::new();
        for row in tail {
            if row.time.count() > 0 {
                s.push(row.time.mean());
            }
        }
        s.mean()
    }

    /// Print the paper-style table (one row per iteration).
    pub fn print_table(&self) {
        println!("== {} ==", self.model);
        println!(
            "{:>5} {:>8} {:>12} {:>11} {:>12} {:>11} {:>6}",
            "iter", "time(s)", "±std", "perplexity", "±std", "topics/word", "n"
        );
        for row in &self.per_iteration {
            println!(
                "{:>5} {:>8.3} {:>12.3} {:>11.1} {:>12.1} {:>11.2} {:>6}",
                row.iteration,
                row.time.mean(),
                row.time.std(),
                if row.perplexity.count() > 0 {
                    row.perplexity.mean()
                } else {
                    f64::NAN
                },
                if row.perplexity.count() > 0 {
                    row.perplexity.std()
                } else {
                    f64::NAN
                },
                row.topics_per_word.mean(),
                row.datapoints,
            );
        }
        println!(
            "throughput {:.0} tokens/s | net: {} msgs, {} dropped, {:.1} MiB | corrections {} | reassignments {}",
            self.tokens_per_sec,
            self.net.0,
            self.net.1,
            self.net.3 as f64 / (1024.0 * 1024.0),
            self.corrections,
            self.reassignments,
        );
    }

    /// JSON dump for downstream plotting.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .per_iteration
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("iteration", Json::Num(r.iteration as f64)),
                    ("time_mean", Json::Num(r.time.mean())),
                    ("time_std", Json::Num(r.time.std())),
                    ("time_min", Json::Num(nan_to_null(r.time.min()))),
                    ("time_max", Json::Num(nan_to_null(r.time.max()))),
                    (
                        "perplexity_mean",
                        Json::Num(if r.perplexity.count() > 0 {
                            r.perplexity.mean()
                        } else {
                            -1.0
                        }),
                    ),
                    ("perplexity_std", Json::Num(r.perplexity.std())),
                    ("loglik_mean", Json::Num(r.log_lik.mean())),
                    ("topics_per_word", Json::Num(r.topics_per_word.mean())),
                    ("datapoints", Json::Num(r.datapoints as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("rows", Json::Arr(rows)),
            ("total_tokens", Json::Num(self.total_tokens as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec)),
            ("net_msgs", Json::Num(self.net.0 as f64)),
            ("net_bytes", Json::Num(self.net.3 as f64)),
            ("corrections", Json::Num(self.corrections as f64)),
            ("reassignments", Json::Num(self.reassignments as f64)),
        ])
    }
}

fn nan_to_null(x: f64) -> f64 {
    if x.is_nan() {
        -1.0
    } else {
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(shard: usize, iter: u64, secs: f64, perp: Option<f64>) -> IterRecord {
        IterRecord {
            shard,
            client_idx: shard,
            iteration: iter,
            secs,
            sample_secs: secs * 0.8,
            tokens: 1000,
            perplexity: perp,
            avg_ll: -7.0,
            topics_per_word: 3.0,
            acceptance: 0.95,
            corrections: 1,
        }
    }

    #[test]
    fn aggregates_per_iteration() {
        let records = vec![
            rec(0, 1, 1.0, Some(900.0)),
            rec(1, 1, 2.0, Some(1100.0)),
            rec(0, 2, 1.0, None),
        ];
        let rep = TrainReport::from_records("test", &records, 10.0, (5, 0, 0, 100), 2, 0);
        assert_eq!(rep.per_iteration.len(), 2);
        let r1 = &rep.per_iteration[0];
        assert_eq!(r1.datapoints, 2);
        assert!((r1.time.mean() - 1.5).abs() < 1e-12);
        assert!((r1.perplexity.mean() - 1000.0).abs() < 1e-12);
        let r2 = &rep.per_iteration[1];
        assert_eq!(r2.datapoints, 1, "data points shrink");
        assert_eq!(r2.perplexity.count(), 0);
        assert_eq!(rep.total_tokens, 3000);
        assert_eq!(rep.corrections, 3 + 2);
    }

    #[test]
    fn final_perplexity_skips_non_eval_iters() {
        let records = vec![rec(0, 1, 1.0, Some(500.0)), rec(0, 2, 1.0, None)];
        let rep = TrainReport::from_records("t", &records, 1.0, (0, 0, 0, 0), 0, 0);
        assert_eq!(rep.final_perplexity(), 500.0);
    }

    /// A mid-run segment's records aggregate from their first iteration —
    /// no leading run of empty rows.
    #[test]
    fn segment_records_skip_leading_empty_iterations() {
        let records = vec![rec(0, 41, 1.0, Some(700.0)), rec(0, 42, 1.0, None)];
        let rep = TrainReport::from_records("t", &records, 2.0, (0, 0, 0, 0), 0, 0);
        assert_eq!(rep.per_iteration.len(), 2);
        assert_eq!(rep.per_iteration[0].iteration, 41);
        assert_eq!(rep.per_iteration[1].iteration, 42);
        assert_eq!(rep.final_perplexity(), 700.0);
    }

    /// The bounded fold reproduces `from_records` exactly while holding
    /// aggregate rows only — O(iterations), zero raw records.
    #[test]
    fn fold_matches_from_records_and_stays_bounded() {
        let records = vec![
            rec(0, 1, 1.0, Some(900.0)),
            rec(1, 1, 2.0, Some(1100.0)),
            rec(0, 3, 1.5, None), // gap at 2 → empty interior row
        ];
        let mut fold = RecordFold::new();
        for r in &records {
            fold.push(r);
        }
        assert_eq!(fold.records_seen(), 3);
        assert_eq!(fold.rows_held(), 2, "rows track distinct iterations");
        let a = TrainReport::from_records("t", &records, 9.0, (1, 2, 3, 4), 5, 6);
        let b = TrainReport::from_fold("t", &fold, 9.0, (1, 2, 3, 4), 5, 6);
        assert_eq!(a.per_iteration.len(), b.per_iteration.len());
        for (x, y) in a.per_iteration.iter().zip(&b.per_iteration) {
            assert_eq!(x.iteration, y.iteration);
            assert_eq!(x.datapoints, y.datapoints);
            assert_eq!(x.time.mean(), y.time.mean());
            assert_eq!(x.perplexity.count(), y.perplexity.count());
        }
        assert_eq!(a.per_iteration[1].datapoints, 0, "gap row is empty");
        assert_eq!(a.total_tokens, b.total_tokens);
        assert_eq!(a.corrections, b.corrections);
        assert_eq!(a.tokens_per_sec, b.tokens_per_sec);
    }

    #[test]
    fn json_has_rows() {
        let rep = TrainReport::from_records("t", &[rec(0, 1, 1.0, None)], 1.0, (0, 0, 0, 0), 0, 0);
        let j = rep.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
