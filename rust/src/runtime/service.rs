//! The evaluation service: a dedicated thread owning the PJRT [`Engine`]
//! (the `xla` crate's client is not `Send`/`Sync` — it holds `Rc`s over
//! FFI handles), exposed to worker threads through a cloneable,
//! thread-safe request/reply handle.
//!
//! This also matches the deployment shape: one compiled executable set on
//! the leader process, many sampling threads asking it to score batches.

use std::path::Path;
use std::sync::mpsc;

use super::client::Engine;
use crate::Result;

/// A dense-evaluation backend (the PJRT engine or its service proxy).
pub trait DenseEval: Send + Sync {
    /// Can `log_dot` serve `k`-topic models?
    fn supports_log_dot(&self, k: usize) -> bool;
    /// `out[b] = log Σ_t θ[b,t]·φ[b,t]`.
    fn log_dot(&self, theta: &[f32], phi: &[f32], rows: usize, k: usize) -> Result<Vec<f32>>;
}

enum Req {
    LogDot {
        theta: Vec<f32>,
        phi: Vec<f32>,
        rows: usize,
        k: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Supports {
        k: usize,
        reply: mpsc::Sender<bool>,
    },
}

/// Thread-safe handle to the engine thread.
pub struct EvalService {
    tx: std::sync::Mutex<mpsc::Sender<Req>>,
    // The service thread exits when the last sender drops.
    _handle: std::thread::JoinHandle<()>,
}

impl EvalService {
    /// Spawn the service, loading artifacts from `dir` on the service
    /// thread. `Ok(None)` when no artifacts exist.
    pub fn spawn(dir: &Path) -> Result<Option<EvalService>> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Req>();
        let (load_tx, load_rx) = mpsc::channel::<std::result::Result<bool, String>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-eval".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(Some(e)) => {
                        let _ = load_tx.send(Ok(true));
                        e
                    }
                    Ok(None) => {
                        let _ = load_tx.send(Ok(false));
                        return;
                    }
                    Err(e) => {
                        let _ = load_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::LogDot {
                            theta,
                            phi,
                            rows,
                            k,
                            reply,
                        } => {
                            let _ = reply.send(engine.log_dot(&theta, &phi, rows, k));
                        }
                        Req::Supports { k, reply } => {
                            let _ = reply.send(engine.supports_log_dot(k));
                        }
                    }
                }
            })
            .expect("spawn pjrt-eval");
        match load_rx.recv() {
            Ok(Ok(true)) => Ok(Some(EvalService {
                tx: std::sync::Mutex::new(tx),
                _handle: handle,
            })),
            Ok(Ok(false)) => Ok(None),
            Ok(Err(e)) => Err(anyhow::anyhow!("PJRT load failed: {e}")),
            Err(_) => Err(anyhow::anyhow!("PJRT service thread died during load")),
        }
    }

    fn send(&self, req: Req) {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .expect("pjrt-eval thread gone");
    }
}

impl DenseEval for EvalService {
    fn supports_log_dot(&self, k: usize) -> bool {
        let (reply, rx) = mpsc::channel();
        self.send(Req::Supports { k, reply });
        rx.recv().unwrap_or(false)
    }

    fn log_dot(&self, theta: &[f32], phi: &[f32], rows: usize, k: usize) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.send(Req::LogDot {
            theta: theta.to_vec(),
            phi: phi.to_vec(),
            rows,
            k,
            reply,
        });
        rx.recv()
            .map_err(|_| anyhow::anyhow!("pjrt-eval thread died"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_without_artifacts_is_none() {
        let dir = std::env::temp_dir().join(format!("hplvm_noart_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let svc = EvalService::spawn(&dir).unwrap();
        assert!(svc.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
