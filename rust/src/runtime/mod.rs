//! PJRT runtime bridge: load the AOT artifacts `python/compile/aot.py`
//! produced (HLO **text** — see DESIGN.md §Offline-environment
//! deviations), compile them once on the CPU PJRT client, and serve the
//! evaluation hot path with **no python anywhere at runtime**.

pub mod artifacts;
pub mod client;
pub mod service;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
pub use client::Engine;
pub use service::{DenseEval, EvalService};

/// Fixed batch size the `log_dot` (perplexity) artifact was lowered with.
pub const LOG_DOT_BATCH: usize = 256;
