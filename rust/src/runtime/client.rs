//! The PJRT execution engine.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `compile` → cached loaded executables.
//! One compiled executable per artifact; inputs are padded to the static
//! shapes the artifact was lowered with.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use super::artifacts::ArtifactManifest;
use crate::Result;

/// A loaded PJRT engine holding compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executions performed (diagnostics).
    pub executions: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load from an artifacts directory (with `manifest.json`). Returns
    /// `Err` when PJRT is unavailable, `Ok(None)` when no artifacts exist.
    pub fn load(dir: &Path) -> Result<Option<Engine>> {
        let manifest = match ArtifactManifest::load(dir) {
            Some(m) => m,
            None => return Ok(None),
        };
        let client = xla::PjRtClient::cpu()?;
        Ok(Some(Engine {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            executions: std::sync::atomic::AtomicU64::new(0),
        }))
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The manifest loaded at startup.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    fn executable(&self, name: &str) -> Result<()> {
        let mut exes = self.exes.lock().unwrap();
        if exes.contains_key(name) {
            return Ok(());
        }
        let path = self
            .manifest
            .path_of(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Can the `log_dot` artifact serve models with `k` topics?
    pub fn supports_log_dot(&self, k: usize) -> bool {
        self.manifest
            .entries
            .get("log_dot")
            .map(|m| k <= m.k)
            .unwrap_or(false)
    }

    /// `out[b] = log(Σ_t θ[b,t]·φ[b,t])` — the perplexity scoring kernel.
    ///
    /// `rows ≤` the artifact batch; `k ≤` the artifact K. Inputs are
    /// zero-padded to the static shapes (zero padding is exact for a
    /// sum-reduce). Returns `rows` values.
    pub fn log_dot(&self, theta: &[f32], phi: &[f32], rows: usize, k: usize) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .entries
            .get("log_dot")
            .ok_or_else(|| anyhow::anyhow!("no log_dot artifact"))?
            .clone();
        anyhow::ensure!(rows <= meta.batch, "batch {} > artifact {}", rows, meta.batch);
        anyhow::ensure!(k <= meta.k, "k {} > artifact {}", k, meta.k);
        anyhow::ensure!(theta.len() == rows * k && phi.len() == rows * k, "shape mismatch");
        self.executable("log_dot")?;

        // Pad [rows, k] → [meta.batch, meta.k]. Padded rows get θ·φ = 1 at
        // slot 0 so log() stays finite (they're sliced away below).
        let mut tpad = vec![0f32; meta.batch * meta.k];
        let mut ppad = vec![0f32; meta.batch * meta.k];
        for r in 0..meta.batch {
            if r < rows {
                tpad[r * meta.k..r * meta.k + k].copy_from_slice(&theta[r * k..(r + 1) * k]);
                ppad[r * meta.k..r * meta.k + k].copy_from_slice(&phi[r * k..(r + 1) * k]);
            } else {
                tpad[r * meta.k] = 1.0;
                ppad[r * meta.k] = 1.0;
            }
        }
        let tl = xla::Literal::vec1(&tpad).reshape(&[meta.batch as i64, meta.k as i64])?;
        let pl = xla::Literal::vec1(&ppad).reshape(&[meta.batch as i64, meta.k as i64])?;
        let exes = self.exes.lock().unwrap();
        let exe = exes.get("log_dot").unwrap();
        let result = exe.execute::<xla::Literal>(&[tl, pl])?[0][0].to_literal_sync()?;
        drop(exes);
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        anyhow::ensure!(values.len() == meta.batch, "bad output length");
        Ok(values[..rows].to_vec())
    }

    /// `phi[b,t] = (n[b,t] + β) / (n_t[t] + β̄)` — the dense-proposal /
    /// φ-normalization kernel over a row batch.
    pub fn phi_dense(
        &self,
        counts: &[f32],
        totals: &[f32],
        beta: f32,
        rows: usize,
        k: usize,
    ) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .entries
            .get("phi_dense")
            .ok_or_else(|| anyhow::anyhow!("no phi_dense artifact"))?
            .clone();
        anyhow::ensure!(rows <= meta.batch && k <= meta.k, "shape exceeds artifact");
        anyhow::ensure!(counts.len() == rows * k && totals.len() == k, "shape mismatch");
        self.executable("phi_dense")?;

        let mut cpad = vec![0f32; meta.batch * meta.k];
        for r in 0..rows {
            cpad[r * meta.k..r * meta.k + k].copy_from_slice(&counts[r * k..(r + 1) * k]);
        }
        // Padded topic slots get total = 1 to avoid 0/0.
        let mut tpad = vec![1f32; meta.k];
        tpad[..k].copy_from_slice(totals);
        let cl = xla::Literal::vec1(&cpad).reshape(&[meta.batch as i64, meta.k as i64])?;
        let tl = xla::Literal::vec1(&tpad).reshape(&[meta.k as i64])?;
        let bl = xla::Literal::from(beta);
        let exes = self.exes.lock().unwrap();
        let exe = exes.get("phi_dense").unwrap();
        let result = exe.execute::<xla::Literal>(&[cl, tl, bl])?[0][0].to_literal_sync()?;
        drop(exes);
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        let mut trimmed = Vec::with_capacity(rows * k);
        for r in 0..rows {
            trimmed.extend_from_slice(&values[r * meta.k..r * meta.k + k]);
        }
        Ok(trimmed)
    }
}

// PJRT-dependent tests live in rust/tests/runtime_pjrt.rs (they need the
// artifacts built by `make artifacts`); manifest-only logic is tested in
// `artifacts.rs`.
