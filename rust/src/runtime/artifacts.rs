//! Artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`, describing each lowered HLO module and the
//! static shapes it was specialized to.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Static batch dimension.
    pub batch: usize,
    /// Static topic (K) dimension.
    pub k: usize,
    /// Kernel flavor recorded by the compiler (`pallas` or `jnp`).
    pub flavor: String,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// name → metadata.
    pub entries: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactManifest {
    /// Parse `dir/manifest.json`. Missing manifest → `None` (the system
    /// falls back to pure-rust evaluation).
    pub fn load(dir: &Path) -> Option<ArtifactManifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON text.
    pub fn parse(dir: &Path, text: &str) -> Option<ArtifactManifest> {
        let j = Json::parse(text).ok()?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => return None,
        };
        let mut entries = BTreeMap::new();
        for (name, meta) in obj {
            let file = meta.get("file")?.as_str()?.to_string();
            let batch = meta.get("batch")?.as_usize()?;
            let k = meta.get("k")?.as_usize()?;
            let flavor = meta
                .get("flavor")
                .and_then(Json::as_str)
                .unwrap_or("jnp")
                .to_string();
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    file,
                    batch,
                    k,
                    flavor,
                },
            );
        }
        Some(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, name: &str) -> Option<PathBuf> {
        self.entries.get(name).map(|m| self.dir.join(&m.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "log_dot": {"file": "log_dot.hlo.txt", "batch": 256, "k": 512, "flavor": "pallas"},
        "phi_dense": {"file": "phi_dense.hlo.txt", "batch": 128, "k": 512, "flavor": "pallas"}
    }"#;

    #[test]
    fn parses_entries() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let ld = &m.entries["log_dot"];
        assert_eq!(ld.batch, 256);
        assert_eq!(ld.k, 512);
        assert_eq!(ld.flavor, "pallas");
        assert_eq!(
            m.path_of("log_dot").unwrap(),
            PathBuf::from("/tmp/a/log_dot.hlo.txt")
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse(Path::new("/x"), "[]").is_none());
        assert!(ArtifactManifest::parse(Path::new("/x"), "{bad").is_none());
        // Missing required key.
        assert!(
            ArtifactManifest::parse(Path::new("/x"), r#"{"a":{"file":"f"}}"#).is_none()
        );
    }
}
