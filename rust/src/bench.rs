//! Mini benchmark harness (criterion is unavailable offline): warmup +
//! timed repetitions with mean/std/min, and paper-style table printing.
//! Every `rust/benches/*.rs` target (`harness = false`) drives this.

use crate::util::stats::RunningStats;
use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Seconds per repetition.
    pub stats: RunningStats,
    /// Optional work units per repetition (tokens, draws, …) for
    /// throughput reporting.
    pub units_per_rep: f64,
}

impl BenchResult {
    /// Mean seconds per repetition.
    pub fn mean_secs(&self) -> f64 {
        self.stats.mean()
    }

    /// Units per second (0 when no units were declared).
    pub fn throughput(&self) -> f64 {
        if self.units_per_rep > 0.0 && self.stats.mean() > 0.0 {
            self.units_per_rep / self.stats.mean()
        } else {
            0.0
        }
    }

    /// One formatted row.
    pub fn row(&self) -> String {
        if self.units_per_rep > 0.0 {
            format!(
                "{:<44} {:>11.6}s ±{:>9.6}  {:>14.0} units/s",
                self.name,
                self.stats.mean(),
                self.stats.std(),
                self.throughput()
            )
        } else {
            format!(
                "{:<44} {:>11.6}s ±{:>9.6}",
                self.name,
                self.stats.mean(),
                self.stats.std()
            )
        }
    }
}

/// Time `f` for `reps` repetitions after `warmup` unmeasured ones.
pub fn time_fn(name: &str, warmup: usize, reps: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = RunningStats::new();
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        stats,
        units_per_rep: 0.0,
    }
}

/// Like [`time_fn`] but records `units` work items per repetition.
pub fn time_units(
    name: &str,
    warmup: usize,
    reps: usize,
    units: f64,
    f: impl FnMut(),
) -> BenchResult {
    let mut r = time_fn(name, warmup, reps, f);
    r.units_per_rep = units;
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!();
    println!("### {title}");
    println!("{}", "-".repeat(title.len() + 4));
}

/// Print an aligned table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i.min(widths.len() - 1)]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(headers.iter().map(|s| s.to_string()).collect())
    );
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
}

/// Nearest-rank percentile over raw samples (`pct` in `[0, 100]`);
/// sorts a copy. NaN for an empty sample set. Used by the serving
/// latency reports (p50/p99).
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// The published-system survey behind Fig 1 (parameters vs cores), as
/// reported in the paper's related-work comparison; this repo's own runs
/// append a live row.
pub fn fig1_survey() -> Vec<(&'static str, f64, f64, &'static str)> {
    // (system, #parameters, #cores, kind)
    vec![
        ("VW (Langford)", 1e9, 1e3, "supervised"),
        ("Graphlab", 1e9, 1e3, "unsupervised"),
        ("Naiad", 1e9, 1e2, "supervised"),
        ("REEF", 1e8, 1e2, "supervised"),
        ("Petuum", 1e10, 1e3, "unsupervised"),
        ("MLbase", 1e7, 1e2, "supervised"),
        ("YahooLDA", 1e10, 1e3, "unsupervised"),
        ("DistBelief", 1e9, 1e4, "supervised"),
        ("Parameter Server [12]", 1e11, 1e4, "supervised"),
        ("THIS WORK (paper)", 4e12, 6e4, "unsupervised"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let r = time_fn("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.stats.count(), 5);
        assert!(r.mean_secs() >= 0.0);
    }

    #[test]
    fn throughput_uses_units() {
        let r = time_units("u", 0, 3, 1000.0, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let tp = r.throughput();
        assert!(tp > 0.0 && tp < 1_500_000.0, "tp {tp}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn fig1_has_this_work() {
        let s = fig1_survey();
        assert!(s.iter().any(|(n, _, _, _)| n.contains("THIS WORK")));
        assert!(s.iter().all(|&(_, p, c, _)| p > 0.0 && c > 0.0));
    }
}
