//! Chaos harness: kill and resize a live cluster under load, and prove
//! convergence and serving availability survived it.
//!
//! The paper's headline robustness claim (§6) is operational, not
//! algorithmic: relaxed-consistency sync plus snapshot-restore failover
//! let a 60k-core production run shrug off preempted machines. The unit
//! tests exercise each primitive in isolation — worker failover, server
//! freeze/restore/thaw, ring grow with drain-and-handoff, set-wide
//! serving reloads — but an operable system has to survive them
//! *composed*, injected mid-flight into one live topology. This module
//! is that composition:
//!
//! * [`ChaosPlan`] — a deterministic, seeded fault schedule. Each
//!   [`ChaosEvent`] fires when the training session's **median progress
//!   probe** reaches its iteration (never wall-clock, so a loaded CI
//!   host runs the same scenario as a fast laptop).
//! * [`ChaosHarness`] — drives a live [`TrainSession`] *and* a serving
//!   [`ReplicaSet`] built from its checkpoint, while an injector thread
//!   fires the plan through the session's chaos probes
//!   ([`TrainSession::sim_net`], [`TrainSession::worker_nodes`],
//!   [`TrainSession::progress_probe`], [`TrainSession::elastic`]) and a
//!   query thread streams inference requests throughout.
//! * [`ChaosReport`] — what actually happened: every fault injected,
//!   handoff accounting from ring grows, worker reassignments,
//!   iterations lost to the chaos, queries dropped (sent − answered),
//!   and the post-chaos eval perplexity.
//!
//! ## Determinism and `CHAOS_SEED`
//!
//! Every schedule derives from one `u64` seed ([`chaos_seed`] reads the
//! `CHAOS_SEED` environment variable, falling back to
//! [`DEFAULT_CHAOS_SEED`]), so a failing CI run reproduces locally with
//! one command:
//!
//! ```text
//! CHAOS_SEED=12345 cargo test --release --test chaos_scenarios
//! ```
//!
//! The *plan* — which faults, in which order, at which iterations — is a
//! pure function of the seed. Outcomes (exact perplexity, how many
//! queries landed while a replica resized) ride real thread scheduling
//! and are asserted with tolerances, the same contract the trainer's
//! own convergence tests use.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::{ModelKind, TrainConfig};
use crate::coordinator::TrainSession;
use crate::corpus::source::SyntheticSource;
use crate::net::Pacer;
use crate::ps::server::HandoffStats;
use crate::serve::{InferConfig, ReplicaSet};
use crate::util::rng::Rng;
use crate::Result;

/// Default scenario seed when `CHAOS_SEED` is unset.
pub const DEFAULT_CHAOS_SEED: u64 = 0xC7A05;

/// The scenario seed: `CHAOS_SEED` from the environment when set and
/// parseable, [`DEFAULT_CHAOS_SEED`] otherwise.
pub fn chaos_seed() -> u64 {
    parse_seed(std::env::var("CHAOS_SEED").ok())
}

fn parse_seed(var: Option<String>) -> u64 {
    var.and_then(|s| s.trim().parse().ok())
        .unwrap_or(DEFAULT_CHAOS_SEED)
}

/// One injectable fault.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Hard-kill one live worker node (picked from the session's live
    /// worker directory at fire time). Heartbeat-driven failover
    /// respawns the shard from its snapshot.
    KillWorker,
    /// Hard-kill one server slot. The server manager detects the dead
    /// node, freezes the group, restores the slot from its latest
    /// snapshot, and thaws.
    KillServerSlot { slot: usize },
    /// Grow the server ring `N → N+1` with drain-and-handoff
    /// ([`crate::ps::server::Elastic::grow`]) — live clients re-route
    /// on their next push/pull.
    GrowServerRing,
    /// Spike the simulated transport: every send pays `latency` extra
    /// and is dropped with probability `drop`.
    DegradeNet { latency: Duration, drop: f64 },
    /// Restore healthy transport.
    ClearDegrade,
    /// Resize the serving set to `to` replicas between generations
    /// (in-flight queries keep their pinned generation).
    ResizeReplicas { to: usize },
    /// Make `replica`'s next reload fail mid-prepare, then drive a
    /// reload into the fault (set keeps serving the old generation) and
    /// a recovery reload after it.
    AbortReplicaReload { replica: usize },
}

/// A fault scheduled against the training progress probe.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosEvent {
    /// Fire once median completed iterations reach this value.
    pub at_iteration: u64,
    pub fault: Fault,
}

/// A deterministic fault schedule (a pure function of its seed).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Events in firing order (ascending `at_iteration`).
    pub events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    /// The full membership-chaos drill, seeded: degrade the net, kill a
    /// worker, abort a replica reload, kill a server slot, grow the
    /// serving set, heal the net, grow the server ring, shrink the
    /// serving set — phased across `(start, end)` training iterations
    /// with seeded jitter. Which slot and which replica get hit is also
    /// drawn from the seed.
    pub fn seeded(
        seed: u64,
        start: u64,
        end: u64,
        n_servers: usize,
        replicas: usize,
    ) -> ChaosPlan {
        let mut rng = Rng::new(seed);
        let span = end.saturating_sub(start).max(8);
        // Phase p of 10, with jitter strictly below one phase width so
        // the drill's ordering (degrade before heal before grow) holds
        // for every seed.
        let at = |phase: u64, rng: &mut Rng| -> u64 {
            let jitter = rng.below(((span / 10).max(1)) as usize) as u64;
            (start + span * phase / 10 + jitter).clamp(start + 1, end.saturating_sub(1).max(start + 1))
        };
        let events = vec![
            ChaosEvent {
                at_iteration: at(1, &mut rng),
                fault: Fault::DegradeNet {
                    latency: Duration::from_micros(500),
                    drop: 0.02,
                },
            },
            ChaosEvent {
                at_iteration: at(2, &mut rng),
                fault: Fault::KillWorker,
            },
            ChaosEvent {
                at_iteration: at(3, &mut rng),
                fault: Fault::AbortReplicaReload {
                    replica: rng.below(replicas.max(1)),
                },
            },
            ChaosEvent {
                at_iteration: at(4, &mut rng),
                fault: Fault::KillServerSlot {
                    slot: rng.below(n_servers.max(1)),
                },
            },
            ChaosEvent {
                at_iteration: at(5, &mut rng),
                fault: Fault::ResizeReplicas { to: replicas + 1 },
            },
            ChaosEvent {
                at_iteration: at(6, &mut rng),
                fault: Fault::ClearDegrade,
            },
            ChaosEvent {
                at_iteration: at(7, &mut rng),
                fault: Fault::GrowServerRing,
            },
            ChaosEvent {
                at_iteration: at(8, &mut rng),
                fault: Fault::ResizeReplicas {
                    to: replicas.max(2) - 1,
                },
            },
        ];
        ChaosPlan { seed, events }
    }
}

/// What one chaos run actually did and what survived it.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    pub seed: u64,
    /// Human-readable fault log, in firing order.
    pub faults: Vec<String>,
    pub workers_killed: usize,
    pub server_slots_killed: usize,
    /// Replica reloads aborted by an injected mid-prepare fault.
    pub replica_reloads_aborted: usize,
    /// Serving-set membership changes committed (grows + shrinks).
    pub replica_resizes: usize,
    /// Handoff accounting from every server-ring grow.
    pub handoffs: Vec<HandoffStats>,
    /// Worker reassignments the session performed (failovers).
    pub reassignments: u64,
    pub target_iterations: u64,
    pub reached_iterations: u64,
    pub queries_sent: u64,
    pub queries_answered: u64,
    /// Post-chaos eval perplexity (the chaotic segment's final eval).
    pub final_perplexity: f64,
}

impl ChaosReport {
    /// Iterations the chaos cost (0 when the quorum still reached the
    /// target — the availability claim for training).
    pub fn iterations_lost(&self) -> u64 {
        self.target_iterations.saturating_sub(self.reached_iterations)
    }

    /// Queries that entered the stream but never got an answer (0 is
    /// the availability claim for serving).
    pub fn queries_dropped(&self) -> u64 {
        self.queries_sent.saturating_sub(self.queries_answered)
    }

    /// Multi-line summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("chaos run (seed {:#x})\n", self.seed));
        for f in &self.faults {
            out.push_str(&format!("  fault: {f}\n"));
        }
        out.push_str(&format!(
            "  killed: {} worker(s), {} server slot(s); {} replica reload(s) \
             aborted; {} serving resize(s)\n",
            self.workers_killed,
            self.server_slots_killed,
            self.replica_reloads_aborted,
            self.replica_resizes,
        ));
        for h in &self.handoffs {
            out.push_str(&format!(
                "  ring grow: {}/{} rows handed off ({:.1}% moved, complete={})\n",
                h.rows_moved,
                h.rows_total,
                h.moved_fraction() * 100.0,
                h.complete,
            ));
        }
        out.push_str(&format!(
            "  training: {}/{} iterations ({} lost), {} reassignment(s), \
             final perplexity {:.1}\n",
            self.reached_iterations,
            self.target_iterations,
            self.iterations_lost(),
            self.reassignments,
            self.final_perplexity,
        ));
        out.push_str(&format!(
            "  serving: {}/{} queries answered ({} dropped)\n",
            self.queries_answered,
            self.queries_sent,
            self.queries_dropped(),
        ));
        out
    }
}

/// Injector-side tally, shared between the injector thread and the
/// harness.
#[derive(Clone, Debug, Default)]
struct ChaosLog {
    faults: Vec<String>,
    workers_killed: usize,
    server_slots_killed: usize,
    replica_reloads_aborted: usize,
    replica_resizes: usize,
    handoffs: Vec<HandoffStats>,
}

/// A training config sized for chaos drills: multi-client, two server
/// slots, periodic snapshots (failover restore needs them), sub-ms
/// simulated latency.
pub fn chaos_train_config() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = ModelKind::AliasLda;
    cfg.params.topics = 8;
    cfg.corpus.n_docs = 120;
    cfg.corpus.vocab_size = 300;
    cfg.corpus.n_topics = 8;
    cfg.corpus.doc_len_mean = 12.0;
    cfg.cluster.clients = 3;
    // 3 clients × ⅔ → 2 server slots, so a slot kill and a ring grow
    // both have somewhere to go.
    cfg.cluster.server_fraction = 0.67;
    cfg.cluster.net.base_latency = Duration::from_micros(50);
    cfg.cluster.net.jitter = Duration::from_micros(50);
    // Failover restores workers and server slots from these.
    cfg.cluster.snapshot_every = Some(Duration::from_millis(100));
    cfg.iterations = 12;
    cfg.eval_every = 2;
    cfg.test_docs = 15;
    cfg
}

/// Drives one full chaos scenario: warm up a live session, checkpoint
/// it into a serving [`ReplicaSet`], then train the chaotic segment
/// while the plan's faults fire and a query stream runs.
pub struct ChaosHarness {
    cfg: TrainConfig,
    plan: ChaosPlan,
    /// Initial serving replica count.
    replicas: usize,
    /// Pre-chaos iterations (builds the checkpoint the serving set and
    /// every failover restore pull from).
    warmup: u64,
    /// Absolute iteration target of the chaotic segment.
    target: u64,
}

impl ChaosHarness {
    pub fn new(
        cfg: TrainConfig,
        plan: ChaosPlan,
        replicas: usize,
        warmup: u64,
        target: u64,
    ) -> ChaosHarness {
        ChaosHarness {
            cfg,
            plan,
            replicas,
            warmup,
            target,
        }
    }

    /// Run the scenario to completion and report what survived.
    pub fn run(self) -> Result<ChaosReport> {
        let ChaosHarness {
            cfg,
            plan,
            replicas,
            warmup,
            target,
        } = self;
        anyhow::ensure!(warmup >= 1, "chaos needs a warmup segment (≥ 1 iteration)");
        anyhow::ensure!(
            target > warmup,
            "chaos target ({target}) must exceed the warmup ({warmup})"
        );
        anyhow::ensure!(replicas >= 1, "serving needs at least one replica");

        let source = SyntheticSource::new(cfg.corpus.clone());
        let mut session = TrainSession::start(cfg, &source)?;
        session.run_to(warmup)?;

        // The checkpoint is both the serving set's snapshot directory
        // and the restore source for every failover the chaos causes.
        let dir = std::env::temp_dir().join(format!(
            "hplvm_chaos_{}_{:016x}",
            std::process::id(),
            plan.seed ^ session.run_id(),
        ));
        session.checkpoint(&dir)?;
        let set = ReplicaSet::load_dir(&dir, replicas)?;

        let stop = Arc::new(AtomicBool::new(false));

        // Query stream: continuous inference against the live set. Sent
        // is bumped before the call, answered after — a panic anywhere
        // in the serving path shows up as dropped queries.
        let q_sent = Arc::new(AtomicU64::new(0));
        let q_answered = Arc::new(AtomicU64::new(0));
        let query_thread = {
            let (set, stop) = (set.clone(), stop.clone());
            let (q_sent, q_answered) = (q_sent.clone(), q_answered.clone());
            let vocab = session.vocab();
            let seed = plan.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
                let icfg = InferConfig::default();
                // Absolute-deadline pacing: sleep-after-infer would add
                // each query's service time to the 200µs period and the
                // stream would sag under exactly the chaos-induced
                // latency it exists to probe.
                let mut pacer =
                    Pacer::new(std::time::Instant::now(), Duration::from_micros(200));
                while !stop.load(Ordering::Relaxed) {
                    pacer.wait();
                    let doc: Vec<u32> =
                        (0..16).map(|_| rng.below(vocab) as u32).collect();
                    q_sent.fetch_add(1, Ordering::Relaxed);
                    let res = set.infer(&doc, &icfg, &mut rng);
                    debug_assert!(!res.theta.is_empty());
                    q_answered.fetch_add(1, Ordering::Relaxed);
                }
            })
        };

        // Injector: fires each event once median progress reaches it.
        // After the segment ends the probe sits at the reached target,
        // so every remaining due event still fires (against the idle
        // but alive cluster) before the stop flag is honored.
        let log = Arc::new(Mutex::new(ChaosLog::default()));
        let injector = {
            let net = session.sim_net();
            let progress = session.progress_probe();
            let workers = session.worker_nodes();
            let elastic = session.elastic()?;
            let (set, stop, log) = (set.clone(), stop.clone(), log.clone());
            let mut pending: VecDeque<ChaosEvent> = plan.events.clone().into();
            let seed = plan.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ 0xC0FF_EE00);
                while let Some(next) = pending.front() {
                    if progress.load(Ordering::Relaxed) < next.at_iteration {
                        if stop.load(Ordering::Relaxed) {
                            let mut lg = log.lock().unwrap();
                            for e in &pending {
                                lg.faults.push(format!(
                                    "iter {}: {:?} skipped (segment over before \
                                     its iteration)",
                                    e.at_iteration, e.fault
                                ));
                            }
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    let ev = pending.pop_front().unwrap();
                    let mut lg = log.lock().unwrap();
                    match ev.fault {
                        Fault::KillWorker => {
                            let victim = {
                                let ws = workers.read().unwrap();
                                if ws.is_empty() {
                                    None
                                } else {
                                    Some(ws[rng.below(ws.len())])
                                }
                            };
                            match victim {
                                Some((shard, node)) => {
                                    net.kill(node);
                                    lg.workers_killed += 1;
                                    lg.faults.push(format!(
                                        "iter {}: killed worker shard {shard} \
                                         (node {node})",
                                        ev.at_iteration
                                    ));
                                }
                                None => lg.faults.push(format!(
                                    "iter {}: kill-worker skipped (no live \
                                     workers)",
                                    ev.at_iteration
                                )),
                            }
                        }
                        Fault::KillServerSlot { slot } => {
                            let slot = slot.min(elastic.n_slots() - 1);
                            elastic.kill_slot(slot);
                            lg.server_slots_killed += 1;
                            lg.faults.push(format!(
                                "iter {}: killed server slot {slot}",
                                ev.at_iteration
                            ));
                        }
                        Fault::GrowServerRing => {
                            // Grow assumes a healthy transport for its
                            // drain deadline; heal first.
                            net.clear_degraded();
                            let hs = elastic.grow();
                            lg.faults.push(format!(
                                "iter {}: grew server ring to {} slots \
                                 ({}/{} rows handed off, complete={})",
                                ev.at_iteration,
                                elastic.n_slots(),
                                hs.rows_moved,
                                hs.rows_total,
                                hs.complete
                            ));
                            lg.handoffs.push(hs);
                        }
                        Fault::DegradeNet { latency, drop } => {
                            net.set_degraded(latency, drop);
                            lg.faults.push(format!(
                                "iter {}: degraded net (+{latency:?}, drop \
                                 {drop})",
                                ev.at_iteration
                            ));
                        }
                        Fault::ClearDegrade => {
                            net.clear_degraded();
                            lg.faults.push(format!(
                                "iter {}: healed net",
                                ev.at_iteration
                            ));
                        }
                        Fault::ResizeReplicas { to } => match set.resize(to) {
                            Ok(gen) => {
                                lg.replica_resizes += 1;
                                lg.faults.push(format!(
                                    "iter {}: resized serving set to {to} \
                                     replica(s) (generation {gen})",
                                    ev.at_iteration
                                ));
                            }
                            Err(e) => lg.faults.push(format!(
                                "iter {}: resize to {to} failed: {e:#}",
                                ev.at_iteration
                            )),
                        },
                        Fault::AbortReplicaReload { replica } => {
                            let r = replica.min(set.replicas() - 1);
                            set.replica(r).fail_next_reload();
                            let aborted = set.reload_latest().is_err();
                            if aborted {
                                lg.replica_reloads_aborted += 1;
                            }
                            let recovered = set.reload_latest().is_ok();
                            lg.faults.push(format!(
                                "iter {}: replica {r} dropped mid-reload \
                                 (reload aborted={aborted}, retry \
                                 recovered={recovered})",
                                ev.at_iteration
                            ));
                        }
                    }
                }
            })
        };

        let seg = session.run_to(target)?;
        stop.store(true, Ordering::Relaxed);
        let _ = injector.join();
        let _ = query_thread.join();

        let reassignments = session.reassignments();
        let final_perplexity = seg.report.final_perplexity();
        let reached = seg.end_iteration;
        session.finish()?;
        let _ = std::fs::remove_dir_all(&dir);

        let lg = log.lock().unwrap().clone();
        Ok(ChaosReport {
            seed: plan.seed,
            faults: lg.faults,
            workers_killed: lg.workers_killed,
            server_slots_killed: lg.server_slots_killed,
            replica_reloads_aborted: lg.replica_reloads_aborted,
            replica_resizes: lg.replica_resizes,
            handoffs: lg.handoffs,
            reassignments,
            target_iterations: target,
            reached_iterations: reached,
            queries_sent: q_sent.load(Ordering::Relaxed),
            queries_answered: q_answered.load(Ordering::Relaxed),
            final_perplexity,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_parsing_prefers_env_value_and_falls_back() {
        assert_eq!(parse_seed(None), DEFAULT_CHAOS_SEED);
        assert_eq!(parse_seed(Some("not a number".into())), DEFAULT_CHAOS_SEED);
        assert_eq!(parse_seed(Some("12345".into())), 12345);
        assert_eq!(parse_seed(Some("  7 ".into())), 7);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_seed_sensitive() {
        let a = ChaosPlan::seeded(41, 4, 24, 2, 2);
        let b = ChaosPlan::seeded(41, 4, 24, 2, 2);
        assert_eq!(a, b, "same seed must give the identical plan");
        // Seeds vary the schedule: across a handful of seeds at least
        // two distinct plans must appear.
        let plans: std::collections::BTreeSet<String> = (0..8)
            .map(|s| format!("{:?}", ChaosPlan::seeded(s, 4, 24, 2, 2)))
            .collect();
        assert!(plans.len() >= 2, "seeds never vary the plan");
    }

    #[test]
    fn plan_phases_keep_their_ordering_constraints() {
        for seed in 0..32 {
            let plan = ChaosPlan::seeded(seed, 4, 24, 2, 2);
            assert_eq!(plan.events.len(), 8);
            // Ascending fire order, inside the (start, end) window.
            for w in plan.events.windows(2) {
                assert!(w[0].at_iteration <= w[1].at_iteration, "seed {seed}");
            }
            for e in &plan.events {
                assert!(e.at_iteration > 4 && e.at_iteration < 24, "seed {seed}");
            }
            // Degrade fires before the heal, the heal before the grow —
            // the grow's drain deadline assumes a healthy transport.
            let pos = |f: fn(&Fault) -> bool| {
                plan.events.iter().position(|e| f(&e.fault)).unwrap()
            };
            let degrade = pos(|f| matches!(f, Fault::DegradeNet { .. }));
            let heal = pos(|f| matches!(f, Fault::ClearDegrade));
            let grow = pos(|f| matches!(f, Fault::GrowServerRing));
            assert!(degrade < heal && heal < grow, "seed {seed}");
        }
    }

    #[test]
    fn report_accounting_derives_losses_and_drops() {
        let mut rep = ChaosReport::default();
        rep.target_iterations = 20;
        rep.reached_iterations = 18;
        rep.queries_sent = 1000;
        rep.queries_answered = 1000;
        assert_eq!(rep.iterations_lost(), 2);
        assert_eq!(rep.queries_dropped(), 0);
        rep.workers_killed = 1;
        rep.server_slots_killed = 1;
        let text = rep.render();
        assert!(text.contains("1 worker(s)"), "{text}");
        assert!(text.contains("0 dropped"), "{text}");
    }
}
