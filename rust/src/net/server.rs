//! The wire server: one accept thread round-robining accepted sockets
//! over N reactor threads ([`super::reactor`]), each reactor owning its
//! connections and its own micro-batching [`InferenceService`] worker
//! over the **shared** hot-reloadable backend.
//!
//! ```no_run
//! use hplvm::net::{ListenAddr, ModelInfo, WireConfig, WireServer};
//! use hplvm::serve::ServingHandle;
//! use std::sync::Arc;
//!
//! let handle = ServingHandle::load_dir(std::path::Path::new("snapshots")).unwrap();
//! let info = ModelInfo {
//!     family: handle.model().kind().family_name().to_string(),
//!     k: handle.model().k() as u32,
//!     vocab: handle.model().vocab() as u32,
//! };
//! let server = WireServer::start(
//!     handle.clone(),
//!     info,
//!     &ListenAddr::parse("127.0.0.1:0"),
//!     WireConfig::default(),
//! )
//! .unwrap();
//! println!("serving on {}", server.local_addr());
//! handle.reload_latest().ok(); // hot reload: in-flight wire queries unaffected
//! server.shutdown();
//! ```
//!
//! [`InferenceService`]: crate::serve::InferenceService

use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::reactor::{run_reactor, Counters, ModelInfo, Stream};
use crate::serve::handle::QueryBackend;
use crate::serve::service::ServeConfig;
use crate::Result;

/// Accept-thread poll interval when no connection is waiting.
const ACCEPT_IDLE: Duration = Duration::from_micros(500);

/// Where to listen.
#[derive(Clone, Debug)]
pub enum ListenAddr {
    /// TCP `host:port` (port 0 picks a free port — read it back from
    /// [`WireServer::local_addr`]).
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse a CLI-style address: `unix:/path/to.sock` for a Unix-domain
    /// socket, anything else as TCP `host:port`.
    pub fn parse(s: &str) -> ListenAddr {
        #[cfg(unix)]
        if let Some(path) = s.strip_prefix("unix:") {
            return ListenAddr::Unix(PathBuf::from(path));
        }
        ListenAddr::Tcp(s.to_string())
    }
}

impl std::fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ListenAddr::Tcp(a) => write!(f, "{a}"),
            #[cfg(unix)]
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// Wire-server configuration.
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Reactor threads (0 = one per available core).
    pub reactors: usize,
    /// Per-reactor [`InferenceService`](crate::serve::InferenceService)
    /// shape. Default: one worker per reactor (the thread-per-core
    /// budget: a reactor thread + its worker), shared service seed so
    /// every reactor derives identical per-request streams.
    pub service: ServeConfig,
    /// Drop a connection whose unflushed write buffer exceeds this
    /// (slow-consumer protection).
    pub max_wbuf_bytes: usize,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            reactors: 2,
            service: ServeConfig {
                workers: 1,
                ..ServeConfig::default()
            },
            max_wbuf_bytes: 8 << 20,
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(true)?;
                // Frames are small; Nagle would serialize request/response
                // round-trips at ~40 ms each.
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(true)?;
                Ok(Stream::Unix(s))
            }
        }
    }
}

/// Point-in-time server counters (see [`WireServer::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Connections accepted since start.
    pub accepted: u64,
    /// Connections currently open.
    pub connections: u64,
    /// Frames decoded since start.
    pub frames_in: u64,
    /// INFER queries answered.
    pub served: u64,
    /// Error frames sent.
    pub errors: u64,
    /// Reactor threads.
    pub reactors: u32,
}

/// A running wire front-end. [`shutdown`](Self::shutdown) (or drop)
/// stops the accept thread, closes every connection, and joins the
/// reactors.
pub struct WireServer {
    local: String,
    counters: Arc<Counters>,
    accept: Option<JoinHandle<()>>,
    reactors: Vec<JoinHandle<()>>,
    #[cfg(unix)]
    unix_path: Option<PathBuf>,
}

impl WireServer {
    /// Bind `addr` and start serving `backend` over the wire.
    pub fn start(
        backend: Arc<dyn QueryBackend>,
        info: ModelInfo,
        addr: &ListenAddr,
        cfg: WireConfig,
    ) -> Result<WireServer> {
        let n_reactors = if cfg.reactors == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
        } else {
            cfg.reactors
        };
        let (listener, local) = match addr {
            ListenAddr::Tcp(a) => {
                let l = TcpListener::bind(a)
                    .map_err(|e| anyhow::anyhow!("bind {a}: {e}"))?;
                let local = l
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| a.clone());
                l.set_nonblocking(true)
                    .map_err(|e| anyhow::anyhow!("set_nonblocking: {e}"))?;
                (Listener::Tcp(l), local)
            }
            #[cfg(unix)]
            ListenAddr::Unix(path) => {
                // A previous run's socket file would fail the bind.
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .map_err(|e| anyhow::anyhow!("bind unix:{}: {e}", path.display()))?;
                l.set_nonblocking(true)
                    .map_err(|e| anyhow::anyhow!("set_nonblocking: {e}"))?;
                (Listener::Unix(l), format!("unix:{}", path.display()))
            }
        };
        let counters = Arc::new(Counters::default());
        let mut reactors = Vec::with_capacity(n_reactors);
        let mut senders = Vec::with_capacity(n_reactors);
        for r in 0..n_reactors {
            let (tx, rx) = mpsc::channel::<Stream>();
            senders.push(tx);
            let backend = backend.clone();
            let info = info.clone();
            let service_cfg = cfg.service.clone();
            let counters = counters.clone();
            let max_wbuf = cfg.max_wbuf_bytes.max(1 << 16);
            let reactors_total = n_reactors as u32;
            reactors.push(
                std::thread::Builder::new()
                    .name(format!("wire-reactor-{r}"))
                    .spawn(move || {
                        run_reactor(
                            r,
                            rx,
                            backend,
                            info,
                            service_cfg,
                            counters,
                            max_wbuf,
                            reactors_total,
                        )
                    })
                    .map_err(|e| anyhow::anyhow!("spawn reactor: {e}"))?,
            );
        }
        let accept_counters = counters.clone();
        let accept = std::thread::Builder::new()
            .name("wire-accept".to_string())
            .spawn(move || {
                // Round-robin hand-off: reactor i gets every n-th socket.
                let mut next = 0usize;
                while !accept_counters.stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok(stream) => {
                            accept_counters.accepted.fetch_add(1, Ordering::Relaxed);
                            accept_counters.conns_open.fetch_add(1, Ordering::Relaxed);
                            if senders[next % senders.len()].send(stream).is_err() {
                                // Reactor gone (shutdown race): undo.
                                accept_counters.conns_open.fetch_sub(1, Ordering::Relaxed);
                            }
                            next = next.wrapping_add(1);
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_IDLE);
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(e) => {
                            crate::warn!("net", "accept failed: {e}");
                            std::thread::sleep(ACCEPT_IDLE);
                        }
                    }
                }
                // Dropping `senders` disconnects every reactor's handoff.
            })
            .map_err(|e| anyhow::anyhow!("spawn accept thread: {e}"))?;
        crate::info!(
            "net",
            "wire server listening on {local} ({n_reactors} reactors)"
        );
        #[cfg(unix)]
        let unix_path = match addr {
            ListenAddr::Unix(p) => Some(p.clone()),
            _ => None,
        };
        Ok(WireServer {
            local,
            counters,
            accept: Some(accept),
            reactors,
            #[cfg(unix)]
            unix_path,
        })
    }

    /// The bound address — for TCP with port 0, the resolved `host:port`;
    /// for Unix sockets, `unix:<path>`.
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// Counter snapshot (the same numbers a STATS frame reports).
    pub fn stats(&self) -> WireStats {
        WireStats {
            accepted: self.counters.accepted.load(Ordering::Relaxed),
            connections: self.counters.conns_open.load(Ordering::Relaxed),
            frames_in: self.counters.frames_in.load(Ordering::Relaxed),
            served: self.counters.served.load(Ordering::Relaxed),
            errors: self.counters.errors.load(Ordering::Relaxed),
            reactors: self.reactors.len() as u32,
        }
    }

    /// Stop accepting, close every connection, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.counters.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.reactors.drain(..) {
            let _ = h.join();
        }
        #[cfg(unix)]
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop();
    }
}
