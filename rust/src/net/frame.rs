//! Length-prefixed frame codec — the lowest layer of the wire protocol.
//!
//! Every message on a connection is one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     payload length N (u32, little-endian, ≤ MAX_FRAME_BYTES)
//! 4       1     protocol version (PROTO_VERSION)
//! 5       1     opcode (see super::proto::op)
//! 6       N     payload (opcode-specific, see super::proto)
//! ```
//!
//! The codec is deliberately dumb: [`decode`] only answers "is a complete
//! frame buffered, and is its declared length sane?". It does **not**
//! validate the version byte — a version-mismatched frame still parses,
//! so the server can answer it with an explicit
//! [`err::BAD_VERSION`](super::proto::err::BAD_VERSION) error frame
//! instead of hanging or closing silently. What it *does* enforce is the
//! length cap: a declared payload beyond [`MAX_FRAME_BYTES`] is rejected
//! as soon as the 4-byte header is readable, before any buffering of the
//! body — the guard that keeps a hostile or corrupt length prefix from
//! ballooning a connection's read buffer.
//!
//! Truncated input is never an error at this layer: [`decode`] returns
//! `Ok(None)` ("need more bytes") and the caller keeps accumulating.
//! Stream desynchronization therefore surfaces either here (absurd
//! declared length) or in [`super::proto`] (opcode/payload validation),
//! both of which the server converts into an error frame and a closed
//! connection.

/// Wire protocol version stamped into (and expected in) every frame.
pub const PROTO_VERSION: u8 = 1;

/// Hard cap on a frame's declared payload length. Generous for real
/// queries (a 1 MiB INFER payload carries ~260k word ids) while bounding
/// what a bad length prefix can make the server buffer.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Fixed bytes before the payload: length (4) + version (1) + opcode (1).
pub const HEADER_BYTES: usize = 6;

/// One decoded frame: version and opcode verbatim from the header (the
/// protocol layer validates them), payload copied out of the stream
/// buffer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// Protocol version byte as received (not yet validated).
    pub version: u8,
    /// Opcode byte as received (not yet validated).
    pub opcode: u8,
    /// Opcode-specific payload bytes.
    pub payload: Vec<u8>,
}

/// Connection-fatal framing error: the stream cannot be re-synchronized
/// after this, so the peer gets one error frame and the connection is
/// closed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Declared payload length exceeds [`MAX_FRAME_BYTES`].
    Oversize {
        /// The length the header declared.
        declared: usize,
    },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversize { declared } => write!(
                f,
                "declared frame payload of {declared} bytes exceeds the \
                 {MAX_FRAME_BYTES}-byte cap"
            ),
        }
    }
}

/// Append one encoded frame (with [`PROTO_VERSION`]) to `out`.
pub fn encode_into(out: &mut Vec<u8>, opcode: u8, payload: &[u8]) {
    encode_parts_into(out, PROTO_VERSION, opcode, payload);
}

/// Append one encoded frame with an explicit version byte — the hook the
/// version-mismatch tests (and any future protocol bump) use.
pub fn encode_parts_into(out: &mut Vec<u8>, version: u8, opcode: u8, payload: &[u8]) {
    debug_assert!(payload.len() <= MAX_FRAME_BYTES, "refusing to encode an oversize frame");
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.push(version);
    out.push(opcode);
    out.extend_from_slice(payload);
}

/// One encoded frame as a fresh buffer.
pub fn encode(opcode: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    encode_into(&mut out, opcode, payload);
    out
}

/// Try to decode one frame from the front of `buf`.
///
/// * `Ok(Some((frame, consumed)))` — a complete frame; the caller drains
///   `consumed` bytes and may call again (frames are back-to-back).
/// * `Ok(None)` — incomplete; keep reading. Never an error, so a
///   truncated frame (peer died mid-write) simply never completes.
/// * `Err(..)` — unrecoverable framing violation; close the connection.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let declared = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    // Reject an absurd length the moment it is readable — *before*
    // waiting for (and buffering) a body that may never come.
    if declared > MAX_FRAME_BYTES {
        return Err(FrameError::Oversize { declared });
    }
    let total = HEADER_BYTES + declared;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some((
        Frame {
            version: buf[4],
            opcode: buf[5],
            payload: buf[6..total].to_vec(),
        },
        total,
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trips_arbitrary_payloads() {
        // Property: encode → decode is the identity for arbitrary
        // (version, opcode, payload) triples, including empty and
        // max-size payloads.
        let mut rng = Rng::new(0xF7A3E);
        for case in 0..200 {
            let len = match case {
                0 => 0,
                1 => MAX_FRAME_BYTES,
                _ => rng.below(2_000),
            };
            let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let version = rng.next_u64() as u8;
            let opcode = rng.next_u64() as u8;
            let mut bytes = Vec::new();
            encode_parts_into(&mut bytes, version, opcode, &payload);
            let (frame, consumed) = decode(&bytes).unwrap().expect("complete frame");
            assert_eq!(consumed, bytes.len());
            assert_eq!(frame.version, version);
            assert_eq!(frame.opcode, opcode);
            assert_eq!(frame.payload, payload);
        }
    }

    #[test]
    fn every_truncation_is_incomplete_never_a_panic() {
        // Property: any strict prefix of a valid frame decodes to
        // "incomplete" — no prefix length panics or fabricates a frame.
        let mut rng = Rng::new(0xBEEF);
        let payload: Vec<u8> = (0..257).map(|_| rng.next_u64() as u8).collect();
        let bytes = encode(0x02, &payload);
        for cut in 0..bytes.len() {
            assert_eq!(
                decode(&bytes[..cut]).unwrap(),
                None,
                "prefix of {cut} bytes must be incomplete"
            );
        }
    }

    #[test]
    fn back_to_back_frames_decode_in_sequence() {
        let mut bytes = Vec::new();
        encode_into(&mut bytes, 1, b"first");
        encode_into(&mut bytes, 2, b"");
        encode_into(&mut bytes, 3, b"third");
        let mut rest: &[u8] = &bytes;
        let mut seen = Vec::new();
        while let Some((f, n)) = decode(rest).unwrap() {
            seen.push((f.opcode, f.payload));
            rest = &rest[n..];
        }
        assert!(rest.is_empty());
        assert_eq!(
            seen,
            vec![
                (1u8, b"first".to_vec()),
                (2, Vec::new()),
                (3, b"third".to_vec())
            ]
        );
    }

    #[test]
    fn oversize_length_rejected_from_header_alone() {
        // 4 header bytes declaring MAX+1: rejected immediately, with no
        // body buffered — and any continuation bytes change nothing.
        let declared = (MAX_FRAME_BYTES + 1) as u32;
        let mut bytes = declared.to_le_bytes().to_vec();
        assert_eq!(
            decode(&bytes),
            Err(FrameError::Oversize {
                declared: MAX_FRAME_BYTES + 1
            })
        );
        bytes.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7]);
        assert!(decode(&bytes).is_err());
        // The all-ones length a random/hostile peer is most likely to
        // produce is also caught.
        assert!(decode(&u32::MAX.to_le_bytes()).is_err());
    }

    #[test]
    fn max_size_frame_is_still_legal() {
        let payload = vec![0xABu8; MAX_FRAME_BYTES];
        let bytes = encode(9, &payload);
        let (frame, n) = decode(&bytes).unwrap().expect("max-size frame decodes");
        assert_eq!(n, HEADER_BYTES + MAX_FRAME_BYTES);
        assert_eq!(frame.payload.len(), MAX_FRAME_BYTES);
    }

    #[test]
    fn version_byte_passes_through_unvalidated() {
        // The codec hands mismatched versions up intact so the protocol
        // layer can answer with an error *frame* instead of dropping the
        // bytes on the floor.
        let mut bytes = Vec::new();
        encode_parts_into(&mut bytes, 99, 0x04, b"x");
        let (frame, _) = decode(&bytes).unwrap().unwrap();
        assert_eq!(frame.version, 99);
    }
}
