//! Wire front-end: a framed protocol server on a thread-per-core
//! reactor, plus the load-generation client that drives it — the layer
//! that turns the in-process serving stack into a deployable inference
//! tier behind a real network boundary (std-only TCP or Unix-domain
//! sockets; no async runtime, no dependencies).
//!
//! # Reactor model
//!
//! One **accept thread** owns the (nonblocking) listener and hands each
//! accepted socket to one of **N reactor threads**, round-robin. Each
//! reactor ([`reactor`]) owns its connections outright — read buffers,
//! write buffers, in-flight request table — so no lock is ever taken on
//! a connection, and each reactor runs its **own**
//! [`InferenceService`](crate::serve::InferenceService) micro-batch
//! worker over the **shared** hot-reloadable
//! [`QueryBackend`](crate::serve::QueryBackend) (a
//! [`ServingHandle`](crate::serve::ServingHandle) or a multi-replica
//! [`ReplicaSet`](crate::serve::ReplicaSet)). The per-reactor loop is:
//! adopt handed-off sockets → drain readable bytes → decode frames →
//! INFER frames become `submit_with_seed` jobs (micro-batching and
//! back-pressure engage exactly as in-process) → poll reply channels →
//! encode answers → flush. With the default one service worker per
//! reactor, N reactors cost 2N threads — the thread-per-core budget.
//!
//! Determinism crosses the wire intact: every INFER carries an explicit
//! request seed naming the service's RNG stream, so an answer is
//! bit-identical to the in-process answer at the same service seed —
//! independent of which reactor, which connection, or what arrival
//! order. Hot reloads swap the backend generation under the reactors;
//! in-flight micro-batches finish on the generation they pinned and
//! every response reports the generation that served it.
//!
//! # Frame grammar
//!
//! Every message is one length-prefixed frame ([`frame`]):
//!
//! ```text
//! [payload_len: u32 LE] [version: u8] [opcode: u8] [payload: len bytes]
//! ```
//!
//! with a 1 MiB payload cap (an over-declared length is rejected the
//! moment the 4 header bytes are readable — a hostile prefix cannot
//! balloon the read buffer). On top of that, [`proto`] defines the
//! messages; all payloads lead with a client-chosen correlation id
//! (pipelining-safe), integers are little-endian, θ travels as IEEE-754
//! bits:
//!
//! ```text
//! HELLO(id, family?)           → HELLO_OK(id, generation, k, vocab, family)
//! INFER(id, seed, min_gen, words) → INFER_OK(id, generation, latency_µs,
//!                                            tokens, θ[], served_by[])
//! STATS(id)                    → STATS_OK(id, generation, counters…)
//! PING(id)                     → PONG(id)
//! anything invalid             → ERROR(id, code, message)
//! ```
//!
//! Malformed payloads, foreign versions, and oversize frames get an
//! explicit ERROR frame and the connection closes (the stream can no
//! longer be trusted frame-to-frame); an unknown opcode in a well-formed
//! frame gets an ERROR and the connection survives. A family mismatch at
//! HELLO closes; a generation mismatch on INFER answers only that
//! request. Other connections are never affected.
//!
//! [`server`] assembles listener + accept thread + reactors into
//! [`WireServer`]; [`loadgen`] is the measuring client (open-loop or
//! closed-loop, qps/p50/p99/max, deterministic query streams shared with
//! the parity tests).

pub mod frame;
pub mod loadgen;
pub mod proto;
pub mod reactor;
pub mod server;

pub use loadgen::{
    connection_queries, hello, LoadReport, LoadgenConfig, Pacer, ServerHello, WireAnswer,
};
pub use reactor::{Counters, ModelInfo};
pub use server::{ListenAddr, WireConfig, WireServer, WireStats};
