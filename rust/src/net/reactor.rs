//! One reactor: a thread owning a set of nonblocking connections, a
//! decode → micro-batch → encode loop, and its own
//! [`InferenceService`] worker over the shared backend.
//!
//! The loop per iteration: adopt sockets handed off by the accept
//! thread, drain readable bytes into each connection's read buffer,
//! decode complete frames, convert INFER frames into
//! [`InferenceService::submit_with_seed`] jobs (so the micro-batch path
//! and the deterministic per-request RNG streams engage exactly as they
//! do in-process), poll pending reply channels, encode finished answers
//! into the write buffer, and flush what the socket will take. Control
//! frames (HELLO/STATS/PING) are answered inline. A connection is
//! dropped when the peer closes, on I/O error, when its write buffer
//! outgrows the slow-consumer cap, or after a connection-fatal protocol
//! error's error frame has been flushed.
//!
//! Queue back-pressure propagates naturally: `submit_with_seed` blocks
//! while the service queue is full, which stalls this reactor's decode
//! loop, which stops reading, which fills the peer's TCP window —
//! exactly the cascade an open-loop overload needs to hit the client.

use std::io::{self, Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use super::frame::{self, FrameError};
use super::proto::{self, err, Request, Response};
use crate::serve::handle::QueryBackend;
use crate::serve::infer::InferResult;
use crate::serve::service::{InferenceService, ServeConfig};

/// Sleep when a full pass over every connection made no progress.
const IDLE_SLEEP: Duration = Duration::from_micros(200);

/// Read chunk size per `read` call.
const READ_CHUNK: usize = 16 * 1024;

/// What the server tells clients about the model behind it (the
/// [`QueryBackend`] trait is deliberately metadata-free, so the server
/// captures this once at startup).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Serving family name (e.g. "LDA") — HELLO cross-checks it.
    pub family: String,
    /// Topic count: the length of every INFER_OK θ.
    pub k: u32,
    /// Vocabulary size (ids ≥ vocab are legal but never-observed: they
    /// fold in under pure smoothing).
    pub vocab: u32,
}

/// Counters shared by the accept thread, every reactor, and
/// [`WireServer::stats`](super::server::WireServer::stats).
#[derive(Debug, Default)]
pub struct Counters {
    /// Connections accepted since start.
    pub accepted: AtomicU64,
    /// Connections currently open.
    pub conns_open: AtomicU64,
    /// Frames decoded since start.
    pub frames_in: AtomicU64,
    /// INFER queries answered.
    pub served: AtomicU64,
    /// Error frames sent.
    pub errors: AtomicU64,
    /// Set by shutdown; every thread exits its loop on observing it.
    pub stop: AtomicBool,
}

/// A nonblocking byte stream — TCP or Unix-domain, one enum so the
/// reactor loop is transport-agnostic.
pub(crate) enum Stream {
    /// Loopback/remote TCP.
    Tcp(TcpStream),
    /// Unix-domain socket.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }

    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn shutdown(&self) {
        match self {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

struct Conn {
    stream: Stream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// In-flight INFER jobs: (request id, reply channel), answered in
    /// whatever order the service finishes them (ids correlate).
    pending: Vec<(u64, mpsc::Receiver<InferResult>)>,
    /// Peer closed its write side; drop once nothing is left to answer.
    read_closed: bool,
    /// Connection-fatal protocol error seen; stop reading, drop once the
    /// error frame (and any earlier answers) have flushed.
    closing: bool,
    /// Unrecoverable I/O state; drop immediately.
    dead: bool,
}

impl Conn {
    fn new(stream: Stream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            pending: Vec::new(),
            read_closed: false,
            closing: false,
            dead: false,
        }
    }

    fn done(&self) -> bool {
        self.dead
            || ((self.closing || self.read_closed)
                && self.wbuf.is_empty()
                && self.pending.is_empty())
    }
}

/// The reactor thread body. Owns its connections and its own
/// [`InferenceService`] (micro-batching worker pool) over the shared
/// backend; exits when `counters.stop` is set, closing every connection.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_reactor(
    reactor_id: usize,
    handoff: mpsc::Receiver<Stream>,
    backend: Arc<dyn QueryBackend>,
    info: ModelInfo,
    service_cfg: ServeConfig,
    counters: Arc<Counters>,
    max_wbuf: usize,
    reactors_total: u32,
) {
    let service = InferenceService::spawn(backend.clone(), service_cfg);
    let mut conns: Vec<Conn> = Vec::new();
    while !counters.stop.load(Ordering::Relaxed) {
        // Adopt newly accepted sockets.
        loop {
            match handoff.try_recv() {
                Ok(s) => conns.push(Conn::new(s)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        let mut progress = false;
        for conn in conns.iter_mut() {
            progress |= service_conn(conn, &service, &backend, &info, &counters, reactors_total);
            if conn.wbuf.len() > max_wbuf {
                crate::warn!(
                    "net",
                    "reactor {reactor_id}: dropping slow consumer ({} buffered bytes)",
                    conn.wbuf.len()
                );
                conn.dead = true;
            }
        }
        let before = conns.len();
        conns.retain(|c| {
            if c.done() {
                c.stream.shutdown();
                false
            } else {
                true
            }
        });
        let dropped = (before - conns.len()) as u64;
        if dropped > 0 {
            counters.conns_open.fetch_sub(dropped, Ordering::Relaxed);
            progress = true;
        }
        if !progress {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
    counters
        .conns_open
        .fetch_sub(conns.len() as u64, Ordering::Relaxed);
    for conn in &conns {
        conn.stream.shutdown();
    }
    drop(conns);
    service.shutdown();
}

/// One pass over one connection: read, decode, dispatch, poll replies,
/// flush. Returns whether any byte or answer moved.
fn service_conn(
    conn: &mut Conn,
    service: &InferenceService,
    backend: &Arc<dyn QueryBackend>,
    info: &ModelInfo,
    counters: &Arc<Counters>,
    reactors_total: u32,
) -> bool {
    let mut progress = false;

    // Read what the socket has.
    if !conn.read_closed && !conn.closing && !conn.dead {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.read_closed = true;
                    progress = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    progress = true;
                    if n < chunk.len() {
                        break;
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
    }

    // Decode every complete frame and dispatch it.
    let mut consumed = 0usize;
    while !conn.closing && !conn.dead {
        match frame::decode(&conn.rbuf[consumed..]) {
            Ok(Some((f, used))) => {
                consumed += used;
                counters.frames_in.fetch_add(1, Ordering::Relaxed);
                handle_frame(conn, &f, service, backend, info, counters, reactors_total);
                progress = true;
            }
            Ok(None) => break,
            Err(FrameError::Oversize { declared }) => {
                // The stream cannot re-synchronize after a bad length:
                // one error frame, then close.
                send_error(
                    conn,
                    counters,
                    0,
                    err::OVERSIZE,
                    &format!("declared frame of {declared} bytes exceeds the cap"),
                );
                conn.closing = true;
                progress = true;
            }
        }
    }
    if consumed > 0 {
        conn.rbuf.drain(..consumed);
    }

    // Poll in-flight INFER replies.
    if !conn.pending.is_empty() && !conn.dead {
        let pending = std::mem::take(&mut conn.pending);
        for (id, rx) in pending {
            match rx.try_recv() {
                Ok(res) => {
                    counters.served.fetch_add(1, Ordering::Relaxed);
                    send_response(
                        conn,
                        &Response::InferOk {
                            id,
                            generation: res.generation,
                            latency_micros: res.latency_micros,
                            tokens: res.tokens as u32,
                            theta: res.theta,
                            served_by: res.served_by,
                        },
                    );
                    progress = true;
                }
                Err(mpsc::TryRecvError::Empty) => conn.pending.push((id, rx)),
                Err(mpsc::TryRecvError::Disconnected) => {
                    send_error(conn, counters, id, err::SHUTTING_DOWN, "service stopped");
                    progress = true;
                }
            }
        }
    }

    // Flush what the socket will take.
    while !conn.wbuf.is_empty() && !conn.dead {
        match conn.stream.write(&conn.wbuf) {
            Ok(0) => {
                conn.dead = true;
            }
            Ok(n) => {
                conn.wbuf.drain(..n);
                progress = true;
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
            }
        }
    }

    progress
}

fn send_response(conn: &mut Conn, res: &Response) {
    proto::encode_response_into(&mut conn.wbuf, res);
}

fn send_error(conn: &mut Conn, counters: &Arc<Counters>, id: u64, code: u8, message: &str) {
    counters.errors.fetch_add(1, Ordering::Relaxed);
    send_response(
        conn,
        &Response::Error {
            id,
            code,
            message: message.to_string(),
        },
    );
}

fn handle_frame(
    conn: &mut Conn,
    f: &frame::Frame,
    service: &InferenceService,
    backend: &Arc<dyn QueryBackend>,
    info: &ModelInfo,
    counters: &Arc<Counters>,
    reactors_total: u32,
) {
    let req = match proto::decode_request(f) {
        Ok(req) => req,
        Err(e) => {
            send_error(conn, counters, e.id, e.code, &e.message);
            // A malformed payload or foreign version means the stream
            // can't be trusted frame-to-frame; an unknown opcode arrived
            // in a well-formed frame, so the connection survives it.
            if e.code != err::UNKNOWN_OPCODE {
                conn.closing = true;
            }
            return;
        }
    };
    match req {
        Request::Hello { id, family } => {
            if !family.is_empty() && family != info.family {
                send_error(
                    conn,
                    counters,
                    id,
                    err::FAMILY_MISMATCH,
                    &format!("server family is {}, client expects {family}", info.family),
                );
                conn.closing = true;
                return;
            }
            send_response(
                conn,
                &Response::HelloOk {
                    id,
                    generation: backend.generation(),
                    k: info.k,
                    vocab: info.vocab,
                    family: info.family.clone(),
                },
            );
        }
        Request::Infer {
            id,
            seed,
            min_generation,
            tokens,
        } => {
            if min_generation > 0 && backend.generation() < min_generation {
                send_error(
                    conn,
                    counters,
                    id,
                    err::GENERATION_MISMATCH,
                    &format!(
                        "serving generation {} < required {min_generation}",
                        backend.generation()
                    ),
                );
                return;
            }
            // May block on a full service queue — that *is* the
            // back-pressure path (see module docs).
            let rx = service.submit_with_seed(tokens, seed);
            conn.pending.push((id, rx));
        }
        Request::Stats { id } => {
            send_response(
                conn,
                &Response::StatsOk {
                    id,
                    generation: backend.generation(),
                    served: counters.served.load(Ordering::Relaxed),
                    errors: counters.errors.load(Ordering::Relaxed),
                    connections: counters.conns_open.load(Ordering::Relaxed),
                    accepted: counters.accepted.load(Ordering::Relaxed),
                    frames_in: counters.frames_in.load(Ordering::Relaxed),
                    reactors: reactors_total,
                },
            );
        }
        Request::Ping { id } => {
            send_response(conn, &Response::Pong { id });
        }
    }
}
