//! Wire message grammar on top of the [`frame`](super::frame) codec.
//!
//! Every request and response payload starts with a caller-chosen `id`
//! (u64) echoed verbatim in the answer, so clients may pipeline any
//! number of requests per connection and match answers out of order.
//! All integers are little-endian; `f64`s travel as their IEEE-754 bit
//! patterns (`to_bits`/`from_bits`), so a topic mixture crosses the wire
//! **bit-exactly** — the property the routed-parity and in-process-parity
//! tests assert end to end.
//!
//! ```text
//! request  opcode  payload
//! HELLO    0x01    id:u64  family:str        (family "" = no check)
//! INFER    0x02    id:u64  seed:u64  min_generation:u64  n:u32  word:u32 ×n
//! STATS    0x03    id:u64
//! PING     0x04    id:u64
//!
//! response opcode  payload
//! HELLO_OK 0x81    id:u64  generation:u64  k:u32  vocab:u32  family:str
//! INFER_OK 0x82    id:u64  generation:u64  latency_micros:u64  tokens:u32
//!                  n:u32  theta_bits:u64 ×n  m:u32  served_by:u32 ×m
//! STATS_OK 0x83    id:u64  generation:u64  served:u64  errors:u64
//!                  connections:u64  accepted:u64  frames_in:u64  reactors:u32
//! PONG     0x84    id:u64
//! ERROR    0xFF    id:u64  code:u8  message:str
//!
//! str ::= len:u32  utf8 ×len              (len ≤ 65536)
//! ```
//!
//! Decoding is strict: short payloads, over-declared counts, non-UTF-8
//! strings, and trailing garbage all fail with
//! [`err::MALFORMED`], which the server converts into an ERROR frame.
//! An unknown opcode in a well-formed frame is [`err::UNKNOWN_OPCODE`]
//! (connection survives); a version-byte mismatch is
//! [`err::BAD_VERSION`] (connection closes after the error frame).

use super::frame::{Frame, PROTO_VERSION};

/// Request/response opcodes. Responses set the high bit of the request
/// they answer; ERROR answers anything.
pub mod op {
    /// Handshake: optional family cross-check, returns model shape.
    pub const HELLO: u8 = 0x01;
    /// Fold-in query: word ids + per-request RNG seed.
    pub const INFER: u8 = 0x02;
    /// Server-wide counters.
    pub const STATS: u8 = 0x03;
    /// Liveness probe.
    pub const PING: u8 = 0x04;
    /// Answer to [`HELLO`].
    pub const HELLO_OK: u8 = 0x81;
    /// Answer to [`INFER`].
    pub const INFER_OK: u8 = 0x82;
    /// Answer to [`STATS`].
    pub const STATS_OK: u8 = 0x83;
    /// Answer to [`PING`].
    pub const PONG: u8 = 0x84;
    /// Error answer to any request.
    pub const ERROR: u8 = 0xFF;
}

/// Error-frame codes.
pub mod err {
    /// Payload failed to parse (short, over-declared, trailing bytes…).
    pub const MALFORMED: u8 = 1;
    /// Frame's version byte is not [`super::PROTO_VERSION`].
    pub const BAD_VERSION: u8 = 2;
    /// Well-formed frame, opcode this server does not speak.
    pub const UNKNOWN_OPCODE: u8 = 3;
    /// Declared frame length beyond the cap (connection closes).
    pub const OVERSIZE: u8 = 4;
    /// HELLO named a family the served snapshot does not belong to.
    pub const FAMILY_MISMATCH: u8 = 5;
    /// INFER demanded `min_generation` newer than what is live.
    pub const GENERATION_MISMATCH: u8 = 6;
    /// Server is shutting down; the request was not answered.
    pub const SHUTTING_DOWN: u8 = 7;
}

/// Longest accepted string field (family names, error messages).
const MAX_STR_BYTES: usize = 65_536;

/// A client→server message.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Handshake; `family` "" skips the family cross-check.
    Hello {
        /// Correlation id echoed in the answer.
        id: u64,
        /// Expected serving family name ("" = accept any).
        family: String,
    },
    /// Fold a document in and return its topic mixture.
    Infer {
        /// Correlation id echoed in the answer.
        id: u64,
        /// Per-request RNG stream: the service derives
        /// `Rng::new(service_seed).derive(seed)`, so the answer is
        /// deterministic however requests interleave across connections.
        seed: u64,
        /// Refuse (GENERATION_MISMATCH) unless the live generation is at
        /// least this; 0 accepts any.
        min_generation: u64,
        /// The document's word ids.
        tokens: Vec<u32>,
    },
    /// Server-wide counter snapshot.
    Stats {
        /// Correlation id echoed in the answer.
        id: u64,
    },
    /// Liveness probe.
    Ping {
        /// Correlation id echoed in the answer.
        id: u64,
    },
}

/// A server→client message.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Handshake answer: the served model's shape.
    HelloOk {
        /// Echo of the request id.
        id: u64,
        /// Live serving generation.
        generation: u64,
        /// Topic count (θ length of every INFER_OK).
        k: u32,
        /// Vocabulary size (valid word ids are `0..vocab`).
        vocab: u32,
        /// Serving family name (e.g. "LDA").
        family: String,
    },
    /// A topic mixture.
    InferOk {
        /// Echo of the request id.
        id: u64,
        /// Generation that served the query.
        generation: u64,
        /// Queue + service time stamped by the service worker — the same
        /// measurement the in-process bench reports.
        latency_micros: u64,
        /// Tokens folded in.
        tokens: u32,
        /// Topic mixture, bit-exact.
        theta: Vec<f64>,
        /// Replicas that contributed (empty on a single-model backend).
        served_by: Vec<u32>,
    },
    /// Server-wide counters.
    StatsOk {
        /// Echo of the request id.
        id: u64,
        /// Live serving generation.
        generation: u64,
        /// INFER queries answered.
        served: u64,
        /// Error frames sent.
        errors: u64,
        /// Connections currently open.
        connections: u64,
        /// Connections accepted since start.
        accepted: u64,
        /// Frames decoded since start.
        frames_in: u64,
        /// Reactor threads.
        reactors: u32,
    },
    /// Liveness answer.
    Pong {
        /// Echo of the request id.
        id: u64,
    },
    /// Request-level failure (see [`err`] for codes).
    Error {
        /// Echo of the request id (0 when it could not be parsed).
        id: u64,
        /// One of the [`err`] codes.
        code: u8,
        /// Human-readable detail.
        message: String,
    },
}

/// A protocol-level decode failure: the error code to answer with, the
/// request id when one was recoverable, and a message for the frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProtoError {
    /// One of the [`err`] codes.
    pub code: u8,
    /// Best-effort request id recovered from the payload (0 if none).
    pub id: u64,
    /// Human-readable detail.
    pub message: String,
}

// ---- little-endian payload building ----------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let take = bytes.len().min(MAX_STR_BYTES);
    put_u32(out, take as u32);
    out.extend_from_slice(&bytes[..take]);
}

// ---- strict payload reading ------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() - self.pos < n {
            return Err(format!(
                "payload truncated: wanted {n} more bytes, {} left",
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        if n > MAX_STR_BYTES {
            return Err(format!("string field of {n} bytes exceeds the cap"));
        }
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "string field is not UTF-8".to_string())
    }

    /// Error unless every payload byte was consumed — trailing garbage
    /// marks a desynchronized or corrupt stream.
    fn finish(self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!(
                "{} trailing bytes after the message body",
                self.buf.len() - self.pos
            ))
        }
    }
}

/// Best-effort request id from a payload (for error frames answering
/// unparseable requests): every message begins with one.
fn peek_id(payload: &[u8]) -> u64 {
    if payload.len() >= 8 {
        u64::from_le_bytes([
            payload[0], payload[1], payload[2], payload[3], payload[4], payload[5], payload[6],
            payload[7],
        ])
    } else {
        0
    }
}

// ---- requests ---------------------------------------------------------

/// Encode a request as a complete frame, appended to `out`.
pub fn encode_request_into(out: &mut Vec<u8>, req: &Request) {
    let mut p = Vec::new();
    let opcode = match req {
        Request::Hello { id, family } => {
            put_u64(&mut p, *id);
            put_str(&mut p, family);
            op::HELLO
        }
        Request::Infer {
            id,
            seed,
            min_generation,
            tokens,
        } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *seed);
            put_u64(&mut p, *min_generation);
            put_u32(&mut p, tokens.len() as u32);
            for &w in tokens {
                put_u32(&mut p, w);
            }
            op::INFER
        }
        Request::Stats { id } => {
            put_u64(&mut p, *id);
            op::STATS
        }
        Request::Ping { id } => {
            put_u64(&mut p, *id);
            op::PING
        }
    };
    super::frame::encode_into(out, opcode, &p);
}

/// Decode a request frame, validating version, opcode, and payload.
pub fn decode_request(frame: &Frame) -> Result<Request, ProtoError> {
    let id = peek_id(&frame.payload);
    if frame.version != PROTO_VERSION {
        return Err(ProtoError {
            code: err::BAD_VERSION,
            id,
            message: format!(
                "protocol version {} not supported (this server speaks {PROTO_VERSION})",
                frame.version
            ),
        });
    }
    let malformed = |id: u64, m: String| ProtoError {
        code: err::MALFORMED,
        id,
        message: m,
    };
    let mut r = Reader::new(&frame.payload);
    match frame.opcode {
        op::HELLO => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let family = r.str().map_err(|m| malformed(id, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Request::Hello { id, family })
        }
        op::INFER => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let seed = r.u64().map_err(|m| malformed(id, m))?;
            let min_generation = r.u64().map_err(|m| malformed(id, m))?;
            let n = r.u32().map_err(|m| malformed(id, m))? as usize;
            // The count is bounded by the frame itself: refuse an
            // over-declared count before allocating for it.
            if n * 4 > frame.payload.len() {
                return Err(malformed(
                    id,
                    format!(
                        "declared {n} tokens but the payload holds at most {}",
                        frame.payload.len() / 4
                    ),
                ));
            }
            let mut tokens = Vec::with_capacity(n);
            for _ in 0..n {
                tokens.push(r.u32().map_err(|m| malformed(id, m))?);
            }
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Request::Infer {
                id,
                seed,
                min_generation,
                tokens,
            })
        }
        op::STATS => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Request::Stats { id })
        }
        op::PING => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Request::Ping { id })
        }
        other => Err(ProtoError {
            code: err::UNKNOWN_OPCODE,
            id,
            message: format!("unknown request opcode {other:#04x}"),
        }),
    }
}

// ---- responses --------------------------------------------------------

/// Encode a response as a complete frame, appended to `out`.
pub fn encode_response_into(out: &mut Vec<u8>, res: &Response) {
    let mut p = Vec::new();
    let opcode = match res {
        Response::HelloOk {
            id,
            generation,
            k,
            vocab,
            family,
        } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *generation);
            put_u32(&mut p, *k);
            put_u32(&mut p, *vocab);
            put_str(&mut p, family);
            op::HELLO_OK
        }
        Response::InferOk {
            id,
            generation,
            latency_micros,
            tokens,
            theta,
            served_by,
        } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *generation);
            put_u64(&mut p, *latency_micros);
            put_u32(&mut p, *tokens);
            put_u32(&mut p, theta.len() as u32);
            for &t in theta {
                put_u64(&mut p, t.to_bits());
            }
            put_u32(&mut p, served_by.len() as u32);
            for &r in served_by {
                put_u32(&mut p, r);
            }
            op::INFER_OK
        }
        Response::StatsOk {
            id,
            generation,
            served,
            errors,
            connections,
            accepted,
            frames_in,
            reactors,
        } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *generation);
            put_u64(&mut p, *served);
            put_u64(&mut p, *errors);
            put_u64(&mut p, *connections);
            put_u64(&mut p, *accepted);
            put_u64(&mut p, *frames_in);
            put_u32(&mut p, *reactors);
            op::STATS_OK
        }
        Response::Pong { id } => {
            put_u64(&mut p, *id);
            op::PONG
        }
        Response::Error { id, code, message } => {
            put_u64(&mut p, *id);
            p.push(*code);
            put_str(&mut p, message);
            op::ERROR
        }
    };
    super::frame::encode_into(out, opcode, &p);
}

/// Decode a response frame (the client side of [`decode_request`]).
pub fn decode_response(frame: &Frame) -> Result<Response, ProtoError> {
    let id = peek_id(&frame.payload);
    if frame.version != PROTO_VERSION {
        return Err(ProtoError {
            code: err::BAD_VERSION,
            id,
            message: format!("response carries protocol version {}", frame.version),
        });
    }
    let malformed = |id: u64, m: String| ProtoError {
        code: err::MALFORMED,
        id,
        message: m,
    };
    let mut r = Reader::new(&frame.payload);
    match frame.opcode {
        op::HELLO_OK => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let generation = r.u64().map_err(|m| malformed(id, m))?;
            let k = r.u32().map_err(|m| malformed(id, m))?;
            let vocab = r.u32().map_err(|m| malformed(id, m))?;
            let family = r.str().map_err(|m| malformed(id, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Response::HelloOk {
                id,
                generation,
                k,
                vocab,
                family,
            })
        }
        op::INFER_OK => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let generation = r.u64().map_err(|m| malformed(id, m))?;
            let latency_micros = r.u64().map_err(|m| malformed(id, m))?;
            let tokens = r.u32().map_err(|m| malformed(id, m))?;
            let n = r.u32().map_err(|m| malformed(id, m))? as usize;
            if n * 8 > frame.payload.len() {
                return Err(malformed(id, format!("declared {n} θ entries overrun the payload")));
            }
            let mut theta = Vec::with_capacity(n);
            for _ in 0..n {
                theta.push(f64::from_bits(r.u64().map_err(|m| malformed(id, m))?));
            }
            let m_n = r.u32().map_err(|m| malformed(id, m))? as usize;
            if m_n * 4 > frame.payload.len() {
                return Err(malformed(id, format!("declared {m_n} replica ids overrun the payload")));
            }
            let mut served_by = Vec::with_capacity(m_n);
            for _ in 0..m_n {
                served_by.push(r.u32().map_err(|m| malformed(id, m))?);
            }
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Response::InferOk {
                id,
                generation,
                latency_micros,
                tokens,
                theta,
                served_by,
            })
        }
        op::STATS_OK => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let generation = r.u64().map_err(|m| malformed(id, m))?;
            let served = r.u64().map_err(|m| malformed(id, m))?;
            let errors = r.u64().map_err(|m| malformed(id, m))?;
            let connections = r.u64().map_err(|m| malformed(id, m))?;
            let accepted = r.u64().map_err(|m| malformed(id, m))?;
            let frames_in = r.u64().map_err(|m| malformed(id, m))?;
            let reactors = r.u32().map_err(|m| malformed(id, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Response::StatsOk {
                id,
                generation,
                served,
                errors,
                connections,
                accepted,
                frames_in,
                reactors,
            })
        }
        op::PONG => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Response::Pong { id })
        }
        op::ERROR => {
            let id = r.u64().map_err(|m| malformed(0, m))?;
            let code = r.u8().map_err(|m| malformed(id, m))?;
            let message = r.str().map_err(|m| malformed(id, m))?;
            r.finish().map_err(|m| malformed(id, m))?;
            Ok(Response::Error { id, code, message })
        }
        other => Err(ProtoError {
            code: err::UNKNOWN_OPCODE,
            id,
            message: format!("unknown response opcode {other:#04x}"),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame;
    use crate::util::rng::Rng;

    fn round_trip_request(req: Request) -> Request {
        let mut bytes = Vec::new();
        encode_request_into(&mut bytes, &req);
        let (f, n) = frame::decode(&bytes).unwrap().expect("complete");
        assert_eq!(n, bytes.len());
        decode_request(&f).expect("valid request")
    }

    fn round_trip_response(res: Response) -> Response {
        let mut bytes = Vec::new();
        encode_response_into(&mut bytes, &res);
        let (f, n) = frame::decode(&bytes).unwrap().expect("complete");
        assert_eq!(n, bytes.len());
        decode_response(&f).expect("valid response")
    }

    #[test]
    fn requests_round_trip_on_arbitrary_payloads() {
        let mut rng = Rng::new(0x11E5);
        for _ in 0..100 {
            let req = match rng.below(4) {
                0 => Request::Hello {
                    id: rng.next_u64(),
                    family: if rng.coin(0.5) { "LDA".into() } else { String::new() },
                },
                1 => Request::Infer {
                    id: rng.next_u64(),
                    seed: rng.next_u64(),
                    min_generation: rng.next_u64() % 4,
                    tokens: (0..rng.below(300)).map(|_| rng.next_u64() as u32).collect(),
                },
                2 => Request::Stats { id: rng.next_u64() },
                _ => Request::Ping { id: rng.next_u64() },
            };
            assert_eq!(round_trip_request(req.clone()), req);
        }
    }

    #[test]
    fn responses_round_trip_with_bit_exact_theta() {
        let mut rng = Rng::new(0x2E55);
        for _ in 0..100 {
            // Exotic but legal f64 values must survive bit-exactly.
            let theta: Vec<f64> = (0..rng.below(64) + 1)
                .map(|i| match i % 5 {
                    0 => rng.f64(),
                    1 => f64::MIN_POSITIVE,
                    2 => 1.0 / 3.0,
                    3 => 1e-300,
                    _ => rng.f64() * 1e18,
                })
                .collect();
            let res = Response::InferOk {
                id: rng.next_u64(),
                generation: rng.next_u64() % 100,
                latency_micros: rng.next_u64() % 1_000_000,
                tokens: rng.next_u64() as u32 % 1000,
                theta: theta.clone(),
                served_by: (0..rng.below(5)).map(|r| r as u32).collect(),
            };
            match round_trip_response(res.clone()) {
                Response::InferOk { theta: got, .. } => {
                    assert_eq!(got.len(), theta.len());
                    for (a, b) in got.iter().zip(theta.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "θ not bit-exact");
                    }
                }
                other => panic!("wrong variant {other:?}"),
            }
        }
        let res = Response::Error {
            id: 7,
            code: err::FAMILY_MISMATCH,
            message: "nope".into(),
        };
        assert_eq!(round_trip_response(res.clone()), res);
        let stats = Response::StatsOk {
            id: 1,
            generation: 2,
            served: 3,
            errors: 4,
            connections: 5,
            accepted: 6,
            frames_in: 7,
            reactors: 8,
        };
        assert_eq!(round_trip_response(stats.clone()), stats);
    }

    #[test]
    fn truncated_and_over_declared_payloads_are_malformed_not_panics() {
        // Build a valid INFER, then mutilate the payload every way a
        // hostile peer can while keeping the frame itself well-formed.
        let req = Request::Infer {
            id: 42,
            seed: 9,
            min_generation: 0,
            tokens: vec![1, 2, 3, 4, 5],
        };
        let mut bytes = Vec::new();
        encode_request_into(&mut bytes, &req);
        let (full, _) = frame::decode(&bytes).unwrap().unwrap();
        // Every strict payload prefix: MALFORMED, never a panic.
        for cut in 0..full.payload.len() {
            let f = Frame {
                version: PROTO_VERSION,
                opcode: op::INFER,
                payload: full.payload[..cut].to_vec(),
            };
            let e = decode_request(&f).expect_err("truncated payload must fail");
            assert_eq!(e.code, err::MALFORMED, "cut {cut}");
        }
        // Over-declared token count (count bytes live at offset 24).
        let mut p = full.payload.clone();
        p[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = decode_request(&Frame {
            version: PROTO_VERSION,
            opcode: op::INFER,
            payload: p,
        })
        .expect_err("over-declared count must fail");
        assert_eq!(e.code, err::MALFORMED);
        assert_eq!(e.id, 42, "id recoverable from a malformed body");
        // Trailing garbage after a valid body.
        let mut p = full.payload.clone();
        p.push(0xEE);
        let e = decode_request(&Frame {
            version: PROTO_VERSION,
            opcode: op::INFER,
            payload: p,
        })
        .expect_err("trailing bytes must fail");
        assert_eq!(e.code, err::MALFORMED);
    }

    #[test]
    fn version_and_opcode_violations_map_to_their_codes() {
        let mut bytes = Vec::new();
        encode_request_into(&mut bytes, &Request::Ping { id: 5 });
        let (mut f, _) = frame::decode(&bytes).unwrap().unwrap();
        f.version = 9;
        let e = decode_request(&f).expect_err("bad version");
        assert_eq!((e.code, e.id), (err::BAD_VERSION, 5));
        let f = Frame {
            version: PROTO_VERSION,
            opcode: 0x77,
            payload: 123u64.to_le_bytes().to_vec(),
        };
        let e = decode_request(&f).expect_err("unknown opcode");
        assert_eq!((e.code, e.id), (err::UNKNOWN_OPCODE, 123));
    }
}
