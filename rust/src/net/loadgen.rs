//! Load-test client for the wire server: C concurrent connections, each
//! driving a deterministic query stream, with open-loop (scheduled
//! arrivals at a target rate) or closed-loop (bounded in-flight window)
//! pacing, reporting qps / p50 / p99 / max and error counts.
//!
//! Open-loop latency is charged from each request's *scheduled* send
//! time, not the moment the socket accepted it — when the server falls
//! behind, the queueing delay counts against it (no coordinated
//! omission).
//!
//! The query stream is exposed as [`connection_queries`] so the parity
//! tests can replay exactly what the loadgen sent through the in-process
//! [`InferenceService`](crate::serve::InferenceService) and compare θ
//! bit-for-bit: request `seed`s name the service's RNG streams, making
//! the wire answer independent of arrival order.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::{Duration, Instant};

use super::frame;
use super::proto::{self, Request, Response};
use crate::bench::percentile;
use crate::serve::service::synth_queries;
use crate::util::rng::Rng;
use crate::Result;

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent connections.
    pub connections: usize,
    /// Requests **per connection**.
    pub requests: usize,
    /// Target total arrival rate in requests/sec across all connections;
    /// 0 = closed loop (each connection keeps `window` in flight).
    pub rate: f64,
    /// Closed-loop in-flight window per connection.
    pub window: usize,
    /// Vocabulary the synthetic queries draw words from.
    pub vocab: usize,
    /// Mean document length (Poisson).
    pub doc_len: f64,
    /// Seed for the deterministic query streams.
    pub seed: u64,
    /// `min_generation` stamped on every INFER (0 = any).
    pub min_generation: u64,
    /// Collect every answer's θ into [`LoadReport::responses`] (parity
    /// tests); off for pure load runs.
    pub keep_responses: bool,
    /// Give up on answers not seen by this deadline per connection.
    pub timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            connections: 8,
            requests: 64,
            rate: 0.0,
            window: 4,
            vocab: 1_000,
            doc_len: 20.0,
            seed: 42,
            min_generation: 0,
            keep_responses: false,
            timeout: Duration::from_secs(30),
        }
    }
}

/// The deterministic query stream of one connection: `(request seed,
/// word ids)` per request. Pure function of `(cfg.seed, cfg.vocab,
/// cfg.doc_len, cfg.requests, conn)` — the parity tests rebuild it to
/// replay the identical load in-process.
pub fn connection_queries(cfg: &LoadgenConfig, conn: usize) -> Vec<(u64, Vec<u32>)> {
    let doc_seed = Rng::new(cfg.seed).derive(conn as u64).next_u64();
    let docs = synth_queries(cfg.vocab, cfg.requests, cfg.doc_len, doc_seed);
    // Request seeds from an independent derived stream: distinct across
    // connections and requests, stable across runs.
    let mut seeds = Rng::new(cfg.seed ^ 0x5EED_C0FF_EE00_0001).derive(conn as u64);
    docs.into_iter().map(|d| (seeds.next_u64(), d)).collect()
}

/// Absolute-deadline pacer for fixed-rate loops.
///
/// The naive pattern — do the tick's work, then `sleep(interval)` —
/// drifts: tick `i` starts after `Σ(workⱼ + interval)`, so every
/// microsecond of work (or sleep overshoot) pushes the whole schedule
/// later, and the achieved rate sags below the target the longer the
/// run. A `Pacer` fixes the schedule up front instead: tick `i` is due
/// at `start + i·interval`, independent of how long any tick took. A
/// slow tick is followed by immediately-due catch-up ticks, so the
/// long-run rate holds exactly. Used by the open-loop send schedule
/// here and by the chaos harness's query stream.
#[derive(Clone, Debug)]
pub struct Pacer {
    start: Instant,
    interval: Duration,
    next: u64,
}

impl Pacer {
    /// A pacer whose tick `i` is due at `start + i·interval`.
    pub fn new(start: Instant, interval: Duration) -> Pacer {
        Pacer {
            start,
            interval,
            next: 0,
        }
    }

    /// Deadline of the next unconsumed tick.
    pub fn due(&self) -> Instant {
        self.start + self.interval.mul_f64(self.next as f64)
    }

    /// Is the next tick due at `now`?
    pub fn is_due(&self, now: Instant) -> bool {
        self.due() <= now
    }

    /// Consume the next tick, returning its scheduled deadline — the
    /// instant an open-loop load generator charges latency from, so
    /// server queueing delay counts against the server (no coordinated
    /// omission).
    pub fn consume(&mut self) -> Instant {
        let due = self.due();
        self.next += 1;
        due
    }

    /// Ticks consumed so far.
    pub fn ticks(&self) -> u64 {
        self.next
    }

    /// Block until the next tick is due, then consume it. Behind
    /// schedule this returns immediately — missed deadlines are
    /// consumed one per call, preserving the long-run rate.
    pub fn wait(&mut self) -> Instant {
        let due = self.due();
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        self.consume()
    }
}

/// One collected answer (with `keep_responses`).
#[derive(Clone, Debug)]
pub struct WireAnswer {
    /// Connection index that sent the request.
    pub conn: usize,
    /// Request id (= index into that connection's query stream).
    pub id: u64,
    /// Request seed the stream carried.
    pub seed: u64,
    /// Generation that served it.
    pub generation: u64,
    /// Topic mixture, bit-exact off the wire.
    pub theta: Vec<f64>,
    /// Replicas that contributed.
    pub served_by: Vec<u32>,
    /// Server-side queue + service latency.
    pub latency_micros: u64,
}

/// Aggregated load-run outcome.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// Connections driven.
    pub connections: usize,
    /// Requests written to sockets.
    pub sent: u64,
    /// INFER_OK frames received.
    pub answered: u64,
    /// Error frames received + connection-level failures.
    pub errors: u64,
    /// Requests still unanswered at the per-connection deadline.
    pub timed_out: u64,
    /// Wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// answered / wall_secs.
    pub qps: f64,
    /// Client round-trip latency percentiles, milliseconds.
    pub p50_ms: f64,
    /// 99th percentile client RTT, ms.
    pub p99_ms: f64,
    /// Worst client RTT, ms.
    pub max_ms: f64,
    /// Server-stamped (`latency_micros`) p50, ms.
    pub server_p50_ms: f64,
    /// Server-stamped p99, ms.
    pub server_p99_ms: f64,
    /// Lowest generation observed across answers (0 if none).
    pub min_generation: u64,
    /// Highest generation observed across answers (0 if none).
    pub max_generation: u64,
    /// Every answer, when `keep_responses` was set.
    pub responses: Vec<WireAnswer>,
}

impl LoadReport {
    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        format!(
            "connections {}  sent {}  answered {}  errors {}  timed_out {}\n\
             qps {:.0}  wall {:.2}s\n\
             client  p50 {:.3} ms  p99 {:.3} ms  max {:.3} ms\n\
             server  p50 {:.3} ms  p99 {:.3} ms\n\
             generations seen {}..{}",
            self.connections,
            self.sent,
            self.answered,
            self.errors,
            self.timed_out,
            self.qps,
            self.wall_secs,
            self.p50_ms,
            self.p99_ms,
            self.max_ms,
            self.server_p50_ms,
            self.server_p99_ms,
            self.min_generation,
            self.max_generation,
        )
    }
}

enum ClientStream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl ClientStream {
    fn connect(addr: &str) -> io::Result<ClientStream> {
        #[cfg(unix)]
        if let Some(path) = addr.strip_prefix("unix:") {
            let s = UnixStream::connect(path)?;
            s.set_nonblocking(true)?;
            return Ok(ClientStream::Unix(s));
        }
        let s = TcpStream::connect(addr)?;
        let _ = s.set_nodelay(true);
        s.set_nonblocking(true)?;
        Ok(ClientStream::Tcp(s))
    }

    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.read(buf),
        }
    }

    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            ClientStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            ClientStream::Unix(s) => s.write(buf),
        }
    }
}

struct ConnOutcome {
    sent: u64,
    answered: u64,
    errors: u64,
    timed_out: u64,
    /// Client RTT seconds per answered request.
    latencies: Vec<f64>,
    /// Server-stamped latency per answered request, µs.
    server_lat: Vec<u64>,
    min_gen: u64,
    max_gen: u64,
    answers: Vec<WireAnswer>,
}

/// The server handshake, via [`hello`].
#[derive(Clone, Debug)]
pub struct ServerHello {
    /// Live serving generation at handshake time.
    pub generation: u64,
    /// Topic count.
    pub k: u32,
    /// Vocabulary size.
    pub vocab: u32,
    /// Serving family name.
    pub family: String,
}

/// Connect, HELLO, and return the server's model shape — how
/// `bench-serve --addr` learns the vocabulary to generate load against.
pub fn hello(addr: &str, timeout: Duration) -> Result<ServerHello> {
    let mut stream = ClientStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let mut wbuf = Vec::new();
    proto::encode_request_into(
        &mut wbuf,
        &Request::Hello {
            id: 0,
            family: String::new(),
        },
    );
    let deadline = Instant::now() + timeout;
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if !wbuf.is_empty() {
            match stream.write(&wbuf) {
                Ok(n) => {
                    wbuf.drain(..n);
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {}
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow::anyhow!("hello write: {e}")),
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Err(anyhow::anyhow!("server closed during HELLO")),
            Ok(n) => rbuf.extend_from_slice(&chunk[..n]),
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {}
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::anyhow!("hello read: {e}")),
        }
        if let Some((f, _)) = frame::decode(&rbuf).map_err(|e| anyhow::anyhow!("{e}"))? {
            return match proto::decode_response(&f) {
                Ok(Response::HelloOk {
                    generation,
                    k,
                    vocab,
                    family,
                    ..
                }) => Ok(ServerHello {
                    generation,
                    k,
                    vocab,
                    family,
                }),
                Ok(Response::Error { code, message, .. }) => {
                    Err(anyhow::anyhow!("HELLO refused (code {code}): {message}"))
                }
                Ok(other) => Err(anyhow::anyhow!("unexpected HELLO answer: {other:?}")),
                Err(e) => Err(anyhow::anyhow!("bad HELLO answer: {}", e.message)),
            };
        }
        if Instant::now() > deadline {
            return Err(anyhow::anyhow!("HELLO timed out after {timeout:?}"));
        }
        std::thread::sleep(Duration::from_micros(100));
    }
}

/// Drive the configured load against `addr` (TCP `host:port` or
/// `unix:/path`) and aggregate the outcome.
pub fn run(addr: &str, cfg: &LoadgenConfig) -> Result<LoadReport> {
    let started = Instant::now();
    let outcomes: Vec<io::Result<ConnOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections.max(1))
            .map(|conn| s.spawn(move || run_conn(addr, cfg, conn)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen connection thread panicked"))
            .collect()
    });
    let wall_secs = started.elapsed().as_secs_f64();

    let mut report = LoadReport {
        connections: cfg.connections.max(1),
        wall_secs,
        min_generation: u64::MAX,
        ..LoadReport::default()
    };
    let mut latencies = Vec::new();
    let mut server_lat = Vec::new();
    for out in outcomes {
        let out = out.map_err(|e| anyhow::anyhow!("loadgen connection failed: {e}"))?;
        report.sent += out.sent;
        report.answered += out.answered;
        report.errors += out.errors;
        report.timed_out += out.timed_out;
        latencies.extend(out.latencies);
        server_lat.extend(out.server_lat.iter().map(|&u| u as f64 / 1_000.0));
        if out.answered > 0 {
            report.min_generation = report.min_generation.min(out.min_gen);
            report.max_generation = report.max_generation.max(out.max_gen);
        }
        report.responses.extend(out.answers);
    }
    if report.min_generation == u64::MAX {
        report.min_generation = 0;
    }
    let ms: Vec<f64> = latencies.iter().map(|&s| s * 1_000.0).collect();
    report.qps = if wall_secs > 0.0 {
        report.answered as f64 / wall_secs
    } else {
        0.0
    };
    if !ms.is_empty() {
        report.p50_ms = percentile(&ms, 50.0);
        report.p99_ms = percentile(&ms, 99.0);
        report.max_ms = ms.iter().cloned().fold(0.0, f64::max);
    }
    if !server_lat.is_empty() {
        report.server_p50_ms = percentile(&server_lat, 50.0);
        report.server_p99_ms = percentile(&server_lat, 99.0);
    }
    Ok(report)
}

fn run_conn(addr: &str, cfg: &LoadgenConfig, conn_id: usize) -> io::Result<ConnOutcome> {
    let mut stream = ClientStream::connect(addr)?;
    let queries = connection_queries(cfg, conn_id);
    let mut out = ConnOutcome {
        sent: 0,
        answered: 0,
        errors: 0,
        timed_out: 0,
        latencies: Vec::with_capacity(queries.len()),
        server_lat: Vec::with_capacity(queries.len()),
        min_gen: u64::MAX,
        max_gen: 0,
        answers: Vec::new(),
    };
    let start = Instant::now();
    let deadline = start + cfg.timeout;
    // Open-loop: this connection's share of the total target rate, paced
    // against absolute deadlines so per-request work can't slip the
    // schedule.
    let mut pacer = if cfg.rate > 0.0 {
        Some(Pacer::new(
            start,
            Duration::from_secs_f64(cfg.connections.max(1) as f64 / cfg.rate),
        ))
    } else {
        None
    };
    let mut next_send = 0usize;
    let mut inflight: HashMap<u64, Instant> = HashMap::new();
    let mut wbuf: Vec<u8> = Vec::new();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        let mut progress = false;

        // Encode every request that is due.
        while next_send < queries.len() {
            let charge = match pacer.as_mut() {
                Some(p) => {
                    if !p.is_due(Instant::now()) {
                        break;
                    }
                    p.consume() // open loop: latency includes server queueing delay
                }
                None => {
                    if inflight.len() >= cfg.window.max(1) {
                        break;
                    }
                    Instant::now()
                }
            };
            let (seed, tokens) = &queries[next_send];
            let id = next_send as u64;
            proto::encode_request_into(
                &mut wbuf,
                &Request::Infer {
                    id,
                    seed: *seed,
                    min_generation: cfg.min_generation,
                    tokens: tokens.clone(),
                },
            );
            inflight.insert(id, charge);
            out.sent += 1;
            next_send += 1;
            progress = true;
        }

        // Flush.
        while !wbuf.is_empty() {
            match stream.write(&wbuf) {
                Ok(0) => return finish_eof(out, inflight),
                Ok(n) => {
                    wbuf.drain(..n);
                    progress = true;
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return finish_eof(out, inflight),
            }
        }

        // Read.
        loop {
            match stream.read(&mut chunk) {
                Ok(0) => return finish_eof(out, inflight),
                Ok(n) => {
                    rbuf.extend_from_slice(&chunk[..n]);
                    progress = true;
                    if n < chunk.len() {
                        break;
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return finish_eof(out, inflight),
            }
        }

        // Decode answers.
        let mut consumed = 0usize;
        loop {
            let (f, used) = match frame::decode(&rbuf[consumed..]) {
                Ok(Some(hit)) => hit,
                Ok(None) => break,
                Err(_) => {
                    // The server desynchronized us — unrecoverable.
                    out.errors += 1 + inflight.len() as u64;
                    return Ok(out);
                }
            };
            consumed += used;
            progress = true;
            match proto::decode_response(&f) {
                Ok(Response::InferOk {
                    id,
                    generation,
                    latency_micros,
                    theta,
                    served_by,
                    ..
                }) => {
                    if let Some(charged) = inflight.remove(&id) {
                        out.latencies
                            .push(charged.elapsed().as_secs_f64());
                    }
                    out.answered += 1;
                    out.server_lat.push(latency_micros);
                    out.min_gen = out.min_gen.min(generation);
                    out.max_gen = out.max_gen.max(generation);
                    if cfg.keep_responses {
                        out.answers.push(WireAnswer {
                            conn: conn_id,
                            id,
                            seed: queries
                                .get(id as usize)
                                .map(|(s, _)| *s)
                                .unwrap_or(0),
                            generation,
                            theta,
                            served_by,
                            latency_micros,
                        });
                    }
                }
                Ok(Response::Error { id, .. }) => {
                    inflight.remove(&id);
                    out.errors += 1;
                }
                Ok(_) => {}
                Err(_) => {
                    out.errors += 1;
                }
            }
        }
        if consumed > 0 {
            rbuf.drain(..consumed);
        }

        let done = (out.answered + out.errors) as usize >= queries.len()
            && next_send >= queries.len()
            && wbuf.is_empty();
        if done {
            return Ok(out);
        }
        if Instant::now() > deadline {
            out.timed_out = inflight.len() as u64;
            return Ok(out);
        }
        if !progress {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// The server went away mid-run: everything still in flight is an error.
fn finish_eof(mut out: ConnOutcome, inflight: HashMap<u64, Instant>) -> io::Result<ConnOutcome> {
    out.errors += inflight.len() as u64;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pacer_schedule_is_anchored_not_cumulative() {
        let start = Instant::now();
        let iv = Duration::from_millis(10);
        let mut p = Pacer::new(start, iv);
        // The deadline of tick i depends only on i, never on when the
        // previous ticks were consumed — so no per-tick cost can
        // accumulate into the schedule (the drift the sleep-after-work
        // loop suffers from).
        for i in 0..1000u64 {
            let due = p.consume();
            let want = iv.mul_f64(i as f64).as_secs_f64();
            let got = due.duration_since(start).as_secs_f64();
            assert!((got - want).abs() < 1e-9, "tick {i}: due {got}, want {want}");
        }
        assert_eq!(p.ticks(), 1000);
        // After 1000 consumed ticks the next deadline sits exactly 10s
        // past start; the drifting loop's would be 10s plus the sum of
        // every tick's work time.
        let horizon = p.due().duration_since(start).as_secs_f64();
        assert!((horizon - 10.0).abs() < 1e-6, "{horizon}");
    }

    #[test]
    fn pacer_releases_backlog_when_behind_schedule() {
        // Anchor 55ms in the past: ticks at 0,10,…,50ms are already due
        // and must be released immediately (catch-up preserves the
        // long-run rate), not rescheduled from "now".
        let start = Instant::now() - Duration::from_millis(55);
        let mut p = Pacer::new(start, Duration::from_millis(10));
        let now = Instant::now();
        let mut released = 0;
        while p.is_due(now) {
            p.consume();
            released += 1;
        }
        assert!(released >= 6, "only {released} backlogged ticks released");
        assert!(!p.is_due(now), "catch-up must stop at the schedule edge");
    }
}
