//! The multi-thread alias sampler of §5.1: two thread pools in a
//! producer/consumer arrangement with deliberately *relaxed* consistency.
//!
//! * **Alias threads** (producers, 1 or few) build alias tables and
//!   pre-draw a *stash* of samples per token-type, weighing token-types by
//!   demand and refreshing the stashes whose supply runs low.
//! * **Sampling threads** (consumers, ≈ #cores) pop pre-drawn samples
//!   lock-free; when a stash runs dry they notify the producers and
//!   *recycle old samples* rather than block — the paper's lock-free
//!   relaxation ("substantially improves the performance ... without
//!   compromising the quality of the results in practice").
//!
//! The stash is a fixed ring of `u32` outcomes plus an atomic cursor;
//! `pop` is one `fetch_add` and one relaxed load. When the cursor passes
//! the stash length, consumers wrap (recycling), and the demand counter
//! tells producers which words to refresh first.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use super::alias::AliasTable;
use crate::util::rng::Rng;

/// One word's stash of pre-drawn topic samples.
pub struct Stash {
    samples: Box<[AtomicU32]>,
    cursor: AtomicUsize,
    /// Incremented on every refill — lets consumers detect freshness.
    generation: AtomicU64,
    /// Total pops (demand accounting for the producer's priority queue).
    demand: AtomicU64,
    /// Pops that wrapped past fresh supply (recycled samples).
    recycled: AtomicU64,
}

impl Stash {
    /// Create with capacity `cap` (rounded up to at least 8), filled from
    /// `table`.
    pub fn new(cap: usize, table: &AliasTable, rng: &mut Rng) -> Self {
        let cap = cap.max(8);
        let samples: Box<[AtomicU32]> = (0..cap)
            .map(|_| AtomicU32::new(table.sample(rng) as u32))
            .collect();
        Stash {
            samples,
            cursor: AtomicUsize::new(0),
            generation: AtomicU64::new(1),
            demand: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// Capacity of the ring.
    pub fn capacity(&self) -> usize {
        self.samples.len()
    }

    /// Pop a sample (lock-free; recycles when supply is exhausted).
    /// Returns `(sample, was_recycled)`.
    #[inline]
    pub fn pop(&self) -> (u32, bool) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.demand.fetch_add(1, Ordering::Relaxed);
        let recycled = i >= self.samples.len();
        if recycled {
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
        let v = self.samples[i % self.samples.len()].load(Ordering::Relaxed);
        (v, recycled)
    }

    /// Supply remaining before consumers start recycling.
    pub fn remaining(&self) -> usize {
        self.samples
            .len()
            .saturating_sub(self.cursor.load(Ordering::Relaxed))
    }

    /// Refill from a (rebuilt) alias table and reset the cursor.
    pub fn refill(&self, table: &AliasTable, rng: &mut Rng) {
        for slot in self.samples.iter() {
            slot.store(table.sample(rng) as u32, Ordering::Relaxed);
        }
        self.cursor.store(0, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    /// Cumulative demand (pops).
    pub fn total_demand(&self) -> u64 {
        self.demand.load(Ordering::Relaxed)
    }

    /// Cumulative recycled pops.
    pub fn total_recycled(&self) -> u64 {
        self.recycled.load(Ordering::Relaxed)
    }

    /// Refill generation counter.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }
}

/// Message from consumers to the alias pool: "word w needs a refill".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RefillRequest {
    /// Word whose stash ran low.
    pub word: u32,
}

/// The producer/consumer pool: per-word stashes, an alias thread, and the
/// lock-free demand/refill protocol.
///
/// Weight providers are supplied as a closure computing the *current*
/// dense weights for a word — the alias thread calls it on refill, so the
/// stash tracks the slowly-changing distribution exactly the way §3.3's
/// proposal-rebuild schedule prescribes.
pub struct AliasPool {
    stashes: Vec<Arc<Stash>>,
    refill_tx: mpsc::Sender<RefillRequest>,
    shutdown: Arc<AtomicBool>,
    producer: Option<std::thread::JoinHandle<u64>>,
}

impl AliasPool {
    /// Spawn a pool over `vocab` words. `stash_cap` samples per word.
    /// `weights(word)` must return the dense proposal weights.
    pub fn spawn(
        vocab: usize,
        stash_cap: usize,
        weights: impl Fn(u32) -> Vec<f64> + Send + 'static,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let stashes: Vec<Arc<Stash>> = (0..vocab)
            .map(|w| {
                let table = AliasTable::build(&weights(w as u32));
                Arc::new(Stash::new(stash_cap, &table, &mut rng))
            })
            .collect();
        let (tx, rx) = mpsc::channel::<RefillRequest>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let producer = {
            let stashes = stashes.clone();
            let shutdown = shutdown.clone();
            let mut rng = Rng::new(seed ^ 0x9E3779B9);
            std::thread::spawn(move || {
                let mut refills = 0u64;
                // Drain refill requests, most-recent-demand first. A
                // simple dedup set bounds redundant rebuilds.
                while !shutdown.load(Ordering::Relaxed) {
                    match rx.recv_timeout(std::time::Duration::from_millis(1)) {
                        Ok(req) => {
                            let table = AliasTable::build(&weights(req.word));
                            stashes[req.word as usize].refill(&table, &mut rng);
                            refills += 1;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                refills
            })
        };
        AliasPool {
            stashes,
            refill_tx: tx,
            shutdown,
            producer: Some(producer),
        }
    }

    /// Pop a pre-drawn sample for `word`, requesting a refill when the
    /// fresh supply is low (≤ ¼ capacity) and recycling when dry.
    #[inline]
    pub fn pop(&self, word: u32) -> (u32, bool) {
        let stash = &self.stashes[word as usize];
        let out = stash.pop();
        if stash.remaining() * 4 <= stash.capacity() {
            // Best-effort: losing the race to a full channel is fine.
            let _ = self.refill_tx.send(RefillRequest { word });
        }
        out
    }

    /// Stash accessor (diagnostics).
    pub fn stash(&self, word: u32) -> &Stash {
        &self.stashes[word as usize]
    }

    /// Stop the producer and return how many refills it performed.
    pub fn shutdown(mut self) -> u64 {
        self.shutdown.store(true, Ordering::Relaxed);
        match self.producer.take() {
            Some(h) => h.join().unwrap_or(0),
            None => 0,
        }
    }
}

impl Drop for AliasPool {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stash_pop_and_recycle() {
        let mut rng = Rng::new(1);
        let table = AliasTable::build(&[1.0, 2.0, 3.0]);
        let stash = Stash::new(16, &table, &mut rng);
        for _ in 0..16 {
            let (_, recycled) = stash.pop();
            assert!(!recycled);
        }
        let (_, recycled) = stash.pop();
        assert!(recycled, "17th pop of a 16-stash must recycle");
        assert_eq!(stash.total_demand(), 17);
        assert_eq!(stash.total_recycled(), 1);
    }

    #[test]
    fn refill_resets_supply() {
        let mut rng = Rng::new(2);
        let table = AliasTable::build(&[1.0, 1.0]);
        let stash = Stash::new(8, &table, &mut rng);
        for _ in 0..8 {
            stash.pop();
        }
        assert_eq!(stash.remaining(), 0);
        stash.refill(&table, &mut rng);
        assert_eq!(stash.remaining(), 8);
        assert_eq!(stash.generation(), 2);
    }

    #[test]
    fn pool_produces_correct_marginals() {
        // Word 0 weights = [1, 3]: outcome 1 must appear ≈ 3× outcome 0.
        let pool = AliasPool::spawn(
            2,
            512,
            |w| {
                if w == 0 {
                    vec![1.0, 3.0]
                } else {
                    vec![1.0, 1.0]
                }
            },
            7,
        );
        let mut counts = [0u64; 2];
        for i in 0..50_000 {
            let (s, _) = pool.pop(0);
            counts[s as usize] += 1;
            if i % 500 == 0 {
                // Give the producer air so samples are mostly fresh (the
                // recycled tail adds variance, not bias).
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        let ratio = counts[1] as f64 / counts[0].max(1) as f64;
        assert!((ratio - 3.0).abs() < 0.8, "ratio {ratio}");
        pool.shutdown();
    }

    #[test]
    fn pool_is_threadsafe_under_contention() {
        let pool = Arc::new(AliasPool::spawn(4, 32, |_| vec![1.0; 8], 9));
        let mut handles = Vec::new();
        for th in 0..4 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let mut acc = 0u64;
                for i in 0..50_000u64 {
                    let w = ((i + th) % 4) as u32;
                    let (s, _) = pool.pop(w);
                    assert!(s < 8);
                    acc += s as u64;
                }
                acc
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Demand accounting must see every pop.
        let total: u64 = (0..4).map(|w| pool.stash(w).total_demand()).sum();
        assert_eq!(total, 200_000);
    }

    #[test]
    fn producer_refills_under_load() {
        let pool = AliasPool::spawn(1, 16, |_| vec![1.0; 4], 11);
        for _ in 0..400 {
            pool.pop(0);
            std::thread::yield_now();
        }
        // Give the producer a beat to drain.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let gen = pool.stash(0).generation();
        let refills = pool.shutdown();
        assert!(gen > 1, "no refill ever happened");
        assert!(refills > 0);
    }
}
