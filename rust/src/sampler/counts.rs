//! Shared sufficient-statistics matrices — the **sparse hot path**.
//!
//! A [`CountMatrix`] is a client's local replica of one shared statistic
//! (LDA: `n_tw`; PDP: `m_tw` and `s_tw`; HDP adds table counts). Rows are
//! word-indexed, `K`-wide, lazily allocated (a shard only touches its own
//! vocabulary slice), and every mutation is mirrored into a **delta log**
//! that the parameter-server client drains into batched row pushes (§5.3
//! "batched communication").
//!
//! Three sparsity mechanisms make every per-token operation cost
//! `O(topics actually touched)` instead of `O(K)`:
//!
//! * **Sparse delta log.** A token move touches 2 cells, so the per-word
//!   delta record is a short unsorted `(topic, ±delta)` list (`DeltaRow`)
//!   that spills to a dense `K`-wide row only past a density threshold
//!   (`K/4` distinct topics). `inc` is `O(k_w)` with no `K`-wide
//!   allocation; a word's record is allocated once and reused across
//!   drain cycles, so the steady-state token loop allocates nothing.
//! * **Sparse wire rows.** [`CountMatrix::drain_deltas`] emits [`RowData`]
//!   — `Sparse(Vec<(topic, value)>)` when `8·nnz < 4·K`, `Dense` otherwise
//!   — and the same enum carries pull responses, so both push and pull
//!   traffic pay for the cells that exist, not for `K`
//!   (see [`crate::ps::msg`] for the wire-size accounting).
//! * **Incremental normalizers.** Every sampler denominator has the shape
//!   `n_t + smoothing` (`β̄`, PDP `b`, `γ̄`). The matrix caches
//!   `inv_denom[t] = 1/(max(n_t,0) + smoothing)` and refreshes it on each
//!   total change (one division per `inc` instead of one per topic per
//!   token in the samplers' inner loops). Enable with
//!   [`CountMatrix::set_smoothing`]; read with [`CountMatrix::inv_denom`].
//!
//! The replica-merge rule is the paper's: the server aggregates deltas from
//! all clients; a pull overwrites the local row with the server value
//! *plus* any still-unflushed local deltas, so local Gibbs moves are never
//! lost (eventual consistency, §5.3). [`CountMatrix::apply_pull`] borrows
//! the pending delta record in place — no per-pull clone.

use std::collections::HashMap;

/// One batched row on the wire: either a full `K`-wide row (dense) or the
/// non-zero `(topic, value)` cells (sparse, sorted by topic).
///
/// For a `Push` the values are **deltas** (unlisted topics moved by 0);
/// for a `PullResp` they are **absolute** counts (unlisted topics are 0).
/// Both follow from the same invariant: a sparse row *is* the dense row
/// with its zero cells elided, so `to_dense` ∘ encode is the identity.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RowData {
    /// Full-width row (`len == K`).
    Dense(Box<[i32]>),
    /// Non-zero cells only, sorted by topic.
    Sparse(Vec<(u32, i32)>),
}

impl RowData {
    /// Encode a dense slice, choosing the smaller wire form: sparse costs
    /// 8 bytes per non-zero cell, dense 4 per topic.
    pub fn from_dense_auto(row: &[i32]) -> RowData {
        let nnz = row.iter().filter(|&&v| v != 0).count();
        if 8 * nnz < 4 * row.len() {
            RowData::Sparse(
                row.iter()
                    .enumerate()
                    .filter(|&(_, &v)| v != 0)
                    .map(|(t, &v)| (t as u32, v))
                    .collect(),
            )
        } else {
            RowData::Dense(row.to_vec().into_boxed_slice())
        }
    }

    /// Number of non-zero cells.
    pub fn nnz(&self) -> usize {
        match self {
            RowData::Dense(r) => r.iter().filter(|&&v| v != 0).count(),
            RowData::Sparse(es) => es.len(),
        }
    }

    /// Minimum dense width able to hold this row.
    pub fn min_width(&self) -> usize {
        match self {
            RowData::Dense(r) => r.len(),
            RowData::Sparse(es) => es.iter().map(|&(t, _)| t as usize + 1).max().unwrap_or(0),
        }
    }

    /// Value at `topic` (0 when elided).
    #[inline]
    pub fn get(&self, topic: usize) -> i32 {
        match self {
            RowData::Dense(r) => r.get(topic).copied().unwrap_or(0),
            RowData::Sparse(es) => es
                .iter()
                .find(|&&(t, _)| t as usize == topic)
                .map(|&(_, v)| v)
                .unwrap_or(0),
        }
    }

    /// L1 magnitude (the communication filter's priority key).
    pub fn l1(&self) -> u64 {
        match self {
            RowData::Dense(r) => r.iter().map(|&v| v.unsigned_abs() as u64).sum(),
            RowData::Sparse(es) => es.iter().map(|&(_, v)| v.unsigned_abs() as u64).sum(),
        }
    }

    /// Materialize as a `width`-wide dense row. A sparse entry beyond
    /// `width` is a logic error and panics; a dense row wider than
    /// `width` is clamped to the first `width` cells.
    pub fn to_dense(&self, width: usize) -> Box<[i32]> {
        let mut out = vec![0i32; width];
        match self {
            RowData::Dense(r) => out[..r.len().min(width)].copy_from_slice(&r[..r.len().min(width)]),
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    out[t as usize] = v;
                }
            }
        }
        out.into_boxed_slice()
    }

    /// Fold this row as **deltas** into `row` with saturating adds (the
    /// server's push-apply). `row` must already be at least
    /// [`RowData::min_width`] wide.
    pub fn fold_saturating_into(&self, row: &mut [i32]) {
        match self {
            RowData::Dense(r) => {
                for (c, d) in row.iter_mut().zip(r.iter()) {
                    *c = c.saturating_add(*d);
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    let c = &mut row[t as usize];
                    *c = c.saturating_add(v);
                }
            }
        }
    }

    /// Approximate wire footprint in bytes: 1 tag + 4 length + payload
    /// (4 bytes per dense cell, 8 per sparse `(topic, value)` pair).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            RowData::Dense(r) => 5 + 4 * r.len() as u64,
            RowData::Sparse(es) => 5 + 8 * es.len() as u64,
        }
    }
}

/// A word's unflushed deltas: short list first, dense past the spill
/// threshold. Entries are unsorted; zero deltas are removed eagerly so
/// the linear probe stays `O(k_w)`. The dense form tracks its non-zero
/// count so [`DeltaRow::nnz`] — and with it the matrix's live
/// `pending` counter — stays `O(1)` in both forms.
#[derive(Clone, Debug)]
enum DeltaRow {
    Sparse(Vec<(u32, i32)>),
    Dense { row: Box<[i32]>, nnz: usize },
}

impl DeltaRow {
    fn new(spill: usize) -> DeltaRow {
        // Pre-size to the spill threshold: the list converts to dense
        // before it would ever reallocate.
        DeltaRow::Sparse(Vec::with_capacity(spill))
    }

    #[inline]
    fn add(&mut self, topic: usize, delta: i32, k: usize, spill: usize) {
        match self {
            DeltaRow::Sparse(v) => {
                for i in 0..v.len() {
                    if v[i].0 as usize == topic {
                        v[i].1 += delta;
                        if v[i].1 == 0 {
                            v.swap_remove(i);
                        }
                        return;
                    }
                }
                if v.len() >= spill {
                    // Density threshold crossed: spill to a dense row.
                    let mut dense = vec![0i32; k].into_boxed_slice();
                    for &(t, d) in v.iter() {
                        dense[t as usize] = d;
                    }
                    dense[topic] += delta;
                    let nnz = dense.iter().filter(|&&x| x != 0).count();
                    *self = DeltaRow::Dense { row: dense, nnz };
                } else {
                    v.push((topic as u32, delta));
                }
            }
            DeltaRow::Dense { row, nnz } => {
                let before = row[topic];
                row[topic] += delta;
                if before == 0 && row[topic] != 0 {
                    *nnz += 1;
                } else if before != 0 && row[topic] == 0 {
                    *nnz -= 1;
                }
            }
        }
    }

    #[inline]
    fn nnz(&self) -> usize {
        match self {
            DeltaRow::Sparse(v) => v.len(),
            DeltaRow::Dense { nnz, .. } => *nnz,
        }
    }
}

#[inline]
fn inv_of(total: i64, smoothing: f64) -> f64 {
    1.0 / ((total as f64).max(0.0) + smoothing)
}

/// Client replica of a `V × K` count matrix with per-topic aggregates, a
/// sparse delta log, and an incremental normalizer cache.
#[derive(Clone, Debug)]
pub struct CountMatrix {
    k: usize,
    rows: Vec<Option<Box<[i32]>>>,
    /// Per-topic aggregate (`n_t` in LDA, `m_t`/`s_t` in PDP).
    totals: Vec<i64>,
    /// Normalizer smoothing mass (`β̄`, PDP `b`, `γ̄` — whatever the
    /// model adds to `n_t` in its denominators). 0 until
    /// [`CountMatrix::set_smoothing`].
    smoothing: f64,
    /// Cached `1/(max(n_t,0) + smoothing)`, refreshed on every total
    /// change. Meaningless (±inf) until a positive smoothing is set.
    inv_denom: Vec<f64>,
    /// Unflushed local updates per touched row. Entries persist (cleared,
    /// not removed) across drains so the token loop never reallocates.
    deltas: HashMap<u32, DeltaRow>,
    /// Live count of delta records with non-zero content, maintained on
    /// every empty↔non-empty record transition — [`pending_rows`]
    /// (Self::pending_rows) reads it in `O(1)` instead of scanning the
    /// touched vocabulary.
    pending: usize,
    /// Sparse→dense spill threshold for delta records.
    spill: usize,
    /// Reusable decode buffer for sparse pulls.
    pull_scratch: Vec<i32>,
}

impl CountMatrix {
    /// Empty matrix over `vocab` words × `k` topics.
    pub fn new(vocab: usize, k: usize) -> Self {
        CountMatrix {
            k,
            rows: vec![None; vocab],
            totals: vec![0; k],
            smoothing: 0.0,
            inv_denom: vec![f64::INFINITY; k],
            deltas: HashMap::new(),
            pending: 0,
            spill: (k / 4).max(4),
            pull_scratch: Vec::new(),
        }
    }

    /// Topic count `K`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.rows.len()
    }

    /// Read one cell.
    #[inline]
    pub fn get(&self, word: u32, topic: usize) -> i32 {
        match &self.rows[word as usize] {
            Some(r) => r[topic],
            None => 0,
        }
    }

    /// Borrow a row (`None` if the word was never touched).
    #[inline]
    pub fn row(&self, word: u32) -> Option<&[i32]> {
        self.rows[word as usize].as_deref()
    }

    /// Per-topic aggregates (`n_t`).
    #[inline]
    pub fn totals(&self) -> &[i64] {
        &self.totals
    }

    /// Aggregate for one topic.
    #[inline]
    pub fn total(&self, topic: usize) -> i64 {
        self.totals[topic]
    }

    /// Grand total over all topics.
    pub fn grand_total(&self) -> i64 {
        self.totals.iter().sum()
    }

    /// Enable the incremental normalizer cache: `smoothing` is the mass
    /// the model adds to `n_t` in its denominators (`β̄` for LDA/HDP word
    /// factors, `b` for the PDP customer denominator, `γ̄` for the PDP
    /// root). Rebuilds the cache for the current totals.
    pub fn set_smoothing(&mut self, smoothing: f64) {
        self.smoothing = smoothing;
        for t in 0..self.k {
            self.inv_denom[t] = inv_of(self.totals[t], smoothing);
        }
    }

    /// Cached `1/(max(n_t,0) + smoothing)` — the samplers' per-topic
    /// denominator, maintained incrementally so inner loops multiply
    /// instead of divide. Requires [`CountMatrix::set_smoothing`] first.
    #[inline]
    pub fn inv_denom(&self, topic: usize) -> f64 {
        self.inv_denom[topic]
    }

    /// `max(n_t,0) + smoothing` (the uninverted normalizer; cold paths).
    #[inline]
    pub fn denom(&self, topic: usize) -> f64 {
        (self.totals[topic] as f64).max(0.0) + self.smoothing
    }

    fn ensure_row(&mut self, word: u32) -> &mut [i32] {
        let slot = &mut self.rows[word as usize];
        if slot.is_none() {
            *slot = Some(vec![0i32; self.k].into_boxed_slice());
        }
        slot.as_deref_mut().unwrap()
    }

    #[inline]
    fn bump_total(&mut self, topic: usize, delta: i64) {
        self.totals[topic] += delta;
        self.inv_denom[topic] = inv_of(self.totals[topic], self.smoothing);
    }

    /// Apply a local Gibbs move: `cell += delta`, mirrored into the sparse
    /// delta log and the per-topic aggregate (+ normalizer cache). `O(k_w)`
    /// and allocation-free once the word's delta record exists.
    #[inline]
    pub fn inc(&mut self, word: u32, topic: usize, delta: i32) {
        let row = self.ensure_row(word);
        row[topic] += delta;
        self.bump_total(topic, delta as i64);
        let (k, spill) = (self.k, self.spill);
        let rec = self
            .deltas
            .entry(word)
            .or_insert_with(|| DeltaRow::new(spill));
        let was_empty = rec.nnz() == 0;
        rec.add(topic, delta, k, spill);
        let now_empty = rec.nnz() == 0;
        if was_empty && !now_empty {
            self.pending += 1;
        } else if !was_empty && now_empty {
            self.pending -= 1;
        }
    }

    /// Apply a local move *without* recording a delta (used for local-only
    /// statistics and for replaying a snapshot).
    #[inline]
    pub fn inc_local(&mut self, word: u32, topic: usize, delta: i32) {
        let row = self.ensure_row(word);
        row[topic] += delta;
        self.bump_total(topic, delta as i64);
    }

    /// Drain the delta log into `(word, row)` batches for pushing, each
    /// row in the cheaper wire form (sparse below `8·nnz < 4·K`). Zero
    /// rows are skipped; records stay allocated for reuse.
    pub fn drain_deltas(&mut self) -> Vec<(u32, RowData)> {
        let k = self.k;
        let mut out: Vec<(u32, RowData)> = Vec::new();
        for (&w, rec) in self.deltas.iter_mut() {
            match rec {
                DeltaRow::Sparse(v) => {
                    if v.is_empty() {
                        continue;
                    }
                    // Same break-even as `from_dense_auto`: at tiny K a
                    // sparse record can still be cheaper to ship dense.
                    if 8 * v.len() < 4 * k {
                        let mut entries = v.clone();
                        v.clear();
                        entries.sort_unstable_by_key(|&(t, _)| t);
                        out.push((w, RowData::Sparse(entries)));
                    } else {
                        let mut dense = vec![0i32; k];
                        for &(t, d) in v.iter() {
                            dense[t as usize] = d;
                        }
                        v.clear();
                        out.push((w, RowData::Dense(dense.into_boxed_slice())));
                    }
                }
                DeltaRow::Dense { row, nnz } => {
                    if *nnz == 0 {
                        continue;
                    }
                    out.push((w, RowData::from_dense_auto(row)));
                    row.iter_mut().for_each(|x| *x = 0);
                    *nnz = 0;
                }
            }
        }
        self.pending = 0;
        out.sort_unstable_by_key(|&(w, _)| w);
        out
    }

    /// Number of rows currently carrying unflushed deltas — `O(1)`,
    /// served from the live counter maintained on every empty↔non-empty
    /// record transition (it used to scan the touched vocabulary, which
    /// every filter-retain push paid for).
    pub fn pending_rows(&self) -> usize {
        self.pending
    }

    /// The `O(touched-vocab)` scan [`pending_rows`](Self::pending_rows)
    /// replaced — kept as the oracle for the counter's regression test.
    pub fn pending_rows_scan(&self) -> usize {
        self.deltas.values().filter(|d| d.nnz() > 0).count()
    }

    /// Re-queue a delta row the communication filter chose to retain
    /// (folds into any newer pending deltas; does not touch counts).
    pub fn requeue_delta(&mut self, word: u32, row: RowData) {
        let (k, spill) = (self.k, self.spill);
        let rec = self
            .deltas
            .entry(word)
            .or_insert_with(|| DeltaRow::new(spill));
        let was_empty = rec.nnz() == 0;
        match row {
            RowData::Sparse(es) => {
                for (t, v) in es {
                    rec.add(t as usize, v, k, spill);
                }
            }
            RowData::Dense(r) => {
                for (t, &v) in r.iter().enumerate() {
                    if v != 0 {
                        rec.add(t, v, k, spill);
                    }
                }
            }
        }
        let now_empty = rec.nnz() == 0;
        if was_empty && !now_empty {
            self.pending += 1;
        } else if !was_empty && now_empty {
            self.pending -= 1;
        }
    }

    /// Absorb a pulled server row: replica := server + unflushed local
    /// deltas (so local moves aren't erased), aggregates and normalizers
    /// fixed up. The pending record is borrowed, never cloned.
    pub fn apply_pull(&mut self, word: u32, server_row: &[i32]) {
        assert_eq!(server_row.len(), self.k);
        self.ensure_row(word);
        let row = self.rows[word as usize].as_deref_mut().unwrap();
        // Overwrite with the server view…
        for (t, cell) in row.iter_mut().enumerate() {
            let d = (server_row[t] - *cell) as i64;
            if d != 0 {
                self.totals[t] += d;
                self.inv_denom[t] = inv_of(self.totals[t], self.smoothing);
            }
            *cell = server_row[t];
        }
        // …then fold the still-unflushed local deltas back in.
        match self.deltas.get(&word) {
            Some(DeltaRow::Sparse(es)) => {
                for &(t, dv) in es {
                    let t = t as usize;
                    row[t] += dv;
                    self.totals[t] += dv as i64;
                    self.inv_denom[t] = inv_of(self.totals[t], self.smoothing);
                }
            }
            Some(DeltaRow::Dense { row: r, .. }) => {
                for (t, &dv) in r.iter().enumerate() {
                    if dv != 0 {
                        row[t] += dv;
                        self.totals[t] += dv as i64;
                        self.inv_denom[t] = inv_of(self.totals[t], self.smoothing);
                    }
                }
            }
            None => {}
        }
    }

    /// [`CountMatrix::apply_pull`] for a wire row in either form. Sparse
    /// (and short dense — a server row born from narrow sparse pushes)
    /// rows decode through a reusable scratch buffer, padding elided
    /// cells with 0; no per-pull allocation in steady state.
    pub fn apply_pull_row(&mut self, word: u32, server_row: &RowData) {
        match server_row {
            RowData::Dense(r) if r.len() == self.k => self.apply_pull(word, r),
            other => {
                let mut scratch = std::mem::take(&mut self.pull_scratch);
                scratch.clear();
                scratch.resize(self.k, 0);
                match other {
                    RowData::Dense(r) => {
                        let n = r.len().min(self.k);
                        scratch[..n].copy_from_slice(&r[..n]);
                    }
                    RowData::Sparse(es) => {
                        for &(t, v) in es {
                            scratch[t as usize] = v;
                        }
                    }
                }
                self.apply_pull(word, &scratch);
                self.pull_scratch = scratch;
            }
        }
    }

    /// Iterate allocated rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = (u32, &[i32])> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(w, r)| r.as_deref().map(|r| (w as u32, r)))
    }

    /// Recompute per-topic aggregates from scratch (consistency repair /
    /// after bulk row replacement).
    pub fn rebuild_totals(&mut self) {
        let mut totals = vec![0i64; self.k];
        for row in self.rows.iter().flatten() {
            for (t, &c) in row.iter().enumerate() {
                totals[t] += c as i64;
            }
        }
        self.totals = totals;
        for t in 0..self.k {
            self.inv_denom[t] = inv_of(self.totals[t], self.smoothing);
        }
    }

    /// Average number of non-zero topics per allocated word row — the
    /// "average topics per word" panel of the paper's figures.
    pub fn avg_topics_per_word(&self) -> f64 {
        let mut words = 0u64;
        let mut nonzero = 0u64;
        for row in self.rows.iter().flatten() {
            words += 1;
            nonzero += row.iter().filter(|&&c| c > 0).count() as u64;
        }
        if words == 0 {
            0.0
        } else {
            nonzero as f64 / words as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_and_totals() {
        let mut m = CountMatrix::new(10, 4);
        m.inc(3, 1, 2);
        m.inc(3, 2, 1);
        m.inc(7, 1, 1);
        assert_eq!(m.get(3, 1), 2);
        assert_eq!(m.get(3, 0), 0);
        assert_eq!(m.total(1), 3);
        assert_eq!(m.grand_total(), 4);
        assert_eq!(m.row(0), None);
    }

    #[test]
    fn drain_deltas_batches_rows() {
        let mut m = CountMatrix::new(10, 3);
        m.inc(5, 0, 1);
        m.inc(5, 2, -1);
        m.inc(2, 1, 4);
        m.inc(9, 1, 1);
        m.inc(9, 1, -1); // cancels to zero → dropped
        let d = m.drain_deltas();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].0, 2);
        assert_eq!(&*d[0].1.to_dense(3), &[0, 4, 0]);
        assert_eq!(d[1].0, 5);
        assert_eq!(&*d[1].1.to_dense(3), &[1, 0, -1]);
        assert!(m.drain_deltas().is_empty());
        // Matrix content unaffected by draining.
        assert_eq!(m.get(5, 0), 1);
    }

    #[test]
    fn delta_log_spills_to_dense_and_back_to_sparse_wire() {
        let k = 64;
        let mut m = CountMatrix::new(4, k);
        // Touch more than k/4 = 16 distinct topics → record spills dense.
        for t in 0..20 {
            m.inc(1, t, 1);
        }
        let d = m.drain_deltas();
        assert_eq!(d.len(), 1);
        // 20 nnz at k=64: sparse wire (8·20 < 4·64).
        assert!(matches!(d[0].1, RowData::Sparse(_)));
        assert_eq!(d[0].1.nnz(), 20);
        let dense = d[0].1.to_dense(k);
        for t in 0..k {
            assert_eq!(dense[t], i32::from(t < 20));
        }
        // Nearly-full rows go dense on the wire.
        for t in 0..k {
            m.inc(2, t, 1);
        }
        let d = m.drain_deltas();
        assert!(matches!(d[0].1, RowData::Dense(_)));
    }

    #[test]
    fn apply_pull_preserves_unflushed_local_moves() {
        let mut m = CountMatrix::new(4, 2);
        m.inc(1, 0, 3); // unflushed local delta
        m.apply_pull(1, &[10, 5]); // server view
        assert_eq!(m.get(1, 0), 13); // server + pending local
        assert_eq!(m.get(1, 1), 5);
        assert_eq!(m.total(0), 13);
        assert_eq!(m.total(1), 5);

        // After flushing, a pull overwrites exactly.
        let _ = m.drain_deltas();
        m.apply_pull(1, &[20, 6]);
        assert_eq!(m.get(1, 0), 20);
        assert_eq!(m.total(0), 20);
    }

    #[test]
    fn apply_pull_row_sparse_equals_dense() {
        let k = 8;
        let mut a = CountMatrix::new(4, k);
        let mut b = CountMatrix::new(4, k);
        for m in [&mut a, &mut b] {
            m.inc(2, 1, 2);
            m.inc(2, 5, -1);
        }
        let server = [0, 7, 0, 0, 0, 3, 0, 0];
        a.apply_pull(2, &server);
        b.apply_pull_row(2, &RowData::from_dense_auto(&server));
        for t in 0..k {
            assert_eq!(a.get(2, t), b.get(2, t), "cell {t}");
        }
        assert_eq!(a.totals(), b.totals());
    }

    #[test]
    fn inv_denom_tracks_totals() {
        let mut m = CountMatrix::new(10, 3);
        m.set_smoothing(0.5);
        assert!((m.inv_denom(0) - 1.0 / 0.5).abs() < 1e-12);
        m.inc(1, 0, 4);
        assert!((m.inv_denom(0) - 1.0 / 4.5).abs() < 1e-12);
        m.inc(1, 0, -1);
        assert!((m.inv_denom(0) - 1.0 / 3.5).abs() < 1e-12);
        m.apply_pull(1, &[10, 0, 0]); // pending +3 → row = 13
        let _ = m.drain_deltas();
        m.apply_pull(1, &[10, 0, 0]); // flushed → row := 10
        assert!((m.inv_denom(0) - 1.0 / 10.5).abs() < 1e-12);
        // Negative transients clamp to the smoothing floor, like denom().
        m.inc_local(2, 1, -7);
        assert!((m.inv_denom(1) - 1.0 / 0.5).abs() < 1e-12);
        assert!((m.denom(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn requeue_folds_into_newer_deltas() {
        let mut m = CountMatrix::new(6, 4);
        m.inc(3, 1, 2);
        let drained = m.drain_deltas();
        assert_eq!(m.pending_rows(), 0);
        m.inc(3, 2, 5); // newer delta arrives before the requeue
        let (w, row) = drained.into_iter().next().unwrap();
        m.requeue_delta(w, row);
        assert_eq!(m.pending_rows(), 1);
        let d = m.drain_deltas();
        assert_eq!(&*d[0].1.to_dense(4), &[0, 2, 5, 0]);
    }

    /// The O(1) pending counter agrees with the scan it replaced across
    /// every mutation path: inc (including cancel-to-zero), drain,
    /// requeue, and the sparse→dense spill.
    #[test]
    fn pending_counter_matches_scan() {
        let mut m = CountMatrix::new(40, 16);
        let mut rng = crate::util::rng::Rng::new(11);
        for step in 0..2000 {
            let w = rng.below(40) as u32;
            let t = rng.below(16);
            let d = if rng.coin(0.5) { 1 } else { -1 };
            m.inc(w, t, d);
            if step % 97 == 0 {
                let drained = m.drain_deltas();
                assert_eq!(m.pending_rows(), 0, "drain must zero the counter");
                // Filter-retain path: requeue a few drained rows.
                for (w, row) in drained.into_iter().take(3) {
                    m.requeue_delta(w, row);
                }
            }
            assert_eq!(m.pending_rows(), m.pending_rows_scan(), "step {step}");
        }

        // Spill to dense, then cancel every cell back to zero: the
        // counter must follow the record through both transitions.
        let mut m = CountMatrix::new(4, 64);
        for t in 0..40 {
            m.inc(1, t, 1);
            assert_eq!(m.pending_rows(), m.pending_rows_scan());
        }
        assert_eq!(m.pending_rows(), 1);
        for t in 0..40 {
            m.inc(1, t, -1);
            assert_eq!(m.pending_rows(), m.pending_rows_scan());
        }
        assert_eq!(m.pending_rows(), 0, "dense record cancelled to empty");
    }

    #[test]
    fn rebuild_totals_matches_incremental() {
        let mut m = CountMatrix::new(20, 5);
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..500 {
            let w = rng.below(20) as u32;
            let t = rng.below(5);
            m.inc(w, t, 1);
        }
        let inc_totals = m.totals().to_vec();
        m.rebuild_totals();
        assert_eq!(m.totals(), &inc_totals[..]);
    }

    #[test]
    fn topics_per_word_counts_nonzero() {
        let mut m = CountMatrix::new(5, 4);
        m.inc(0, 0, 1);
        m.inc(0, 1, 1);
        m.inc(1, 2, 5);
        assert!((m.avg_topics_per_word() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rowdata_encode_roundtrip() {
        let rows: [&[i32]; 4] = [
            &[0, 0, 0, 0],
            &[1, 0, -2, 0],
            &[5, 5, 5, 5],
            &[0, 0, 0, 9],
        ];
        for r in rows {
            let enc = RowData::from_dense_auto(r);
            assert_eq!(&*enc.to_dense(r.len()), r);
            assert_eq!(enc.nnz(), r.iter().filter(|&&v| v != 0).count());
            assert_eq!(
                enc.l1(),
                r.iter().map(|&v| v.unsigned_abs() as u64).sum::<u64>()
            );
            for (t, &v) in r.iter().enumerate() {
                assert_eq!(enc.get(t), v);
            }
        }
    }

    #[test]
    fn rowdata_fold_saturating() {
        let mut row = vec![1i32, i32::MAX, 0];
        RowData::Sparse(vec![(0, 2), (1, 5)]).fold_saturating_into(&mut row);
        assert_eq!(row, vec![3, i32::MAX, 0]);
        RowData::Dense(vec![1, -1, 7].into_boxed_slice()).fold_saturating_into(&mut row);
        assert_eq!(row, vec![4, i32::MAX - 1, 7]);
    }
}
