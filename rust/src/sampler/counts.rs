//! Shared sufficient-statistics matrices.
//!
//! A [`CountMatrix`] is a client's local replica of one shared statistic
//! (LDA: `n_tw`; PDP: `m_tw` and `s_tw`; HDP adds table counts). Rows are
//! word-indexed, `K`-wide, lazily allocated (a shard only touches its own
//! vocabulary slice), and every mutation is mirrored into a **delta log**
//! that the parameter-server client drains into batched row pushes (§5.3
//! "batched communication").
//!
//! The replica-merge rule is the paper's: the server aggregates deltas from
//! all clients; a pull overwrites the local row with the server value
//! *plus* any still-unflushed local deltas, so local Gibbs moves are never
//! lost (eventual consistency, §5.3).

use std::collections::HashMap;

/// Client replica of a `V × K` count matrix with per-topic aggregates and
/// a delta log.
#[derive(Clone, Debug)]
pub struct CountMatrix {
    k: usize,
    rows: Vec<Option<Box<[i32]>>>,
    /// Per-topic aggregate (`n_t` in LDA, `m_t`/`s_t` in PDP).
    totals: Vec<i64>,
    /// Unflushed local updates per touched row.
    deltas: HashMap<u32, Box<[i32]>>,
}

impl CountMatrix {
    /// Empty matrix over `vocab` words × `k` topics.
    pub fn new(vocab: usize, k: usize) -> Self {
        CountMatrix {
            k,
            rows: vec![None; vocab],
            totals: vec![0; k],
            deltas: HashMap::new(),
        }
    }

    /// Topic count `K`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.rows.len()
    }

    /// Read one cell.
    #[inline]
    pub fn get(&self, word: u32, topic: usize) -> i32 {
        match &self.rows[word as usize] {
            Some(r) => r[topic],
            None => 0,
        }
    }

    /// Borrow a row (`None` if the word was never touched).
    #[inline]
    pub fn row(&self, word: u32) -> Option<&[i32]> {
        self.rows[word as usize].as_deref()
    }

    /// Per-topic aggregates (`n_t`).
    #[inline]
    pub fn totals(&self) -> &[i64] {
        &self.totals
    }

    /// Aggregate for one topic.
    #[inline]
    pub fn total(&self, topic: usize) -> i64 {
        self.totals[topic]
    }

    /// Grand total over all topics.
    pub fn grand_total(&self) -> i64 {
        self.totals.iter().sum()
    }

    fn ensure_row(&mut self, word: u32) -> &mut [i32] {
        let slot = &mut self.rows[word as usize];
        if slot.is_none() {
            *slot = Some(vec![0i32; self.k].into_boxed_slice());
        }
        slot.as_deref_mut().unwrap()
    }

    /// Apply a local Gibbs move: `cell += delta`, mirrored into the delta
    /// log and the per-topic aggregate.
    #[inline]
    pub fn inc(&mut self, word: u32, topic: usize, delta: i32) {
        let k = self.k;
        let row = self.ensure_row(word);
        row[topic] += delta;
        self.totals[topic] += delta as i64;
        let d = self
            .deltas
            .entry(word)
            .or_insert_with(|| vec![0i32; k].into_boxed_slice());
        d[topic] += delta;
    }

    /// Apply a local move *without* recording a delta (used for local-only
    /// statistics and for replaying a snapshot).
    #[inline]
    pub fn inc_local(&mut self, word: u32, topic: usize, delta: i32) {
        let row = self.ensure_row(word);
        row[topic] += delta;
        self.totals[topic] += delta as i64;
    }

    /// Drain the delta log into `(word, row-delta)` batches for pushing.
    /// Zero rows are dropped.
    pub fn drain_deltas(&mut self) -> Vec<(u32, Box<[i32]>)> {
        let mut out: Vec<(u32, Box<[i32]>)> = self
            .deltas
            .drain()
            .filter(|(_, d)| d.iter().any(|&x| x != 0))
            .collect();
        out.sort_unstable_by_key(|(w, _)| *w);
        out
    }

    /// Number of rows currently carrying unflushed deltas.
    pub fn pending_rows(&self) -> usize {
        self.deltas.len()
    }

    /// Re-queue a delta row the communication filter chose to retain
    /// (folds into any newer pending deltas; does not touch counts).
    pub fn requeue_delta(&mut self, word: u32, row: Box<[i32]>) {
        let k = self.k;
        let d = self
            .deltas
            .entry(word)
            .or_insert_with(|| vec![0i32; k].into_boxed_slice());
        for (acc, v) in d.iter_mut().zip(row.iter()) {
            *acc += v;
        }
    }

    /// Absorb a pulled server row: replica := server + unflushed local
    /// deltas (so local moves aren't erased), aggregates fixed up.
    pub fn apply_pull(&mut self, word: u32, server_row: &[i32]) {
        assert_eq!(server_row.len(), self.k);
        let pending: Option<Box<[i32]>> = self.deltas.get(&word).cloned();
        self.ensure_row(word);
        let row = self.rows[word as usize].as_deref_mut().unwrap();
        for (t, cell) in row.iter_mut().enumerate() {
            let newv = server_row[t] + pending.as_ref().map_or(0, |p| p[t]);
            let old = *cell;
            *cell = newv;
            self.totals[t] += (newv - old) as i64;
        }
    }

    /// Iterate allocated rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = (u32, &[i32])> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(w, r)| r.as_deref().map(|r| (w as u32, r)))
    }

    /// Recompute per-topic aggregates from scratch (consistency repair /
    /// after bulk row replacement).
    pub fn rebuild_totals(&mut self) {
        let mut totals = vec![0i64; self.k];
        for row in self.rows.iter().flatten() {
            for (t, &c) in row.iter().enumerate() {
                totals[t] += c as i64;
            }
        }
        self.totals = totals;
    }

    /// Average number of non-zero topics per allocated word row — the
    /// "average topics per word" panel of the paper's figures.
    pub fn avg_topics_per_word(&self) -> f64 {
        let mut words = 0u64;
        let mut nonzero = 0u64;
        for row in self.rows.iter().flatten() {
            words += 1;
            nonzero += row.iter().filter(|&&c| c > 0).count() as u64;
        }
        if words == 0 {
            0.0
        } else {
            nonzero as f64 / words as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_and_totals() {
        let mut m = CountMatrix::new(10, 4);
        m.inc(3, 1, 2);
        m.inc(3, 2, 1);
        m.inc(7, 1, 1);
        assert_eq!(m.get(3, 1), 2);
        assert_eq!(m.get(3, 0), 0);
        assert_eq!(m.total(1), 3);
        assert_eq!(m.grand_total(), 4);
        assert_eq!(m.row(0), None);
    }

    #[test]
    fn drain_deltas_batches_rows() {
        let mut m = CountMatrix::new(10, 3);
        m.inc(5, 0, 1);
        m.inc(5, 2, -1);
        m.inc(2, 1, 4);
        m.inc(9, 1, 1);
        m.inc(9, 1, -1); // cancels to zero → dropped
        let d = m.drain_deltas();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].0, 2);
        assert_eq!(&*d[0].1, &[0, 4, 0]);
        assert_eq!(d[1].0, 5);
        assert_eq!(&*d[1].1, &[1, 0, -1]);
        assert!(m.drain_deltas().is_empty());
        // Matrix content unaffected by draining.
        assert_eq!(m.get(5, 0), 1);
    }

    #[test]
    fn apply_pull_preserves_unflushed_local_moves() {
        let mut m = CountMatrix::new(4, 2);
        m.inc(1, 0, 3); // unflushed local delta
        m.apply_pull(1, &[10, 5]); // server view
        assert_eq!(m.get(1, 0), 13); // server + pending local
        assert_eq!(m.get(1, 1), 5);
        assert_eq!(m.total(0), 13);
        assert_eq!(m.total(1), 5);

        // After flushing, a pull overwrites exactly.
        let _ = m.drain_deltas();
        m.apply_pull(1, &[20, 6]);
        assert_eq!(m.get(1, 0), 20);
        assert_eq!(m.total(0), 20);
    }

    #[test]
    fn rebuild_totals_matches_incremental() {
        let mut m = CountMatrix::new(20, 5);
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..500 {
            let w = rng.below(20) as u32;
            let t = rng.below(5);
            m.inc(w, t, 1);
        }
        let inc_totals = m.totals().to_vec();
        m.rebuild_totals();
        assert_eq!(m.totals(), &inc_totals[..]);
    }

    #[test]
    fn topics_per_word_counts_nonzero() {
        let mut m = CountMatrix::new(5, 4);
        m.inc(0, 0, 1);
        m.inc(0, 1, 1);
        m.inc(1, 2, 5);
        assert!((m.avg_topics_per_word() - 1.5).abs() < 1e-12);
    }
}
