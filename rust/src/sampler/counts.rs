//! Shared sufficient-statistics matrices — the **fully sparse hot path**.
//!
//! A [`CountMatrix`] is a client's local replica of one shared statistic
//! (LDA: `n_tw`; PDP: `m_tw` and `s_tw`; HDP adds table counts). Rows are
//! word-indexed, lazily allocated (a shard only touches its own
//! vocabulary slice), and every mutation is mirrored into a **delta log**
//! that the parameter-server client drains into batched row pushes (§5.3
//! "batched communication").
//!
//! Both the replica rows *and* the delta records are [`HybridRow`]s — a
//! three-stage representation whose memory scales with **occupancy, not
//! K**:
//!
//! * **Short list** (`≤ 8` entries): sorted `(topic, count)` pairs,
//!   binary-searched. Covers the overwhelming majority of words at
//!   paper scale (the average word touches a handful of topics).
//! * **Open-addressing hash** (up to `~K/4` entries): power-of-two
//!   table of `(u32 key, i32 val)` slots, linear probing, grown at 3/4
//!   load. `inc`/`get` stay `O(1)`; iteration skips empty and
//!   cancelled-to-zero slots.
//! * **Dense `i32[K]`** — entered only past `K/4` occupancy (or when
//!   the hash table would outweigh the dense row), where dense is both
//!   smaller and faster to scan. A cached non-zero count keeps `nnz`
//!   `O(1)` in every form.
//!
//! Conversion to/from the [`RowData`] wire forms is lossless and picks
//! the same sparse/dense break-even (`8·nnz < 4·K`) as
//! [`RowData::from_dense_auto`], so wire bytes are bit-identical to the
//! dense era. Records are cleared (capacity kept), not removed, across
//! drain cycles, so the steady-state token loop allocates nothing.
//!
//! The third sparsity mechanism is unchanged: every sampler denominator
//! has the shape `n_t + smoothing` (`β̄`, PDP `b`, `γ̄`), and the matrix
//! caches `inv_denom[t] = 1/(max(n_t,0) + smoothing)` refreshed on each
//! total change (one division per `inc` instead of one per topic per
//! token). Enable with [`CountMatrix::set_smoothing`]; read with
//! [`CountMatrix::inv_denom`].
//!
//! The replica-merge rule is the paper's: the server aggregates deltas
//! from all clients; a pull overwrites the local row with the server
//! value *plus* any still-unflushed local deltas, so local Gibbs moves
//! are never lost (eventual consistency, §5.3).

use std::collections::HashMap;

/// One batched row on the wire: either a full `K`-wide row (dense) or the
/// non-zero `(topic, value)` cells (sparse, sorted by topic).
///
/// For a `Push` the values are **deltas** (unlisted topics moved by 0);
/// for a `PullResp` they are **absolute** counts (unlisted topics are 0).
/// Both follow from the same invariant: a sparse row *is* the dense row
/// with its zero cells elided, so `to_dense` ∘ encode is the identity.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RowData {
    /// Full-width row (`len == K`).
    Dense(Box<[i32]>),
    /// Non-zero cells only, sorted by topic.
    Sparse(Vec<(u32, i32)>),
}

impl RowData {
    /// Encode a dense slice, choosing the smaller wire form: sparse costs
    /// 8 bytes per non-zero cell, dense 4 per topic.
    pub fn from_dense_auto(row: &[i32]) -> RowData {
        let nnz = row.iter().filter(|&&v| v != 0).count();
        if 8 * nnz < 4 * row.len() {
            RowData::Sparse(
                row.iter()
                    .enumerate()
                    .filter(|&(_, &v)| v != 0)
                    .map(|(t, &v)| (t as u32, v))
                    .collect(),
            )
        } else {
            RowData::Dense(row.to_vec().into_boxed_slice())
        }
    }

    /// Number of non-zero cells.
    pub fn nnz(&self) -> usize {
        match self {
            RowData::Dense(r) => r.iter().filter(|&&v| v != 0).count(),
            RowData::Sparse(es) => es.len(),
        }
    }

    /// Minimum dense width able to hold this row.
    pub fn min_width(&self) -> usize {
        match self {
            RowData::Dense(r) => r.len(),
            RowData::Sparse(es) => es.iter().map(|&(t, _)| t as usize + 1).max().unwrap_or(0),
        }
    }

    /// Value at `topic` (0 when elided).
    #[inline]
    pub fn get(&self, topic: usize) -> i32 {
        match self {
            RowData::Dense(r) => r.get(topic).copied().unwrap_or(0),
            RowData::Sparse(es) => es
                .iter()
                .find(|&&(t, _)| t as usize == topic)
                .map(|&(_, v)| v)
                .unwrap_or(0),
        }
    }

    /// L1 magnitude (the communication filter's priority key).
    pub fn l1(&self) -> u64 {
        match self {
            RowData::Dense(r) => r.iter().map(|&v| v.unsigned_abs() as u64).sum(),
            RowData::Sparse(es) => es.iter().map(|&(_, v)| v.unsigned_abs() as u64).sum(),
        }
    }

    /// Materialize as a `width`-wide dense row. A sparse entry beyond
    /// `width` is a logic error and panics; a dense row wider than
    /// `width` is clamped to the first `width` cells.
    pub fn to_dense(&self, width: usize) -> Box<[i32]> {
        let mut out = vec![0i32; width];
        match self {
            RowData::Dense(r) => out[..r.len().min(width)].copy_from_slice(&r[..r.len().min(width)]),
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    out[t as usize] = v;
                }
            }
        }
        out.into_boxed_slice()
    }

    /// Fold this row as **deltas** into `row` with saturating adds (the
    /// server's push-apply). `row` must already be at least
    /// [`RowData::min_width`] wide.
    pub fn fold_saturating_into(&self, row: &mut [i32]) {
        match self {
            RowData::Dense(r) => {
                for (c, d) in row.iter_mut().zip(r.iter()) {
                    *c = c.saturating_add(*d);
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    let c = &mut row[t as usize];
                    *c = c.saturating_add(v);
                }
            }
        }
    }

    /// Approximate wire footprint in bytes: 1 tag + 4 length + payload
    /// (4 bytes per dense cell, 8 per sparse `(topic, value)` pair).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            RowData::Dense(r) => 5 + 4 * r.len() as u64,
            RowData::Sparse(es) => 5 + 8 * es.len() as u64,
        }
    }
}

/// Short-list capacity before a row promotes to the hash form.
const SHORT_MAX: usize = 8;

/// Empty-slot marker in the open-addressing key table (`u32::MAX` is not
/// a valid topic id — K is bounded well below it).
const EMPTY: u32 = u32::MAX;

/// Occupancy above which a row densifies: past `~K/4` distinct topics
/// the dense `i32[K]` row is both smaller than the 8-byte-per-slot hash
/// table and faster to scan.
#[inline]
fn dense_cut(k: usize) -> usize {
    (k / 4).max(SHORT_MAX)
}

/// Open-addressing `(topic → count)` table: power-of-two capacity,
/// Fibonacci-multiply hash, linear probing, no tombstones (a key whose
/// value cancelled to zero keeps its slot until the next rehash so
/// probe chains never break).
#[derive(Clone, Debug)]
struct HashCells {
    keys: Box<[u32]>,
    vals: Box<[i32]>,
    /// Slots holding a key — including zero-valued ones.
    occupied: u32,
    /// Slots holding a non-zero value.
    nnz: u32,
}

impl HashCells {
    fn with_capacity(cap: usize) -> HashCells {
        let cap = cap.next_power_of_two().max(16);
        HashCells {
            keys: vec![EMPTY; cap].into_boxed_slice(),
            vals: vec![0i32; cap].into_boxed_slice(),
            occupied: 0,
            nnz: 0,
        }
    }

    /// Probe for `t`: the slot holding it, or the first empty slot.
    #[inline]
    fn slot_of(&self, t: u32) -> usize {
        let mask = self.keys.len() - 1;
        let mut i = (t.wrapping_mul(0x9E37_79B9) as usize) & mask;
        loop {
            let k = self.keys[i];
            if k == t || k == EMPTY {
                return i;
            }
            i = (i + 1) & mask;
        }
    }

    #[inline]
    fn get(&self, t: u32) -> i32 {
        let i = self.slot_of(t);
        if self.keys[i] == t {
            self.vals[i]
        } else {
            0
        }
    }

    /// True when inserting one more key would push load past 3/4 (the
    /// probe-chain guarantee; an empty slot must always exist).
    #[inline]
    fn wants_grow(&self) -> bool {
        (self.occupied as usize + 1) * 4 > self.keys.len() * 3
    }

    /// Rebuild at a capacity sized for the live entries, dropping
    /// cancelled-to-zero slots.
    fn rehashed(&self) -> HashCells {
        let mut next = HashCells::with_capacity((self.nnz as usize + 1) * 2);
        for i in 0..self.keys.len() {
            if self.keys[i] != EMPTY && self.vals[i] != 0 {
                let j = next.slot_of(self.keys[i]);
                next.keys[j] = self.keys[i];
                next.vals[j] = self.vals[i];
                next.occupied += 1;
                next.nnz += 1;
            }
        }
        next
    }

    /// Empty the table, keeping its capacity.
    fn clear(&mut self) {
        self.keys.fill(EMPTY);
        self.vals.fill(0);
        self.occupied = 0;
        self.nnz = 0;
    }
}

fn densify_short(v: &[(u32, i32)], k: usize) -> (Box<[i32]>, u32) {
    let mut cells = vec![0i32; k].into_boxed_slice();
    let mut nnz = 0u32;
    for &(t, val) in v {
        if val != 0 {
            cells[t as usize] = val;
            nnz += 1;
        }
    }
    (cells, nnz)
}

fn densify_hash(h: &HashCells, k: usize) -> (Box<[i32]>, u32) {
    let mut cells = vec![0i32; k].into_boxed_slice();
    let mut nnz = 0u32;
    for i in 0..h.keys.len() {
        if h.keys[i] != EMPTY && h.vals[i] != 0 {
            cells[h.keys[i] as usize] = h.vals[i];
            nnz += 1;
        }
    }
    (cells, nnz)
}

#[derive(Clone, Debug)]
enum Repr {
    Short(Vec<(u32, i32)>),
    Hash(HashCells),
    Dense { cells: Box<[i32]>, nnz: u32 },
}

/// Which representation a [`HybridRow`] currently uses (diagnostics and
/// the bench memory panel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowReprKind {
    /// Sorted short list of `(topic, count)` pairs.
    Short,
    /// Open-addressing hash table.
    Hash,
    /// Full-width `i32[K]` row.
    Dense,
}

/// A word-topic count row whose memory scales with occupancy, not `K`:
/// sorted short list (≤ 8 pairs) → open-addressing hash → dense
/// `i32[K]` only past `~K/4` occupancy. `O(1)` [`get`](HybridRow::get) /
/// [`add`](HybridRow::add) / [`nnz`](HybridRow::nnz) in every form;
/// [`for_each`](HybridRow::for_each) visits non-zeros only. Promotion is
/// automatic and one-way under mutation; [`compact`](HybridRow::compact)
/// demotes after bulk cancellation.
#[derive(Clone, Debug)]
pub struct HybridRow {
    k: u32,
    repr: Repr,
}

impl HybridRow {
    /// Empty row of width `k`.
    pub fn new(k: usize) -> HybridRow {
        HybridRow {
            k: k as u32,
            repr: Repr::Short(Vec::with_capacity(SHORT_MAX)),
        }
    }

    /// Build from a dense slice (width = `cells.len()`), keeping only
    /// the non-zeros. The representation comes out right-sized.
    pub fn from_dense(cells: &[i32]) -> HybridRow {
        let mut row = HybridRow::new(cells.len());
        for (t, &v) in cells.iter().enumerate() {
            if v != 0 {
                row.set(t, v);
            }
        }
        row
    }

    /// Build from a wire row: width is `width`, widened if the row
    /// carries a cell beyond it. Values are taken as absolute.
    pub fn from_rowdata(data: &RowData, width: usize) -> HybridRow {
        let mut row = HybridRow::new(width.max(data.min_width()));
        match data {
            RowData::Dense(r) => {
                for (t, &v) in r.iter().enumerate() {
                    if v != 0 {
                        row.set(t, v);
                    }
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    if v != 0 {
                        row.set(t as usize, v);
                    }
                }
            }
        }
        row
    }

    /// Row width (`K`).
    #[inline]
    pub fn k(&self) -> usize {
        self.k as usize
    }

    /// Non-zero cell count — `O(1)` in every representation.
    #[inline]
    pub fn nnz(&self) -> usize {
        match &self.repr {
            Repr::Short(v) => v.len(),
            Repr::Hash(h) => h.nnz as usize,
            Repr::Dense { nnz, .. } => *nnz as usize,
        }
    }

    /// Current representation (diagnostics / bench panel).
    pub fn repr_kind(&self) -> RowReprKind {
        match &self.repr {
            Repr::Short(_) => RowReprKind::Short,
            Repr::Hash(_) => RowReprKind::Hash,
            Repr::Dense { .. } => RowReprKind::Dense,
        }
    }

    /// Value at `t` (0 when absent).
    #[inline]
    pub fn get(&self, t: usize) -> i32 {
        debug_assert!(t < self.k as usize, "topic {} out of row width {}", t, self.k);
        match &self.repr {
            Repr::Short(v) => v
                .binary_search_by_key(&(t as u32), |&(tt, _)| tt)
                .map(|i| v[i].1)
                .unwrap_or(0),
            Repr::Hash(h) => h.get(t as u32),
            Repr::Dense { cells, .. } => cells[t],
        }
    }

    /// Core mutation: replace the cell at `t` with `f(current)`. Handles
    /// the empty↔non-empty bookkeeping and representation promotion; `f`
    /// is re-applied exactly once if the current form had no room.
    #[inline]
    fn update_with<F: Copy + Fn(i32) -> i32>(&mut self, t: usize, f: F) {
        assert!(t < self.k as usize, "topic {} out of row width {}", t, self.k);
        let t32 = t as u32;
        let (applied, promote) = match &mut self.repr {
            Repr::Short(v) => match v.binary_search_by_key(&t32, |&(tt, _)| tt) {
                Ok(i) => {
                    let nv = f(v[i].1);
                    if nv == 0 {
                        v.remove(i);
                    } else {
                        v[i].1 = nv;
                    }
                    (true, false)
                }
                Err(i) => {
                    let nv = f(0);
                    if nv == 0 {
                        (true, false)
                    } else if v.len() < SHORT_MAX {
                        v.insert(i, (t32, nv));
                        (true, false)
                    } else {
                        (false, true)
                    }
                }
            },
            Repr::Hash(h) => {
                let i = h.slot_of(t32);
                if h.keys[i] == t32 {
                    let old = h.vals[i];
                    let nv = f(old);
                    h.vals[i] = nv;
                    if old != 0 && nv == 0 {
                        h.nnz -= 1;
                    } else if old == 0 && nv != 0 {
                        h.nnz += 1;
                    }
                    (true, h.nnz as usize > dense_cut(self.k as usize))
                } else {
                    let nv = f(0);
                    if nv == 0 {
                        (true, false)
                    } else if h.wants_grow() {
                        (false, true)
                    } else {
                        h.keys[i] = t32;
                        h.vals[i] = nv;
                        h.occupied += 1;
                        h.nnz += 1;
                        (true, h.nnz as usize > dense_cut(self.k as usize))
                    }
                }
            }
            Repr::Dense { cells, nnz } => {
                let old = cells[t];
                let nv = f(old);
                cells[t] = nv;
                if old != 0 && nv == 0 {
                    *nnz -= 1;
                } else if old == 0 && nv != 0 {
                    *nnz += 1;
                }
                (true, false)
            }
        };
        if promote {
            self.promote();
            if !applied {
                self.update_with(t, f);
            }
        }
    }

    /// Move to the next representation: Short → Hash (or straight to
    /// Dense at tiny `K`, where the short list already exceeds the
    /// density cut), Hash → grown Hash, or → Dense once past the cut or
    /// once the grown table would outweigh `i32[K]`.
    fn promote(&mut self) {
        let k = self.k as usize;
        let cut = dense_cut(k);
        let repr = std::mem::replace(&mut self.repr, Repr::Short(Vec::new()));
        self.repr = match repr {
            Repr::Short(v) => {
                if SHORT_MAX >= cut {
                    let (cells, nnz) = densify_short(&v, k);
                    Repr::Dense { cells, nnz }
                } else {
                    let mut h = HashCells::with_capacity((v.len() + 1) * 2);
                    for &(t, val) in &v {
                        let i = h.slot_of(t);
                        h.keys[i] = t;
                        h.vals[i] = val;
                        h.occupied += 1;
                        h.nnz += 1;
                    }
                    Repr::Hash(h)
                }
            }
            Repr::Hash(h) => {
                let grown_cap = ((h.nnz as usize + 1) * 2).next_power_of_two().max(16);
                if h.nnz as usize > cut || grown_cap * 8 >= k * 4 {
                    let (cells, nnz) = densify_hash(&h, k);
                    Repr::Dense { cells, nnz }
                } else {
                    Repr::Hash(h.rehashed())
                }
            }
            dense @ Repr::Dense { .. } => dense,
        };
    }

    /// `cell += d` (exact; overflow panics in debug like `i32` addition).
    #[inline]
    pub fn add(&mut self, t: usize, d: i32) {
        if d == 0 {
            return;
        }
        self.update_with(t, move |c| c + d);
    }

    /// `cell = cell.saturating_add(d)` (the server's push-apply).
    #[inline]
    pub fn add_saturating(&mut self, t: usize, d: i32) {
        if d == 0 {
            return;
        }
        self.update_with(t, move |c| c.saturating_add(d));
    }

    /// `cell = v`.
    #[inline]
    pub fn set(&mut self, t: usize, v: i32) {
        self.update_with(t, move |_| v);
    }

    /// Visit every non-zero cell as `(topic, value)`. Short rows visit
    /// in topic order; hash rows in table order; dense in topic order.
    #[inline]
    pub fn for_each<F: FnMut(u32, i32)>(&self, mut f: F) {
        match &self.repr {
            Repr::Short(v) => {
                for &(t, val) in v {
                    f(t, val);
                }
            }
            Repr::Hash(h) => {
                for i in 0..h.keys.len() {
                    if h.keys[i] != EMPTY && h.vals[i] != 0 {
                        f(h.keys[i], h.vals[i]);
                    }
                }
            }
            Repr::Dense { cells, .. } => {
                for (t, &v) in cells.iter().enumerate() {
                    if v != 0 {
                        f(t as u32, v);
                    }
                }
            }
        }
    }

    /// Largest cell value, floored at 0 (dense rows always held zeros).
    pub fn max_value(&self) -> i32 {
        let mut m = 0;
        self.for_each(|_, v| m = m.max(v));
        m
    }

    /// Materialize as a full-width dense row.
    pub fn to_dense_box(&self) -> Box<[i32]> {
        let mut out = vec![0i32; self.k as usize].into_boxed_slice();
        self.for_each(|t, v| out[t as usize] = v);
        out
    }

    /// Encode for the wire, choosing the same sparse/dense break-even as
    /// [`RowData::from_dense_auto`] (so wire bytes are bit-identical to
    /// the dense era). Sparse output is sorted by topic.
    pub fn to_rowdata(&self) -> RowData {
        let nnz = self.nnz();
        if 8 * nnz < 4 * self.k as usize {
            let mut es = Vec::with_capacity(nnz);
            self.for_each(|t, v| es.push((t, v)));
            es.sort_unstable_by_key(|&(t, _)| t);
            RowData::Sparse(es)
        } else {
            RowData::Dense(self.to_dense_box())
        }
    }

    /// Fold a wire row in as **deltas** with saturating adds (the
    /// server's push-apply; pairs with [`RowData::fold_saturating_into`]).
    pub fn fold_rowdata(&mut self, data: &RowData) {
        match data {
            RowData::Dense(r) => {
                for (t, &v) in r.iter().enumerate() {
                    if v != 0 {
                        self.add_saturating(t, v);
                    }
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    if v != 0 {
                        self.add_saturating(t as usize, v);
                    }
                }
            }
        }
    }

    /// Fold a wire row in as **deltas** with exact adds (the client's
    /// requeue-after-filter path, where cancellation must be exact).
    pub fn add_rowdata(&mut self, data: &RowData) {
        match data {
            RowData::Dense(r) => {
                for (t, &v) in r.iter().enumerate() {
                    if v != 0 {
                        self.add(t, v);
                    }
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    if v != 0 {
                        self.add(t as usize, v);
                    }
                }
            }
        }
    }

    /// Widen to at least `width` topics (no-op when already wide
    /// enough). Sparse forms just adopt the new width; a dense row
    /// reallocates and copies.
    pub fn ensure_width(&mut self, width: usize) {
        if width <= self.k as usize {
            return;
        }
        if let Repr::Dense { cells, .. } = &mut self.repr {
            let mut wider = vec![0i32; width].into_boxed_slice();
            wider[..cells.len()].copy_from_slice(cells);
            *cells = wider;
        }
        self.k = width as u32;
    }

    /// Zero every cell, keeping the representation and its capacity (the
    /// delta log's drain path — steady state allocates nothing).
    pub fn clear(&mut self) {
        match &mut self.repr {
            Repr::Short(v) => v.clear(),
            Repr::Hash(h) => h.clear(),
            Repr::Dense { cells, nnz } => {
                cells.fill(0);
                *nnz = 0;
            }
        }
    }

    /// Shrink to the smallest representation that fits the current
    /// occupancy. Mutation only ever promotes; call this after bulk
    /// cancellation when the smaller form matters.
    pub fn compact(&mut self) {
        let k = self.k as usize;
        let nnz = self.nnz();
        if nnz <= SHORT_MAX {
            if matches!(self.repr, Repr::Short(_)) {
                return;
            }
            let mut v = Vec::with_capacity(SHORT_MAX);
            self.for_each(|t, val| v.push((t, val)));
            v.sort_unstable_by_key(|&(t, _)| t);
            self.repr = Repr::Short(v);
        } else if nnz <= dense_cut(k) && SHORT_MAX < dense_cut(k) {
            let mut h = HashCells::with_capacity((nnz + 1) * 2);
            self.for_each(|t, val| {
                let i = h.slot_of(t);
                h.keys[i] = t;
                h.vals[i] = val;
                h.occupied += 1;
                h.nnz += 1;
            });
            self.repr = Repr::Hash(h);
        }
        // Above the cut the dense form is already the right one.
    }

    /// Resident heap+inline bytes of this row (the bench memory panel's
    /// per-row figure; a dense-era row was always `4·K` + header).
    pub fn resident_bytes(&self) -> usize {
        std::mem::size_of::<HybridRow>()
            + match &self.repr {
                Repr::Short(v) => v.capacity() * std::mem::size_of::<(u32, i32)>(),
                Repr::Hash(h) => h.keys.len() * 8,
                Repr::Dense { cells, .. } => cells.len() * 4,
            }
    }
}

impl Default for HybridRow {
    fn default() -> Self {
        HybridRow::new(0)
    }
}

impl From<Vec<i32>> for HybridRow {
    fn from(cells: Vec<i32>) -> HybridRow {
        HybridRow::from_dense(&cells)
    }
}

/// Content equality: same width, same non-zero cells (representation is
/// irrelevant — a short, hash, and dense row holding the same cells are
/// equal).
impl PartialEq for HybridRow {
    fn eq(&self, other: &HybridRow) -> bool {
        if self.k != other.k || self.nnz() != other.nnz() {
            return false;
        }
        let mut eq = true;
        self.for_each(|t, v| {
            if other.get(t as usize) != v {
                eq = false;
            }
        });
        eq
    }
}
impl Eq for HybridRow {}

#[inline]
fn inv_of(total: i64, smoothing: f64) -> f64 {
    1.0 / ((total as f64).max(0.0) + smoothing)
}

/// Client replica of a `V × K` count matrix with per-topic aggregates, a
/// sparse delta log, and an incremental normalizer cache. Rows and delta
/// records are both [`HybridRow`]s, so resident memory scales with the
/// topics a word actually uses, never with `K`.
#[derive(Clone, Debug)]
pub struct CountMatrix {
    k: usize,
    rows: Vec<Option<HybridRow>>,
    /// Per-topic aggregate (`n_t` in LDA, `m_t`/`s_t` in PDP).
    totals: Vec<i64>,
    /// Normalizer smoothing mass (`β̄`, PDP `b`, `γ̄` — whatever the
    /// model adds to `n_t` in its denominators). 0 until
    /// [`CountMatrix::set_smoothing`].
    smoothing: f64,
    /// Cached `1/(max(n_t,0) + smoothing)`, refreshed on every total
    /// change. Meaningless (±inf) until a positive smoothing is set.
    inv_denom: Vec<f64>,
    /// Unflushed local updates per touched row. Entries persist (cleared,
    /// not removed) across drains so the token loop never reallocates.
    deltas: HashMap<u32, HybridRow>,
    /// Live count of delta records with non-zero content, maintained on
    /// every empty↔non-empty record transition — [`pending_rows`]
    /// (Self::pending_rows) reads it in `O(1)` instead of scanning the
    /// touched vocabulary.
    pending: usize,
}

impl CountMatrix {
    /// Empty matrix over `vocab` words × `k` topics.
    pub fn new(vocab: usize, k: usize) -> Self {
        CountMatrix {
            k,
            rows: vec![None; vocab],
            totals: vec![0; k],
            smoothing: 0.0,
            inv_denom: vec![f64::INFINITY; k],
            deltas: HashMap::new(),
            pending: 0,
        }
    }

    /// Topic count `K`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.rows.len()
    }

    /// Read one cell.
    #[inline]
    pub fn get(&self, word: u32, topic: usize) -> i32 {
        match &self.rows[word as usize] {
            Some(r) => r.get(topic),
            None => 0,
        }
    }

    /// Borrow a row (`None` if the word was never touched).
    #[inline]
    pub fn row(&self, word: u32) -> Option<&HybridRow> {
        self.rows[word as usize].as_ref()
    }

    /// Per-topic aggregates (`n_t`).
    #[inline]
    pub fn totals(&self) -> &[i64] {
        &self.totals
    }

    /// Aggregate for one topic.
    #[inline]
    pub fn total(&self, topic: usize) -> i64 {
        self.totals[topic]
    }

    /// Grand total over all topics.
    pub fn grand_total(&self) -> i64 {
        self.totals.iter().sum()
    }

    /// Enable the incremental normalizer cache: `smoothing` is the mass
    /// the model adds to `n_t` in its denominators (`β̄` for LDA/HDP word
    /// factors, `b` for the PDP customer denominator, `γ̄` for the PDP
    /// root). Rebuilds the cache for the current totals.
    pub fn set_smoothing(&mut self, smoothing: f64) {
        self.smoothing = smoothing;
        for t in 0..self.k {
            self.inv_denom[t] = inv_of(self.totals[t], smoothing);
        }
    }

    /// Cached `1/(max(n_t,0) + smoothing)` — the samplers' per-topic
    /// denominator, maintained incrementally so inner loops multiply
    /// instead of divide. Requires [`CountMatrix::set_smoothing`] first.
    #[inline]
    pub fn inv_denom(&self, topic: usize) -> f64 {
        self.inv_denom[topic]
    }

    /// `max(n_t,0) + smoothing` (the uninverted normalizer; cold paths).
    #[inline]
    pub fn denom(&self, topic: usize) -> f64 {
        (self.totals[topic] as f64).max(0.0) + self.smoothing
    }

    #[inline]
    fn bump_total(&mut self, topic: usize, delta: i64) {
        self.totals[topic] += delta;
        self.inv_denom[topic] = inv_of(self.totals[topic], self.smoothing);
    }

    /// Apply a local Gibbs move: `cell += delta`, mirrored into the sparse
    /// delta log and the per-topic aggregate (+ normalizer cache). `O(1)`
    /// and allocation-free once the word's row and delta record exist.
    #[inline]
    pub fn inc(&mut self, word: u32, topic: usize, delta: i32) {
        let k = self.k;
        self.rows[word as usize]
            .get_or_insert_with(|| HybridRow::new(k))
            .add(topic, delta);
        self.bump_total(topic, delta as i64);
        let rec = self.deltas.entry(word).or_insert_with(|| HybridRow::new(k));
        let was_empty = rec.nnz() == 0;
        rec.add(topic, delta);
        let now_empty = rec.nnz() == 0;
        if was_empty && !now_empty {
            self.pending += 1;
        } else if !was_empty && now_empty {
            self.pending -= 1;
        }
    }

    /// Apply a local move *without* recording a delta (used for local-only
    /// statistics and for replaying a snapshot).
    #[inline]
    pub fn inc_local(&mut self, word: u32, topic: usize, delta: i32) {
        let k = self.k;
        self.rows[word as usize]
            .get_or_insert_with(|| HybridRow::new(k))
            .add(topic, delta);
        self.bump_total(topic, delta as i64);
    }

    /// Drain the delta log into `(word, row)` batches for pushing, each
    /// row in the cheaper wire form (sparse below `8·nnz < 4·K`). Zero
    /// rows are skipped; records stay allocated for reuse.
    pub fn drain_deltas(&mut self) -> Vec<(u32, RowData)> {
        let mut out: Vec<(u32, RowData)> = Vec::new();
        for (&w, rec) in self.deltas.iter_mut() {
            if rec.nnz() == 0 {
                continue;
            }
            out.push((w, rec.to_rowdata()));
            rec.clear();
        }
        self.pending = 0;
        out.sort_unstable_by_key(|&(w, _)| w);
        out
    }

    /// Number of rows currently carrying unflushed deltas — `O(1)`,
    /// served from the live counter maintained on every empty↔non-empty
    /// record transition (it used to scan the touched vocabulary, which
    /// every filter-retain push paid for).
    pub fn pending_rows(&self) -> usize {
        self.pending
    }

    /// The `O(touched-vocab)` scan [`pending_rows`](Self::pending_rows)
    /// replaced — kept as the oracle for the counter's regression test.
    pub fn pending_rows_scan(&self) -> usize {
        self.deltas.values().filter(|d| d.nnz() > 0).count()
    }

    /// Re-queue a delta row the communication filter chose to retain
    /// (folds into any newer pending deltas; does not touch counts).
    pub fn requeue_delta(&mut self, word: u32, row: RowData) {
        let k = self.k;
        let rec = self.deltas.entry(word).or_insert_with(|| HybridRow::new(k));
        let was_empty = rec.nnz() == 0;
        rec.add_rowdata(&row);
        let now_empty = rec.nnz() == 0;
        if was_empty && !now_empty {
            self.pending += 1;
        } else if !was_empty && now_empty {
            self.pending -= 1;
        }
    }

    /// Take a word's row out, removing its current contents from the
    /// aggregates. The caller repopulates it with the server view and
    /// hands it back to [`pull_finish`](Self::pull_finish).
    fn pull_begin(&mut self, word: u32) -> HybridRow {
        let k = self.k;
        let mut row = self.rows[word as usize]
            .take()
            .unwrap_or_else(|| HybridRow::new(k));
        let totals = &mut self.totals;
        let inv = &mut self.inv_denom;
        let sm = self.smoothing;
        row.for_each(|t, v| {
            let t = t as usize;
            totals[t] -= v as i64;
            inv[t] = inv_of(totals[t], sm);
        });
        row.clear();
        row
    }

    /// Fold the still-unflushed local deltas back into a freshly pulled
    /// row (so local moves aren't erased) and put it back.
    fn pull_finish(&mut self, word: u32, mut row: HybridRow) {
        if let Some(rec) = self.deltas.get(&word) {
            let totals = &mut self.totals;
            let inv = &mut self.inv_denom;
            let sm = self.smoothing;
            rec.for_each(|t, dv| {
                row.add(t as usize, dv);
                let t = t as usize;
                totals[t] += dv as i64;
                inv[t] = inv_of(totals[t], sm);
            });
        }
        self.rows[word as usize] = Some(row);
    }

    /// Absorb a pulled server row: replica := server + unflushed local
    /// deltas (so local moves aren't erased), aggregates and normalizers
    /// fixed up. The pending record is borrowed, never cloned.
    pub fn apply_pull(&mut self, word: u32, server_row: &[i32]) {
        assert_eq!(server_row.len(), self.k);
        let mut row = self.pull_begin(word);
        for (t, &v) in server_row.iter().enumerate() {
            if v != 0 {
                row.set(t, v);
                self.bump_total(t, v as i64);
            }
        }
        self.pull_finish(word, row);
    }

    /// [`CountMatrix::apply_pull`] for a wire row in either form, with no
    /// dense scratch: non-zero cells write straight into the hybrid row.
    /// A dense row wider than `K` is clamped; shorter is zero-padded; a
    /// sparse entry beyond `K` is a logic error and panics.
    pub fn apply_pull_row(&mut self, word: u32, server_row: &RowData) {
        let mut row = self.pull_begin(word);
        match server_row {
            RowData::Dense(r) => {
                let n = r.len().min(self.k);
                for (t, &v) in r[..n].iter().enumerate() {
                    if v != 0 {
                        row.set(t, v);
                        self.bump_total(t, v as i64);
                    }
                }
            }
            RowData::Sparse(es) => {
                for &(t, v) in es {
                    if v != 0 {
                        row.set(t as usize, v);
                        self.bump_total(t as usize, v as i64);
                    }
                }
            }
        }
        self.pull_finish(word, row);
    }

    /// Iterate allocated rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = (u32, &HybridRow)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(w, r)| r.as_ref().map(|r| (w as u32, r)))
    }

    /// Snapshot every non-empty replica row in wire form (the worker
    /// checkpoint's warm-resume payload).
    pub fn export_rows(&self) -> Vec<(u32, RowData)> {
        self.iter_rows()
            .filter(|(_, r)| r.nnz() > 0)
            .map(|(w, r)| (w, r.to_rowdata()))
            .collect()
    }

    /// Resident bytes held by allocated replica rows (excluding the
    /// row-pointer table and the delta log) — the bench memory panel's
    /// numerator; the dense era held `4·K` per touched word.
    pub fn resident_row_bytes(&self) -> usize {
        self.rows
            .iter()
            .flatten()
            .map(|r| r.resident_bytes())
            .sum()
    }

    /// Recompute per-topic aggregates from scratch (consistency repair /
    /// after bulk row replacement).
    pub fn rebuild_totals(&mut self) {
        let mut totals = vec![0i64; self.k];
        for row in self.rows.iter().flatten() {
            row.for_each(|t, c| totals[t as usize] += c as i64);
        }
        self.totals = totals;
        for t in 0..self.k {
            self.inv_denom[t] = inv_of(self.totals[t], self.smoothing);
        }
    }

    /// Average number of non-zero topics per allocated word row — the
    /// "average topics per word" panel of the paper's figures.
    pub fn avg_topics_per_word(&self) -> f64 {
        let mut words = 0u64;
        let mut nonzero = 0u64;
        for row in self.rows.iter().flatten() {
            words += 1;
            row.for_each(|_, c| {
                if c > 0 {
                    nonzero += 1;
                }
            });
        }
        if words == 0 {
            0.0
        } else {
            nonzero as f64 / words as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_and_totals() {
        let mut m = CountMatrix::new(10, 4);
        m.inc(3, 1, 2);
        m.inc(3, 2, 1);
        m.inc(7, 1, 1);
        assert_eq!(m.get(3, 1), 2);
        assert_eq!(m.get(3, 0), 0);
        assert_eq!(m.total(1), 3);
        assert_eq!(m.grand_total(), 4);
        assert!(m.row(0).is_none());
    }

    #[test]
    fn drain_deltas_batches_rows() {
        let mut m = CountMatrix::new(10, 3);
        m.inc(5, 0, 1);
        m.inc(5, 2, -1);
        m.inc(2, 1, 4);
        m.inc(9, 1, 1);
        m.inc(9, 1, -1); // cancels to zero → dropped
        let d = m.drain_deltas();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].0, 2);
        assert_eq!(&*d[0].1.to_dense(3), &[0, 4, 0]);
        assert_eq!(d[1].0, 5);
        assert_eq!(&*d[1].1.to_dense(3), &[1, 0, -1]);
        assert!(m.drain_deltas().is_empty());
        // Matrix content unaffected by draining.
        assert_eq!(m.get(5, 0), 1);
    }

    #[test]
    fn delta_log_spills_to_dense_and_back_to_sparse_wire() {
        let k = 64;
        let mut m = CountMatrix::new(4, k);
        // Touch more than k/4 = 16 distinct topics → record goes dense.
        for t in 0..20 {
            m.inc(1, t, 1);
        }
        let d = m.drain_deltas();
        assert_eq!(d.len(), 1);
        // 20 nnz at k=64: sparse wire (8·20 < 4·64).
        assert!(matches!(d[0].1, RowData::Sparse(_)));
        assert_eq!(d[0].1.nnz(), 20);
        let dense = d[0].1.to_dense(k);
        for t in 0..k {
            assert_eq!(dense[t], i32::from(t < 20));
        }
        // Nearly-full rows go dense on the wire.
        for t in 0..k {
            m.inc(2, t, 1);
        }
        let d = m.drain_deltas();
        assert!(matches!(d[0].1, RowData::Dense(_)));
    }

    #[test]
    fn apply_pull_preserves_unflushed_local_moves() {
        let mut m = CountMatrix::new(4, 2);
        m.inc(1, 0, 3); // unflushed local delta
        m.apply_pull(1, &[10, 5]); // server view
        assert_eq!(m.get(1, 0), 13); // server + pending local
        assert_eq!(m.get(1, 1), 5);
        assert_eq!(m.total(0), 13);
        assert_eq!(m.total(1), 5);

        // After flushing, a pull overwrites exactly.
        let _ = m.drain_deltas();
        m.apply_pull(1, &[20, 6]);
        assert_eq!(m.get(1, 0), 20);
        assert_eq!(m.total(0), 20);
    }

    #[test]
    fn apply_pull_row_sparse_equals_dense() {
        let k = 8;
        let mut a = CountMatrix::new(4, k);
        let mut b = CountMatrix::new(4, k);
        for m in [&mut a, &mut b] {
            m.inc(2, 1, 2);
            m.inc(2, 5, -1);
        }
        let server = [0, 7, 0, 0, 0, 3, 0, 0];
        a.apply_pull(2, &server);
        b.apply_pull_row(2, &RowData::from_dense_auto(&server));
        for t in 0..k {
            assert_eq!(a.get(2, t), b.get(2, t), "cell {t}");
        }
        assert_eq!(a.totals(), b.totals());
    }

    #[test]
    fn inv_denom_tracks_totals() {
        let mut m = CountMatrix::new(10, 3);
        m.set_smoothing(0.5);
        assert!((m.inv_denom(0) - 1.0 / 0.5).abs() < 1e-12);
        m.inc(1, 0, 4);
        assert!((m.inv_denom(0) - 1.0 / 4.5).abs() < 1e-12);
        m.inc(1, 0, -1);
        assert!((m.inv_denom(0) - 1.0 / 3.5).abs() < 1e-12);
        m.apply_pull(1, &[10, 0, 0]); // pending +3 → row = 13
        let _ = m.drain_deltas();
        m.apply_pull(1, &[10, 0, 0]); // flushed → row := 10
        assert!((m.inv_denom(0) - 1.0 / 10.5).abs() < 1e-12);
        // Negative transients clamp to the smoothing floor, like denom().
        m.inc_local(2, 1, -7);
        assert!((m.inv_denom(1) - 1.0 / 0.5).abs() < 1e-12);
        assert!((m.denom(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn requeue_folds_into_newer_deltas() {
        let mut m = CountMatrix::new(6, 4);
        m.inc(3, 1, 2);
        let drained = m.drain_deltas();
        assert_eq!(m.pending_rows(), 0);
        m.inc(3, 2, 5); // newer delta arrives before the requeue
        let (w, row) = drained.into_iter().next().unwrap();
        m.requeue_delta(w, row);
        assert_eq!(m.pending_rows(), 1);
        let d = m.drain_deltas();
        assert_eq!(&*d[0].1.to_dense(4), &[0, 2, 5, 0]);
    }

    /// The O(1) pending counter agrees with the scan it replaced across
    /// every mutation path: inc (including cancel-to-zero), drain,
    /// requeue, and the short→hash→dense promotions.
    #[test]
    fn pending_counter_matches_scan() {
        let mut m = CountMatrix::new(40, 16);
        let mut rng = crate::util::rng::Rng::new(11);
        for step in 0..2000 {
            let w = rng.below(40) as u32;
            let t = rng.below(16);
            let d = if rng.coin(0.5) { 1 } else { -1 };
            m.inc(w, t, d);
            if step % 97 == 0 {
                let drained = m.drain_deltas();
                assert_eq!(m.pending_rows(), 0, "drain must zero the counter");
                // Filter-retain path: requeue a few drained rows.
                for (w, row) in drained.into_iter().take(3) {
                    m.requeue_delta(w, row);
                }
            }
            assert_eq!(m.pending_rows(), m.pending_rows_scan(), "step {step}");
        }

        // Promote to dense, then cancel every cell back to zero: the
        // counter must follow the record through both transitions.
        let mut m = CountMatrix::new(4, 64);
        for t in 0..40 {
            m.inc(1, t, 1);
            assert_eq!(m.pending_rows(), m.pending_rows_scan());
        }
        assert_eq!(m.pending_rows(), 1);
        for t in 0..40 {
            m.inc(1, t, -1);
            assert_eq!(m.pending_rows(), m.pending_rows_scan());
        }
        assert_eq!(m.pending_rows(), 0, "dense record cancelled to empty");
    }

    #[test]
    fn rebuild_totals_matches_incremental() {
        let mut m = CountMatrix::new(20, 5);
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..500 {
            let w = rng.below(20) as u32;
            let t = rng.below(5);
            m.inc(w, t, 1);
        }
        let inc_totals = m.totals().to_vec();
        m.rebuild_totals();
        assert_eq!(m.totals(), &inc_totals[..]);
    }

    #[test]
    fn topics_per_word_counts_nonzero() {
        let mut m = CountMatrix::new(5, 4);
        m.inc(0, 0, 1);
        m.inc(0, 1, 1);
        m.inc(1, 2, 5);
        assert!((m.avg_topics_per_word() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rowdata_encode_roundtrip() {
        let rows: [&[i32]; 4] = [
            &[0, 0, 0, 0],
            &[1, 0, -2, 0],
            &[5, 5, 5, 5],
            &[0, 0, 0, 9],
        ];
        for r in rows {
            let enc = RowData::from_dense_auto(r);
            assert_eq!(&*enc.to_dense(r.len()), r);
            assert_eq!(enc.nnz(), r.iter().filter(|&&v| v != 0).count());
            assert_eq!(
                enc.l1(),
                r.iter().map(|&v| v.unsigned_abs() as u64).sum::<u64>()
            );
            for (t, &v) in r.iter().enumerate() {
                assert_eq!(enc.get(t), v);
            }
        }
    }

    #[test]
    fn rowdata_fold_saturating() {
        let mut row = vec![1i32, i32::MAX, 0];
        RowData::Sparse(vec![(0, 2), (1, 5)]).fold_saturating_into(&mut row);
        assert_eq!(row, vec![3, i32::MAX, 0]);
        RowData::Dense(vec![1, -1, 7].into_boxed_slice()).fold_saturating_into(&mut row);
        assert_eq!(row, vec![4, i32::MAX - 1, 7]);
    }

    // ---- HybridRow ----

    /// Random adds and sets against a dense oracle, across every
    /// promotion boundary, at a K small enough to skip the hash stage
    /// (8 ≥ K/4), a mid K, and a large sparse K.
    #[test]
    fn hybrid_row_matches_dense_oracle() {
        for &k in &[8usize, 64, 1000] {
            let mut row = HybridRow::new(k);
            let mut oracle = vec![0i32; k];
            let mut rng = crate::util::rng::Rng::new(42 + k as u64);
            for step in 0..4000 {
                let t = rng.below(k);
                if rng.coin(0.8) {
                    let d = if rng.coin(0.5) { 1 } else { -1 };
                    row.add(t, d);
                    oracle[t] += d;
                } else {
                    let v = rng.below(7) as i32 - 3;
                    row.set(t, v);
                    oracle[t] = v;
                }
                assert_eq!(row.get(t), oracle[t], "k={k} step={step}");
            }
            let nnz = oracle.iter().filter(|&&v| v != 0).count();
            assert_eq!(row.nnz(), nnz, "k={k}");
            assert_eq!(&*row.to_dense_box(), &oracle[..], "k={k}");
            let mut visited = vec![0i32; k];
            row.for_each(|t, v| {
                assert_ne!(v, 0);
                visited[t as usize] = v;
            });
            assert_eq!(visited, oracle, "for_each k={k}");
            assert_eq!(row, HybridRow::from_dense(&oracle), "eq k={k}");
        }
    }

    /// The representation ladder promotes at the documented thresholds:
    /// ≤8 entries short, ≤K/4 hash, dense past the cut — and `compact`
    /// walks back down after cancellation.
    #[test]
    fn hybrid_row_promotes_at_thresholds() {
        let k = 256; // dense_cut = 64
        let mut row = HybridRow::new(k);
        for t in 0..8 {
            row.add(t, 1);
        }
        assert_eq!(row.repr_kind(), RowReprKind::Short);
        row.add(8, 1);
        assert_eq!(row.repr_kind(), RowReprKind::Hash);
        for t in 9..=64 {
            row.add(t, 1);
        }
        assert_eq!(row.nnz(), 65);
        assert_eq!(row.repr_kind(), RowReprKind::Dense);
        assert_eq!(row.resident_bytes() - std::mem::size_of::<HybridRow>(), 4 * k);
        // Cancel back down; mutation never demotes, compact does.
        for t in 3..=64 {
            row.add(t, -1);
        }
        assert_eq!(row.repr_kind(), RowReprKind::Dense);
        row.compact();
        assert_eq!(row.repr_kind(), RowReprKind::Short);
        assert_eq!(row.nnz(), 3);
        assert_eq!(row, HybridRow::from_dense(&{
            let mut d = vec![0i32; k];
            d[0] = 1;
            d[1] = 1;
            d[2] = 1;
            d
        }));
    }

    /// At tiny K the short list promotes straight to dense (a hash
    /// table would cost more than the row).
    #[test]
    fn hybrid_row_skips_hash_stage_at_tiny_k() {
        let k = 16; // dense_cut = max(4, 8) = 8 ≤ SHORT_MAX
        let mut row = HybridRow::new(k);
        for t in 0..9 {
            row.add(t, 1);
        }
        assert_eq!(row.repr_kind(), RowReprKind::Dense);
        assert_eq!(row.nnz(), 9);
    }

    /// Wire encoding from a hybrid row is bit-identical to the dense
    /// era's `from_dense_auto` at every occupancy.
    #[test]
    fn hybrid_to_rowdata_matches_from_dense_auto() {
        let k = 96;
        let mut row = HybridRow::new(k);
        let mut dense = vec![0i32; k];
        let mut rng = crate::util::rng::Rng::new(7);
        for step in 0..600 {
            let t = rng.below(k);
            let d = if rng.coin(0.6) { 2 } else { -1 };
            row.add(t, d);
            dense[t] += d;
            if step % 13 == 0 {
                assert_eq!(row.to_rowdata(), RowData::from_dense_auto(&dense), "step {step}");
            }
        }
    }

    /// fold_rowdata (saturating) matches the slice-level
    /// `fold_saturating_into` the server used in the dense era.
    #[test]
    fn hybrid_fold_rowdata_matches_slice_fold() {
        let k = 32;
        let mut row = HybridRow::from_dense(&{
            let mut d = vec![0i32; k];
            d[1] = 5;
            d[7] = i32::MAX;
            d[20] = -3;
            d
        });
        let mut oracle = row.to_dense_box();
        for data in [
            RowData::Sparse(vec![(1, 2), (7, 9), (13, -4)]),
            RowData::Dense(vec![1i32; k].into_boxed_slice()),
        ] {
            data.fold_saturating_into(&mut oracle);
            row.fold_rowdata(&data);
            assert_eq!(&*row.to_dense_box(), &*oracle);
        }
    }

    /// clear() keeps capacity (the drain loop's steady state) and
    /// ensure_width widens dense rows losslessly.
    #[test]
    fn hybrid_clear_and_widen() {
        let k = 64;
        let mut row = HybridRow::new(k);
        for t in 0..20 {
            row.add(t, 1);
        }
        let bytes = row.resident_bytes();
        row.clear();
        assert_eq!(row.nnz(), 0);
        assert_eq!(row.resident_bytes(), bytes, "clear must keep capacity");
        row.add(3, 7);
        row.ensure_width(128);
        assert_eq!(row.k(), 128);
        assert_eq!(row.get(3), 7);
        assert_eq!(row.get(100), 0);
        // from_rowdata widens past the requested width when needed.
        let wide = HybridRow::from_rowdata(&RowData::Sparse(vec![(200, 4)]), 64);
        assert_eq!(wide.k(), 201);
        assert_eq!(wide.get(200), 4);
    }

    #[test]
    fn matrix_export_rows_roundtrip() {
        let mut m = CountMatrix::new(12, 48);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..400 {
            m.inc(rng.below(12) as u32, rng.below(48), 1);
        }
        let rows = m.export_rows();
        let mut m2 = CountMatrix::new(12, 48);
        for (w, data) in &rows {
            m2.apply_pull_row(*w, data);
        }
        for w in 0..12u32 {
            for t in 0..48 {
                assert_eq!(m.get(w, t), m2.get(w, t), "w={w} t={t}");
            }
        }
        assert_eq!(m.totals(), m2.totals());
    }
}
