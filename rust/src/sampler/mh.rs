//! Metropolis-Hastings correction for stale proposal distributions
//! (§3.2–§3.3).
//!
//! The alias table is built from a *stale* snapshot `q` of the true
//! conditional `p`; a draw `j ~ q` is accepted over the current state `i`
//! with probability `min(1, q(i)·p(j) / (q(j)·p(i)))` (eq. 7, stationary
//! proposal). With no valid current state the draw is accepted outright
//! ("stateless sampler" property).
//!
//! The chain length `n` trades bias for speed; the paper (and [10]) find
//! 1–2 steps sufficient because `q` tracks `p` closely between rebuilds.

use crate::util::rng::Rng;

/// One stationary-proposal MH decision. Returns the new state.
///
/// * `current` — current state (`None` ⇒ accept unconditionally).
/// * `proposal` — the drawn candidate `j` and its proposal mass `q(j)`.
/// * `q_of` / `p_of` — unnormalized proposal / target masses. Only the
///   *ratios* matter, so neither needs normalization (their normalizers
///   cancel in eq. 7).
#[inline]
pub fn mh_step(
    current: Option<usize>,
    proposal: (usize, f64),
    q_of: impl Fn(usize) -> f64,
    p_of: impl Fn(usize) -> f64,
    rng: &mut Rng,
) -> (usize, bool) {
    let (j, qj) = proposal;
    let i = match current {
        None => return (j, true),
        Some(i) => i,
    };
    if i == j {
        return (j, true);
    }
    let pi = p_of(i);
    let pj = p_of(j);
    let qi = q_of(i);
    // Degenerate guards: relaxed consistency can transiently zero things.
    if pi <= 0.0 || qj <= 0.0 {
        return (j, true);
    }
    let ratio = (qi * pj) / (qj * pi);
    if ratio >= 1.0 || rng.f64() < ratio {
        (j, true)
    } else {
        (i, false)
    }
}

/// A short MH chain: draw `steps` proposals from `propose`, walking the
/// state through [`mh_step`]. Returns `(final_state, acceptances)`.
pub fn mh_chain(
    init: Option<usize>,
    steps: usize,
    mut propose: impl FnMut(&mut Rng) -> (usize, f64),
    q_of: impl Fn(usize) -> f64,
    p_of: impl Fn(usize) -> f64,
    rng: &mut Rng,
) -> (usize, usize) {
    let mut state = init;
    let mut accepted = 0usize;
    for _ in 0..steps.max(1) {
        let prop = propose(rng);
        let (next, acc) = mh_step(state, prop, &q_of, &p_of, rng);
        if acc {
            accepted += 1;
        }
        state = Some(next);
    }
    (state.unwrap(), accepted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::alias::AliasTable;

    /// With proposal == target the acceptance rate must be 1 and the
    /// empirical distribution must match the target.
    #[test]
    fn exact_proposal_always_accepts() {
        let p = [0.5, 0.2, 0.3];
        let table = AliasTable::build(&p);
        let mut rng = Rng::new(1);
        let mut counts = [0u64; 3];
        let mut acc = 0usize;
        let mut state = None;
        for _ in 0..60_000 {
            let (s, a) = mh_chain(
                state,
                1,
                |r| {
                    let j = table.sample(r);
                    (j, p[j])
                },
                |i| p[i],
                |i| p[i],
                &mut rng,
            );
            state = Some(s);
            counts[s] += 1;
            acc += a;
        }
        assert_eq!(acc, 60_000, "identical p,q must always accept");
        for (i, &c) in counts.iter().enumerate() {
            let e = p[i] * 60_000.0;
            assert!((c as f64 - e).abs() < 6.0 * e.sqrt(), "bin {i}: {c} vs {e}");
        }
    }

    /// A *stale* proposal must still converge to the true target thanks to
    /// the MH correction — the core claim of §3.3.
    #[test]
    fn stale_proposal_corrected_to_target() {
        // Target strongly favors outcome 0; stale proposal is uniform.
        let p = [0.7, 0.1, 0.1, 0.1];
        let q = [0.25, 0.25, 0.25, 0.25];
        let table = AliasTable::build(&q);
        let mut rng = Rng::new(2);
        let mut counts = [0u64; 4];
        let mut state = None;
        let n = 200_000;
        for _ in 0..n {
            // 4 MH steps per emitted sample to burn in the stale chain.
            let (s, _) = mh_chain(
                state,
                4,
                |r| {
                    let j = table.sample(r);
                    (j, q[j])
                },
                |i| q[i],
                |i| p[i],
                &mut rng,
            );
            state = Some(s);
            counts[s] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let e = p[i] * n as f64;
            assert!(
                (c as f64 - e).abs() < 0.05 * n as f64,
                "bin {i}: got {c}, want ≈{e}"
            );
        }
    }

    #[test]
    fn stateless_first_draw_accepts() {
        let mut rng = Rng::new(3);
        let (s, acc) = mh_step(None, (2, 0.1), |_| 0.0, |_| 0.0, &mut rng);
        assert_eq!(s, 2);
        assert!(acc);
    }

    #[test]
    fn zero_target_current_state_escapes() {
        // If relaxed consistency zeroed p(current), any proposal is taken.
        let mut rng = Rng::new(4);
        let (s, acc) = mh_step(Some(0), (1, 0.5), |_| 0.5, |i| if i == 0 { 0.0 } else { 1.0 }, &mut rng);
        assert_eq!(s, 1);
        assert!(acc);
    }
}
