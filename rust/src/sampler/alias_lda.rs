//! AliasLDA: the Metropolis-Hastings-Walker sampler of §2.1/§3.
//!
//! Eq. (4) splits the LDA conditional into
//!
//! ```text
//! p(z=t|rest) ∝ n_td·(n_tw+β)/(n_t+β̄)     — sparse, k_d terms, kept EXACT
//!            + α·(n_tw+β)/(n_t+β̄)         — dense, approximated by a
//!                                            STALE alias table per word
//! ```
//!
//! Each draw: a biased coin picks the sparse component (`O(k_d)` exact
//! categorical) or the stale dense component (`O(1)` alias draw); a
//! Metropolis-Hastings accept/reject against the *true* conditional
//! corrects the staleness (eq. 7). The per-word alias table is rebuilt
//! after `K` draws — amortizing its `O(K)` build to `O(1)` per token — or
//! immediately after a parameter-server sync rewrites the word's row
//! (§3.3: "whenever we receive a global parameter update ... recompute the
//! proposal distribution").

use super::alias::{AliasBuilder, AliasTable};
use super::counts::CountMatrix;
use super::doc_state::DocState;
use super::mh::mh_chain;
use super::DocSampler;
use crate::corpus::doc::Document;
use crate::util::rng::Rng;

/// Stale per-word dense proposal: alias table + the weights it was built
/// from (needed to evaluate `q(i)` in the MH ratio) + a rebuild budget.
/// Allocated once per word, then rebuilt **in place** (table, `qw`, and
/// the shared [`AliasBuilder`] scratch are all reused), so steady-state
/// rebuilds are allocation-free.
struct WordProposal {
    table: AliasTable,
    /// Stale dense weights q_w(t) = α·(n_tw+β)/(n_t+β̄).
    qw: Box<[f64]>,
    /// Σ_t qw(t).
    qsum: f64,
    /// Draws remaining before a rebuild (0 ⇒ stale, rebuild before use).
    budget: u32,
}

impl WordProposal {
    fn empty(len: usize) -> WordProposal {
        WordProposal {
            table: AliasTable::empty(),
            qw: vec![0.0; len].into_boxed_slice(),
            qsum: 0.0,
            budget: 0,
        }
    }
}

/// The AliasLDA sampler.
pub struct AliasLda {
    k: usize,
    alpha: f64,
    beta: f64,
    beta_bar: f64,
    /// MH chain length per token (1–2 suffice; see §3.3).
    pub mh_steps: usize,
    /// Shard documents.
    pub docs: Vec<Document>,
    /// Latent state.
    pub state: DocState,
    /// Shared word-topic counts (replica synced via the parameter server).
    pub nwt: CountMatrix,
    proposals: Vec<Option<WordProposal>>,
    alias_builder: AliasBuilder,
    /// Diagnostics: MH proposals / acceptances since construction.
    pub mh_proposed: u64,
    /// Diagnostics: accepted MH moves.
    pub mh_accepted: u64,
    /// Scratch buffers (avoid per-token allocation on the hot path).
    scratch_topics: Vec<u32>,
    scratch_weights: Vec<f64>,
}

impl AliasLda {
    /// Create with random topic initialization.
    pub fn new(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        rng: &mut Rng,
    ) -> Self {
        Self::new_with_init(docs, vocab, k, alpha, beta, None, rng)
    }

    /// Create, taking topic assignments from `init` where provided
    /// (client failover restores from a snapshot this way, §5.4).
    pub fn new_with_init(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        init: Option<&[Vec<u32>]>,
        rng: &mut Rng,
    ) -> Self {
        let mut s = AliasLda {
            k,
            alpha,
            beta,
            beta_bar: beta * vocab as f64,
            mh_steps: 2,
            state: DocState::new(docs.len()),
            nwt: CountMatrix::new(vocab, k),
            proposals: (0..vocab).map(|_| None).collect(),
            alias_builder: AliasBuilder::new(),
            mh_proposed: 0,
            mh_accepted: 0,
            scratch_topics: Vec::with_capacity(64),
            scratch_weights: Vec::with_capacity(64),
            docs,
        };
        s.nwt.set_smoothing(s.beta_bar);
        // Iterate the documents out-of-body so the init pass can mutate
        // the statistics without cloning every token vector.
        let docs = std::mem::take(&mut s.docs);
        for (d, doc) in docs.iter().enumerate() {
            s.state.z[d] = doc
                .tokens
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    let t = init
                        .and_then(|z| z.get(d).and_then(|zd| zd.get(i)).copied())
                        .filter(|&t| (t as usize) < k)
                        .unwrap_or_else(|| rng.below(k) as u32);
                    s.state.n_dt[d].inc(t);
                    s.nwt.inc(w, t as usize, 1);
                    t
                })
                .collect();
        }
        s.docs = docs;
        s
    }

    #[inline]
    fn denom(&self, t: usize) -> f64 {
        (self.nwt.total(t) as f64).max(0.0) + self.beta_bar
    }

    /// Build (or rebuild) the stale dense proposal for word `w` from the
    /// *current* replica. `O(K)`, allocation-free after the word's first
    /// build (buffers are pooled and rebuilt in place).
    fn rebuild_proposal(&mut self, w: u32) {
        let mut p = self.proposals[w as usize]
            .take()
            .unwrap_or_else(|| WordProposal::empty(self.k));
        // Baseline (zero-count) weight per topic, then patch the word's
        // non-zero cells — O(K + nnz) instead of a row `get` per topic,
        // and no dense ghost row is ever materialized.
        let mut qsum = 0.0;
        for t in 0..self.k {
            let v = self.alpha * self.beta * self.nwt.inv_denom(t);
            p.qw[t] = v;
            qsum += v;
        }
        if let Some(row) = self.nwt.row(w) {
            let nwt_m = &self.nwt;
            let (alpha, beta) = (self.alpha, self.beta);
            row.for_each(|t, c| {
                let t = t as usize;
                let v = alpha * ((c.max(0) as f64) + beta) * nwt_m.inv_denom(t);
                qsum += v - p.qw[t];
                p.qw[t] = v;
            });
        }
        p.qsum = qsum;
        self.alias_builder.build_into(&mut p.table, &p.qw);
        // Amortize the O(K) build over K draws → O(1) per draw.
        p.budget = self.k as u32;
        self.proposals[w as usize] = Some(p);
    }

    /// Mark the stale proposal for `w` for rebuild — called by the sync
    /// layer after a pull rewrites the row (§3.3). Buffers are kept for
    /// the rebuild.
    pub fn invalidate_word(&mut self, w: u32) {
        if let Some(p) = self.proposals[w as usize].as_mut() {
            p.budget = 0;
        }
    }

    /// Mark all stale proposals for rebuild (bulk sync).
    pub fn invalidate_all(&mut self) {
        for p in self.proposals.iter_mut().flatten() {
            p.budget = 0;
        }
    }

    /// Observed MH acceptance rate (diagnostics; ≈1 when proposals fresh).
    pub fn acceptance_rate(&self) -> f64 {
        if self.mh_proposed == 0 {
            1.0
        } else {
            self.mh_accepted as f64 / self.mh_proposed as f64
        }
    }

    fn sample_token(&mut self, d: usize, i: usize, rng: &mut Rng) -> (u32, usize) {
        let w = self.docs[d].tokens[i];
        let old = self.state.z[d][i];

        // Remove the token.
        self.state.n_dt[d].dec(old);
        self.nwt.inc(w, old as usize, -1);

        // Ensure a live proposal table, consuming budget.
        let need_rebuild = match &self.proposals[w as usize] {
            Some(p) => p.budget == 0,
            None => true,
        };
        if need_rebuild {
            self.rebuild_proposal(w);
        }

        // Sparse component: exact, recomputed fresh each token. The word
        // row is borrowed ONCE per token — `get` per topic would re-deref
        // the row Option every call (§Perf: +25% at K=1600) — and the
        // denominator comes from the incremental 1/(n_t+β̄) cache, so the
        // inner loop multiplies instead of divides.
        self.scratch_topics.clear();
        self.scratch_weights.clear();
        let mut sparse_sum = 0.0;
        let wrow = self.nwt.row(w);
        for (t, c) in self.state.n_dt[d].iter() {
            let nwt = wrow.map_or(0, |r| r.get(t as usize)).max(0) as f64;
            let wgt = c as f64 * (nwt + self.beta) * self.nwt.inv_denom(t as usize);
            self.scratch_topics.push(t);
            self.scratch_weights.push(wgt);
            sparse_sum += wgt;
        }
        let qsum = self.proposals[w as usize].as_ref().unwrap().qsum;
        let total = sparse_sum + qsum;

        // Mixture proposal: q(t) = [sparse_exact(t) + stale_dense(t)] / total.
        let sparse_topics = &self.scratch_topics;
        let sparse_weights = &self.scratch_weights;
        let proposals = &self.proposals;
        let state = &self.state;
        let nwt_m = &self.nwt;
        let alpha = self.alpha;
        let beta = self.beta;
        let q_of = |t: usize| {
            let ndt = state.n_dt[d].get(t as u32) as f64;
            let nwt = wrow.map_or(0, |r| r.get(t)).max(0) as f64;
            let sparse = ndt * (nwt + beta) * nwt_m.inv_denom(t);
            sparse + proposals[w as usize].as_ref().map_or(0.0, |p| p.qw[t])
        };
        let p_of = |t: usize| {
            let ndt = state.n_dt[d].get(t as u32) as f64;
            let nwt = wrow.map_or(0, |r| r.get(t)).max(0) as f64;
            (ndt + alpha) * (nwt + beta) * nwt_m.inv_denom(t)
        };

        let mut draws = 0u32;
        let propose = |r: &mut Rng| {
            // Biased coin between sparse-exact and stale-dense (§2.1).
            if total > 0.0 && r.f64() * total < sparse_sum {
                // O(k_d) categorical over the sparse component.
                let mut u = r.f64() * sparse_sum;
                let mut idx = sparse_topics.len().saturating_sub(1);
                for (j, &wgt) in sparse_weights.iter().enumerate() {
                    u -= wgt;
                    if u <= 0.0 {
                        idx = j;
                        break;
                    }
                }
                let t = sparse_topics.get(idx).copied().unwrap_or(0) as usize;
                (t, q_of(t))
            } else {
                // O(1) alias draw from the stale dense component.
                let p = proposals[w as usize].as_ref().unwrap();
                let t = p.table.sample(r);
                draws += 1;
                (t, q_of(t))
            }
        };

        let (new_t, accepted) = mh_chain(Some(old as usize), self.mh_steps, propose, q_of, p_of, rng);
        self.mh_proposed += self.mh_steps as u64;
        self.mh_accepted += accepted as u64;

        // Consume alias budget for the draws actually taken from the table.
        if draws > 0 {
            if let Some(p) = self.proposals[w as usize].as_mut() {
                p.budget = p.budget.saturating_sub(draws);
            }
        }

        // Re-add the token.
        let new_t32 = new_t as u32;
        self.state.z[d][i] = new_t32;
        self.state.n_dt[d].inc(new_t32);
        self.nwt.inc(w, new_t, 1);
        (new_t32, accepted)
    }
}

impl crate::eval::perplexity::TopicModelView for AliasLda {
    fn k(&self) -> usize {
        self.k
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        (self.nwt.get(w, t).max(0) as f64 + self.beta) / self.denom(t)
    }
    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
}

impl DocSampler for AliasLda {
    fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize {
        let n = self.docs[d].tokens.len();
        let mut accepted = 0usize;
        for i in 0..n {
            accepted += self.sample_token(d, i, rng).1;
        }
        accepted
    }

    fn num_topics(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "AliasLDA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::CorpusConfig;

    fn make(n_docs: usize, k: usize, seed: u64) -> (AliasLda, Rng) {
        let (c, _) = CorpusConfig {
            n_docs,
            vocab_size: 300,
            n_topics: k,
            doc_len_mean: 25.0,
            seed,
            ..Default::default()
        }
        .generate();
        let mut rng = Rng::new(seed ^ 0xABCD);
        let s = AliasLda::new(c.docs, 300, k, 0.1, 0.01, &mut rng);
        (s, rng)
    }

    fn check_invariants(s: &AliasLda) {
        let mut recount = CountMatrix::new(s.nwt.vocab(), s.k);
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                recount.inc_local(w, s.state.z[d][i] as usize, 1);
            }
            assert_eq!(s.state.n_dt[d].total() as usize, doc.tokens.len());
        }
        for w in 0..s.nwt.vocab() as u32 {
            for t in 0..s.k {
                assert_eq!(s.nwt.get(w, t), recount.get(w, t), "nwt[{w},{t}]");
            }
        }
        assert_eq!(s.nwt.totals(), recount.totals());
    }

    #[test]
    fn counts_consistent_after_sweeps() {
        let (mut s, mut rng) = make(40, 8, 1);
        for _ in 0..3 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        check_invariants(&s);
    }

    #[test]
    fn acceptance_rate_is_high() {
        let (mut s, mut rng) = make(80, 10, 2);
        for _ in 0..3 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let rate = s.acceptance_rate();
        assert!(rate > 0.8, "MH acceptance rate {rate} suspiciously low");
    }

    #[test]
    fn training_improves_likelihood() {
        let (mut s, mut rng) = make(150, 10, 3);
        let ll0 = joint_ll(&s);
        for _ in 0..15 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let ll1 = joint_ll(&s);
        assert!(ll1 > ll0 + 100.0, "ll {ll0} -> {ll1}");
    }

    #[test]
    fn invalidation_is_safe_mid_training() {
        let (mut s, mut rng) = make(40, 8, 4);
        for sweep in 0..4 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
            if sweep % 2 == 0 {
                s.invalidate_all();
            }
        }
        check_invariants(&s);
    }

    /// AliasLDA and SparseLDA sample the *same* posterior: after enough
    /// sweeps on the same corpus their joint likelihoods should land in the
    /// same range.
    #[test]
    fn agrees_with_sparse_lda_posterior() {
        let (corpus, _) = CorpusConfig {
            n_docs: 120,
            vocab_size: 250,
            n_topics: 8,
            doc_len_mean: 30.0,
            seed: 9,
            ..Default::default()
        }
        .generate();
        let mut rng1 = Rng::new(100);
        let mut rng2 = Rng::new(200);
        let mut a = AliasLda::new(corpus.docs.clone(), 250, 8, 0.1, 0.01, &mut rng1);
        let mut y =
            crate::sampler::sparse_lda::SparseLda::new(corpus.docs, 250, 8, 0.1, 0.01, &mut rng2);
        for _ in 0..25 {
            for d in 0..a.docs.len() {
                a.sample_doc(d, &mut rng1);
                y.sample_doc(d, &mut rng2);
            }
        }
        let lla = joint_ll(&a);
        let lly = joint_ll_sparse(&y);
        let rel = (lla - lly).abs() / lly.abs();
        assert!(rel < 0.05, "posterior mismatch: alias {lla} vs sparse {lly}");
    }

    fn joint_ll(s: &AliasLda) -> f64 {
        let mut ll = 0.0;
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                let t = s.state.z[d][i] as usize;
                let phi = (s.nwt.get(w, t) as f64 + s.beta)
                    / (s.nwt.total(t) as f64 + s.beta_bar);
                ll += phi.max(1e-300).ln();
            }
        }
        ll
    }

    fn joint_ll_sparse(s: &crate::sampler::sparse_lda::SparseLda) -> f64 {
        let mut ll = 0.0;
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                let t = s.state.z[d][i] as usize;
                let phi = (s.nwt.get(w, t) as f64 + 0.01)
                    / (s.nwt.total(t) as f64 + 0.01 * 250.0);
                ll += phi.max(1e-300).ln();
            }
        }
        ll
    }
}
