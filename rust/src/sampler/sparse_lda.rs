//! The YahooLDA baseline: Yao–Mimno–McCallum sparse collapsed Gibbs
//! sampling [22], re-implemented on the same parameter server — exactly the
//! comparator the paper uses ("YahooLDA is a re-implementation of [1] in
//! the new parameter server architecture ... for a fair comparison", §6).
//!
//! The conditional (3) is decomposed into three buckets:
//!
//! ```text
//! p(z=t|rest) ∝ αβ/(n_t+β̄)            — s: smoothing-only   (dense, cached)
//!            + n_td·β/(n_t+β̄)          — r: document bucket  (k_d-sparse)
//!            + (α+n_td)·n_tw/(n_t+β̄)   — q: word bucket      (k_w-sparse)
//! ```
//!
//! Per-token cost is `O(k_d + k_w)`. The paper's point: at industrial scale
//! `n_tw` densifies (`k_w → K`), so this sampler's time grows with
//! topics-per-word while AliasLDA stays flat — the crossover Fig 4 shows.

use super::counts::CountMatrix;
use super::doc_state::{DocState, SparseCounts};
use super::DocSampler;
use crate::corpus::doc::Document;
use crate::util::rng::Rng;

/// Sparse collapsed Gibbs sampler for LDA.
pub struct SparseLda {
    k: usize,
    alpha: f64,
    beta: f64,
    beta_bar: f64,
    /// Shard documents.
    pub docs: Vec<Document>,
    /// Latent state.
    pub state: DocState,
    /// Shared word-topic counts (replica synced via the parameter server).
    pub nwt: CountMatrix,
    /// Sparse mirror of the non-zero topics per word (what makes the word
    /// bucket `k_w`-sparse instead of `O(K)` over the dense replica rows).
    word_topics: Vec<SparseCounts>,
    /// Cached smoothing bucket Σ_t αβ/(n_t+β̄), adjusted incrementally on
    /// every token move via the replica's 1/(n_t+β̄) cache; a full O(K)
    /// recompute only happens after a sync rewrites rows.
    s_cache: f64,
    s_dirty: bool,
}

impl SparseLda {
    /// Create with random topic initialization.
    pub fn new(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        rng: &mut Rng,
    ) -> Self {
        Self::new_with_init(docs, vocab, k, alpha, beta, None, rng)
    }

    /// Create, taking topic assignments from `init` where provided
    /// (client failover restores from a snapshot this way, §5.4).
    pub fn new_with_init(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        init: Option<&[Vec<u32>]>,
        rng: &mut Rng,
    ) -> Self {
        let mut s = SparseLda {
            k,
            alpha,
            beta,
            beta_bar: beta * vocab as f64,
            state: DocState::new(docs.len()),
            nwt: CountMatrix::new(vocab, k),
            word_topics: vec![SparseCounts::new(); vocab],
            s_cache: 0.0,
            s_dirty: true,
            docs,
        };
        s.nwt.set_smoothing(s.beta_bar);
        // Iterate the documents out-of-body so the init pass can mutate
        // the statistics without cloning every token vector.
        let docs = std::mem::take(&mut s.docs);
        for (d, doc) in docs.iter().enumerate() {
            s.state.z[d] = doc
                .tokens
                .iter()
                .enumerate()
                .map(|(i, &w)| {
                    let t = init
                        .and_then(|z| z.get(d).and_then(|zd| zd.get(i)).copied())
                        .filter(|&t| (t as usize) < k)
                        .unwrap_or_else(|| rng.below(k) as u32);
                    s.state.n_dt[d].inc(t);
                    s.nwt.inc(w, t as usize, 1);
                    s.word_topics[w as usize].inc(t);
                    t
                })
                .collect();
        }
        s.docs = docs;
        s
    }

    #[inline]
    fn denom(&self, t: usize) -> f64 {
        (self.nwt.total(t) as f64).max(0.0) + self.beta_bar
    }

    /// The word bucket mirror must be refreshed when a pull rewrites a row.
    pub fn refresh_word(&mut self, w: u32) {
        let mut sc = SparseCounts::new();
        if let Some(row) = self.nwt.row(w) {
            row.for_each(|t, c| {
                if c > 0 {
                    sc.set_raw(t, c as u32);
                }
            });
        }
        self.word_topics[w as usize] = sc;
        self.s_dirty = true;
    }

    /// Invalidate all caches (after a bulk sync).
    pub fn invalidate_all(&mut self) {
        for w in 0..self.word_topics.len() {
            self.refresh_word(w as u32);
        }
    }

    fn smoothing_bucket(&mut self) -> f64 {
        if self.s_dirty {
            self.s_cache = (0..self.k)
                .map(|t| self.alpha * self.beta * self.nwt.inv_denom(t))
                .sum();
            self.s_dirty = false;
        }
        self.s_cache
    }

    /// Resample one token; returns its new topic.
    fn sample_token(&mut self, d: usize, i: usize, rng: &mut Rng) -> u32 {
        let w = self.docs[d].tokens[i];
        let old = self.state.z[d][i];

        // Remove the token from all statistics. The smoothing bucket only
        // depends on the one denominator that changed, so it is adjusted
        // incrementally (O(1)) instead of being marked stale (O(K)).
        self.state.n_dt[d].dec(old);
        let inv_before = self.nwt.inv_denom(old as usize);
        self.nwt.inc(w, old as usize, -1);
        if !self.s_dirty {
            self.s_cache +=
                self.alpha * self.beta * (self.nwt.inv_denom(old as usize) - inv_before);
        }
        self.word_topics[w as usize].dec_clamped(old);

        // r bucket: Σ over non-zero n_dt (multiplying by the cached
        // 1/(n_t+β̄) — no division in the per-token loops).
        let mut r_sum = 0.0;
        for (t, c) in self.state.n_dt[d].iter() {
            r_sum += c as f64 * self.beta * self.nwt.inv_denom(t as usize);
        }
        // q bucket: Σ over non-zero n_tw.
        let mut q_sum = 0.0;
        for (t, c) in self.word_topics[w as usize].iter() {
            let ndt = self.state.n_dt[d].get(t) as f64;
            q_sum += (self.alpha + ndt) * c as f64 * self.nwt.inv_denom(t as usize);
        }
        let s_sum = self.smoothing_bucket();

        let total = s_sum + r_sum + q_sum;
        let mut u = rng.f64() * total;
        let new_t;
        if u < q_sum {
            // word bucket
            let mut acc = 0.0;
            let mut chosen = None;
            for (t, c) in self.word_topics[w as usize].iter() {
                let ndt = self.state.n_dt[d].get(t) as f64;
                acc += (self.alpha + ndt) * c as f64 * self.nwt.inv_denom(t as usize);
                if acc >= u {
                    chosen = Some(t);
                    break;
                }
            }
            new_t = chosen.unwrap_or_else(|| {
                self.word_topics[w as usize]
                    .iter()
                    .last()
                    .map(|(t, _)| t)
                    .unwrap_or(0)
            });
        } else {
            u -= q_sum;
            if u < r_sum {
                // document bucket
                let mut acc = 0.0;
                let mut chosen = None;
                for (t, c) in self.state.n_dt[d].iter() {
                    acc += c as f64 * self.beta * self.nwt.inv_denom(t as usize);
                    if acc >= u {
                        chosen = Some(t);
                        break;
                    }
                }
                new_t = chosen
                    .unwrap_or_else(|| self.state.n_dt[d].iter().last().map(|(t, _)| t).unwrap_or(0));
            } else {
                // smoothing bucket: O(K) scan, hit with small probability
                u -= r_sum;
                let mut acc = 0.0;
                let mut chosen = self.k - 1;
                for t in 0..self.k {
                    acc += self.alpha * self.beta * self.nwt.inv_denom(t);
                    if acc >= u {
                        chosen = t;
                        break;
                    }
                }
                new_t = chosen as u32;
            }
        }

        // Add the token back under the new topic (same incremental
        // smoothing-bucket adjustment as the removal).
        self.state.z[d][i] = new_t;
        self.state.n_dt[d].inc(new_t);
        let inv_before = self.nwt.inv_denom(new_t as usize);
        self.nwt.inc(w, new_t as usize, 1);
        if !self.s_dirty {
            self.s_cache +=
                self.alpha * self.beta * (self.nwt.inv_denom(new_t as usize) - inv_before);
        }
        self.word_topics[w as usize].inc(new_t);
        new_t
    }
}

impl crate::eval::perplexity::TopicModelView for SparseLda {
    fn k(&self) -> usize {
        self.k
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        (self.nwt.get(w, t).max(0) as f64 + self.beta) / self.denom(t)
    }
    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
}

impl DocSampler for SparseLda {
    fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize {
        let n = self.docs[d].tokens.len();
        for i in 0..n {
            self.sample_token(d, i, rng);
        }
        n // exact Gibbs: every move "accepted"
    }

    fn num_topics(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "YahooLDA(sparse)"
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::CorpusConfig;

    fn make(n_docs: usize, k: usize) -> (SparseLda, Rng) {
        let (c, _) = CorpusConfig {
            n_docs,
            vocab_size: 300,
            n_topics: k,
            doc_len_mean: 25.0,
            ..Default::default()
        }
        .generate();
        let mut rng = Rng::new(7);
        let s = SparseLda::new(c.docs, 300, k, 0.1, 0.01, &mut rng);
        (s, rng)
    }

    /// Invariant: counts always match a from-scratch recount.
    fn check_invariants(s: &SparseLda) {
        let mut recount = CountMatrix::new(s.nwt.vocab(), s.k);
        for (d, doc) in s.docs.iter().enumerate() {
            assert_eq!(doc.tokens.len(), s.state.z[d].len());
            for (i, &w) in doc.tokens.iter().enumerate() {
                recount.inc_local(w, s.state.z[d][i] as usize, 1);
            }
            assert_eq!(s.state.n_dt[d].total() as usize, doc.tokens.len());
        }
        for w in 0..s.nwt.vocab() as u32 {
            for t in 0..s.k {
                assert_eq!(
                    s.nwt.get(w, t),
                    recount.get(w, t),
                    "nwt[{w},{t}] drifted"
                );
                let mirror = s.word_topics[w as usize].get(t as u32);
                assert_eq!(mirror as i32, recount.get(w, t).max(0), "mirror[{w},{t}]");
            }
        }
        assert_eq!(s.nwt.totals(), recount.totals());
    }

    #[test]
    fn init_consistent() {
        let (s, _) = make(40, 8);
        check_invariants(&s);
    }

    #[test]
    fn counts_stay_consistent_over_sweeps() {
        let (mut s, mut rng) = make(40, 8);
        for _ in 0..3 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        check_invariants(&s);
    }

    #[test]
    fn training_improves_likelihood() {
        // Joint log-likelihood proxy: Σ log p(w|z) must improve from the
        // random initialization after a few sweeps.
        let (mut s, mut rng) = make(150, 10);
        let ll0 = joint_ll(&s);
        for _ in 0..15 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let ll1 = joint_ll(&s);
        assert!(ll1 > ll0 + 100.0, "ll {ll0} -> {ll1} did not improve");
    }

    fn joint_ll(s: &SparseLda) -> f64 {
        let mut ll = 0.0;
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                let t = s.state.z[d][i] as usize;
                let phi = (s.nwt.get(w, t) as f64 + s.beta)
                    / (s.nwt.total(t) as f64 + s.beta_bar);
                ll += phi.max(1e-300).ln();
            }
        }
        ll
    }
}
