//! Generalized Stirling numbers for Pitman-Yor table arithmetic (§2.2).
//!
//! The PDP conditionals (eqs. 5–6) need ratios of generalized Stirling
//! numbers `S^N_{M,a}` obeying
//!
//! ```text
//! S^{N+1}_{M,a} = S^N_{M-1,a} + (N − M·a)·S^N_{M,a},   S^N_{M,a}=0 for M>N,
//! S^0_{0,a}=1 (δ_{N,0} for M=0)
//! ```
//!
//! The values overflow `f64` around `N≈170`, so the table stores
//! `log S^N_{M,a}` and the samplers consume **ratios** (differences of
//! logs), which is all eqs. (5)/(6) require. The table grows on demand and
//! is memoized per discount `a`.

/// Log-space triangular table of generalized Stirling numbers for one
/// fixed discount `a`.
#[derive(Clone, Debug)]
pub struct StirlingTable {
    a: f64,
    /// `log_s[n][m]` = log S^n_{m,a}, for 0 ≤ m ≤ n; −∞ encodes zero.
    log_s: Vec<Vec<f64>>,
}

impl StirlingTable {
    /// New table for discount `a ∈ [0, 1)`, pre-grown to `n_init`.
    pub fn new(a: f64, n_init: usize) -> Self {
        assert!((0.0..1.0).contains(&a), "discount must be in [0,1)");
        let mut t = StirlingTable {
            a,
            log_s: vec![vec![0.0]], // S^0_0 = 1 → log 1 = 0
        };
        t.grow_to(n_init);
        t
    }

    /// Discount parameter.
    pub fn discount(&self) -> f64 {
        self.a
    }

    /// Largest `N` currently tabulated.
    pub fn max_n(&self) -> usize {
        self.log_s.len() - 1
    }

    /// Extend the table so `log_s(n, ·)` is available.
    pub fn grow_to(&mut self, n: usize) {
        while self.log_s.len() <= n {
            let prev_n = self.log_s.len() - 1;
            let prev = &self.log_s[prev_n];
            let mut row = vec![f64::NEG_INFINITY; prev_n + 2];
            // m ranges 0..=prev_n+1 for S^{prev_n+1}_m.
            // m = 0: S^{N}_0 = δ_{N,0} → zero for N ≥ 1.
            for m in 1..=prev_n + 1 {
                let from_m_minus_1 = if m - 1 < prev.len() {
                    prev[m - 1]
                } else {
                    f64::NEG_INFINITY
                };
                let coeff = prev_n as f64 - m as f64 * self.a;
                let from_m = if m < prev.len() && coeff > 0.0 {
                    prev[m] + coeff.ln()
                } else {
                    f64::NEG_INFINITY
                };
                row[m] = log_add(from_m_minus_1, from_m);
            }
            self.log_s.push(row);
        }
    }

    /// `log S^n_{m,a}` (−∞ for impossible configurations).
    pub fn log(&mut self, n: usize, m: usize) -> f64 {
        if m > n {
            return f64::NEG_INFINITY;
        }
        self.grow_to(n);
        self.log_s[n][m]
    }

    /// Read-only `log S^n_{m,a}`. `n` must be within the grown range
    /// (callers clamp; see `AliasPdp::stir`).
    #[inline]
    pub fn log_ro(&self, n: usize, m: usize) -> f64 {
        if m > n {
            return f64::NEG_INFINITY;
        }
        self.log_s[n][m]
    }

    /// The ratio `S^{n+1}_{m,a} / S^n_{m,a}` used by eq. (5)
    /// (same table count, one more customer).
    pub fn ratio_same_tables(&mut self, n: usize, m: usize) -> f64 {
        let num = self.log(n + 1, m);
        let den = self.log(n, m);
        if den == f64::NEG_INFINITY {
            return 0.0;
        }
        (num - den).exp()
    }

    /// The ratio `S^{n+1}_{m+1,a} / S^n_{m,a}` used by eq. (6)
    /// (one more customer opening one more table).
    pub fn ratio_new_table(&mut self, n: usize, m: usize) -> f64 {
        let num = self.log(n + 1, m + 1);
        let den = self.log(n, m);
        if den == f64::NEG_INFINITY {
            return if n == 0 && m == 0 { 1.0 } else { 0.0 };
        }
        (num - den).exp()
    }
}

#[inline]
fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force S^n_m at a=0: unsigned Stirling numbers of the first
    /// kind satisfy s(n+1,m) = s(n,m-1) + n·s(n,m).
    fn stirling1(n: usize, m: usize) -> f64 {
        let mut table = vec![vec![0.0f64; n + 2]; n + 2];
        table[0][0] = 1.0;
        for nn in 0..n {
            for mm in 0..=nn {
                let v = table[nn][mm];
                if v == 0.0 {
                    continue;
                }
                table[nn + 1][mm + 1] += v;
                table[nn + 1][mm] += v * nn as f64;
            }
        }
        table[n][m]
    }

    #[test]
    fn zero_discount_matches_stirling_first_kind() {
        let mut t = StirlingTable::new(0.0, 12);
        for n in 0..=12usize {
            for m in 0..=n {
                let exact = stirling1(n, m);
                let got = t.log(n, m);
                if exact == 0.0 {
                    assert_eq!(got, f64::NEG_INFINITY, "S^{n}_{m}");
                } else {
                    assert!(
                        (got - exact.ln()).abs() < 1e-9,
                        "S^{n}_{m}: got {got}, want {}",
                        exact.ln()
                    );
                }
            }
        }
    }

    #[test]
    fn recurrence_holds_for_positive_discount() {
        let a = 0.3;
        let mut t = StirlingTable::new(a, 30);
        for n in 2..30usize {
            for m in 1..n {
                let lhs = t.log(n + 1, m);
                let rhs = log_add(
                    t.log(n, m - 1),
                    t.log(n, m) + ((n as f64 - m as f64 * a).max(0.0)).ln(),
                );
                if lhs.is_finite() || rhs.is_finite() {
                    assert!((lhs - rhs).abs() < 1e-9, "n={n} m={m}: {lhs} vs {rhs}");
                }
            }
        }
    }

    #[test]
    fn boundary_cases() {
        let mut t = StirlingTable::new(0.5, 5);
        assert_eq!(t.log(0, 0), 0.0); // S^0_0 = 1
        assert_eq!(t.log(3, 5), f64::NEG_INFINITY); // M > N
        assert_eq!(t.log(4, 0), f64::NEG_INFINITY); // S^N_0 = 0 for N>0
        // S^n_n = prod of nothing through the m-1 branch = 1.
        for n in 1..=8 {
            assert!((t.log(n, n) - 0.0).abs() < 1e-12, "S^{n}_{n} must be 1");
        }
    }

    #[test]
    fn ratios_are_finite_and_positive() {
        let mut t = StirlingTable::new(0.1, 50);
        for n in 1..50usize {
            for m in 1..=n {
                let r1 = t.ratio_same_tables(n, m);
                let r2 = t.ratio_new_table(n, m);
                assert!(r1.is_finite() && r1 >= 0.0, "r1({n},{m})={r1}");
                assert!(r2.is_finite() && r2 > 0.0, "r2({n},{m})={r2}");
            }
        }
    }

    #[test]
    fn grows_past_f64_overflow_regime() {
        // Raw S^400_m overflows f64; log-space must stay finite.
        let mut t = StirlingTable::new(0.25, 0);
        let v = t.log(400, 50);
        assert!(v.is_finite() && v > 0.0);
        let r = t.ratio_same_tables(400, 50);
        assert!(r.is_finite() && r > 0.0);
    }

    #[test]
    fn first_customer_opens_first_table_ratio() {
        let mut t = StirlingTable::new(0.2, 2);
        // From (n=0,m=0), opening a table: S^1_1/S^0_0 = 1.
        assert!((t.ratio_new_table(0, 0) - 1.0).abs() < 1e-12);
        // Staying at m=0 is impossible: S^1_0 = 0.
        assert_eq!(t.ratio_same_tables(0, 0), 0.0);
    }
}
