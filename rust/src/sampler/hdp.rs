//! AliasHDP: HDP-LDA (§2.3) — the extra level of hierarchy sits on the
//! *document* side: θ_d ~ DP(b₁, θ₀), θ₀ ~ DP(b₀, H), ψ_t ~ Dir(β).
//!
//! We use the Chinese-restaurant-franchise formulation with per-token
//! "new-table" indicators (the paper's `r_di`): each document is a
//! restaurant whose dishes are topics; a token either sits at an existing
//! table serving topic `t` or opens a new one, in which case the table
//! also registers at the root restaurant (root table counts `t_k` are the
//! shared statistic that estimates θ₀: θ₀_t ∝ t_k, new-topic mass ∝ b₀).
//!
//! A DP is a PDP with discount 0, so the document-side conditionals are
//! eqs. (5)/(6) with `a = 0`, roles word↔topic swapped, multiplied by the
//! Dirichlet-multinomial word factor φ_tw = (n_tw+β)/(n_t+β̄):
//!
//! ```text
//! p(z=t, r=0|rest) ∝ φ_tw · (n_dt+1−tb_dt)/(n_dt+1) · S^{n_dt+1}_{tb_dt}/S^{n_dt}_{tb_dt}
//! p(z=t, r=1|rest) ∝ φ_tw · b₁ · (tb_dt+1)/(n_dt+1) · θ₀(t) · S^{n_dt+1}_{tb_dt+1}/S^{n_dt}_{tb_dt}
//! θ₀(t) = t_k/(b₀+T)  for represented topics,   θ₀(new) = b₀/(b₀+T)
//! ```
//!
//! The `r=0` branch is non-zero only for topics already in the document —
//! the `k_d`-sparse exact component. The `r=1` branch over all topics is
//! the dense component approximated by a stale per-word alias table.
//!
//! Shared statistics: `n_tw` (+ totals `n_t`) and the root table counts
//! `t_k` — with the cross-statistic constraints (`0 ≤ t_k`, `t_k ≤ n_k`,
//! `n_k>0 ⇒ t_k>0`) that projection (§5.5) maintains under relaxed
//! consistency.

use super::alias::{AliasBuilder, AliasTable};
use super::counts::CountMatrix;
use super::doc_state::{DocState, SparseCounts};
use super::mh::mh_chain;
use super::stirling::StirlingTable;
use super::DocSampler;
use crate::corpus::doc::Document;
use crate::util::rng::Rng;

/// Stale per-word proposal; pooled and rebuilt in place (no steady-state
/// allocation).
struct WordProposal {
    table: AliasTable,
    /// Stale dense weights, indexed `t` for (t, r=1), plus slot `K` for
    /// "open a brand-new topic".
    qw: Box<[f64]>,
    qsum: f64,
    budget: u32,
}

impl WordProposal {
    fn empty(len: usize) -> WordProposal {
        WordProposal {
            table: AliasTable::empty(),
            qw: vec![0.0; len].into_boxed_slice(),
            qsum: 0.0,
            budget: 0,
        }
    }
}

/// Root stick weight `θ₀(t) = t_k / (b₀ + T)` given (clamped) root table
/// counts, with the uniform-over-truncation bootstrap for an empty root.
/// Shared by the training sampler and the frozen serving family
/// ([`crate::serve::family::HdpFamily`]).
#[inline]
pub fn root_stick(tk: f64, total: f64, b0: f64, k: usize) -> f64 {
    if tk == 0.0 && total == 0.0 {
        // Empty root: uniform over the truncation (bootstrap).
        return 1.0 / k.max(1) as f64;
    }
    tk / (b0 + total)
}

/// Dirichlet-multinomial predictive word probability
/// `(n_tw + β) / (n_t + β̄)` — the word factor of HDP-LDA and exactly the
/// LDA φ. Shared with the serving families.
#[inline]
pub fn dirichlet_predictive(nwt: f64, nt: f64, beta: f64, beta_bar: f64) -> f64 {
    (nwt + beta) / (nt + beta_bar)
}

/// The AliasHDP sampler. `k` is the truncation `K_max`; topics activate
/// on demand.
pub struct AliasHdp {
    k: usize,
    /// Root DP concentration b₀.
    pub b0: f64,
    /// Document DP concentration b₁.
    pub b1: f64,
    /// Topic-word Dirichlet β.
    pub beta: f64,
    beta_bar: f64,
    /// MH chain length per token.
    pub mh_steps: usize,
    /// Shard documents.
    pub docs: Vec<Document>,
    /// Latent state (`z`, `n_dt`, `r`).
    pub state: DocState,
    /// Shared word-topic counts.
    pub nwt: CountMatrix,
    /// Shared root table counts `t_k`, stored as row 0 of a 1×K matrix so
    /// the parameter-server path treats it like any other row.
    pub tables: CountMatrix,
    /// Per-document table counts `tb_dt` (local only).
    pub tb_dt: Vec<SparseCounts>,
    stirling: StirlingTable,
    proposals: Vec<Option<WordProposal>>,
    alias_builder: AliasBuilder,
    /// Diagnostics.
    pub mh_proposed: u64,
    /// Diagnostics.
    pub mh_accepted: u64,
    scratch_idx: Vec<u32>,
    scratch_w: Vec<f64>,
}

impl AliasHdp {
    /// Create with sequential CRF initialization (tokens pick topics from
    /// the predictive rule so the table bookkeeping starts consistent).
    pub fn new(
        docs: Vec<Document>,
        vocab: usize,
        k_max: usize,
        b0: f64,
        b1: f64,
        beta: f64,
        rng: &mut Rng,
    ) -> Self {
        Self::new_with_init(docs, vocab, k_max, b0, b1, beta, None, rng)
    }

    /// Create, taking topic assignments from `init` where provided (table
    /// indicators are re-derived by the CRP rule).
    #[allow(clippy::too_many_arguments)]
    pub fn new_with_init(
        docs: Vec<Document>,
        vocab: usize,
        k_max: usize,
        b0: f64,
        b1: f64,
        beta: f64,
        init: Option<&[Vec<u32>]>,
        rng: &mut Rng,
    ) -> Self {
        let max_doc_len = docs.iter().map(|d| d.len()).max().unwrap_or(0);
        let mut s = AliasHdp {
            k: k_max,
            b0,
            b1,
            beta,
            beta_bar: beta * vocab as f64,
            mh_steps: 2,
            state: DocState::new(docs.len()),
            nwt: CountMatrix::new(vocab, k_max),
            tables: CountMatrix::new(1, k_max),
            tb_dt: vec![SparseCounts::new(); docs.len()],
            stirling: StirlingTable::new(0.0, (max_doc_len + 2).min(4096)),
            proposals: (0..vocab).map(|_| None).collect(),
            alias_builder: AliasBuilder::new(),
            mh_proposed: 0,
            mh_accepted: 0,
            scratch_idx: Vec::with_capacity(64),
            scratch_w: Vec::with_capacity(64),
            docs,
        };
        s.nwt.set_smoothing(s.beta_bar);
        // Init: seed a handful of active topics, then assign by the
        // document-side CRP so tables start exactly consistent. The
        // documents are iterated out-of-body so the pass can mutate the
        // statistics without cloning every token vector.
        let seed_topics = (k_max / 4).clamp(1, 16);
        let docs_v = std::mem::take(&mut s.docs);
        for (d, doc) in docs_v.iter().enumerate() {
            let tokens = &doc.tokens;
            let mut zs = Vec::with_capacity(tokens.len());
            let mut rs = Vec::with_capacity(tokens.len());
            for (i, &w) in tokens.iter().enumerate() {
                let t = init
                    .and_then(|z| z.get(d).and_then(|zd| zd.get(i)).copied())
                    .filter(|&t| (t as usize) < k_max)
                    .unwrap_or_else(|| rng.below(seed_topics) as u32);
                let ndt = s.state.n_dt[d].get(t);
                let theta0 = s.theta0(t as usize);
                let p_new = s.b1 * theta0 / (ndt as f64 + s.b1 * theta0 + 1e-12);
                let r = ndt == 0 || rng.coin(p_new.clamp(0.0, 1.0));
                s.add_token(d, w, t, r);
                zs.push(t);
                rs.push(r);
            }
            s.state.z[d] = zs;
            s.state.r[d] = rs;
        }
        s.docs = docs_v;
        s
    }

    /// Root stick weight θ₀(t) (zero for unrepresented topics; the
    /// new-topic mass is `theta0_new`).
    #[inline]
    fn theta0(&self, t: usize) -> f64 {
        let tk = self.tables.get(0, t).max(0) as f64;
        let total = (self.tables.grand_total().max(0)) as f64;
        root_stick(tk, total, self.b0, self.k)
    }

    #[inline]
    fn theta0_new(&self) -> f64 {
        let total = (self.tables.grand_total().max(0)) as f64;
        self.b0 / (self.b0 + total)
    }

    /// Number of currently represented topics (diagnostics + figures).
    pub fn active_topics(&self) -> usize {
        (0..self.k)
            .filter(|&t| self.tables.get(0, t) > 0 || self.nwt.total(t) > 0)
            .count()
    }

    #[inline]
    fn phi(&self, w: u32, t: usize) -> f64 {
        // Same value as `dirichlet_predictive`, via the incremental
        // 1/(n_t+β̄) cache — no division on the per-token path.
        (self.nwt.get(w, t).max(0) as f64 + self.beta) * self.nwt.inv_denom(t)
    }

    fn add_token(&mut self, d: usize, w: u32, t: u32, r: bool) {
        self.state.n_dt[d].inc(t);
        self.nwt.inc(w, t as usize, 1);
        if r {
            self.tb_dt[d].inc(t);
            self.tables.inc(0, t as usize, 1);
        }
    }

    fn remove_token(&mut self, d: usize, w: u32, t: u32, r: bool) {
        self.state.n_dt[d].dec(t);
        self.nwt.inc(w, t as usize, -1);
        let ndt_after = self.state.n_dt[d].get(t);
        let tb = self.tb_dt[d].get(t);
        if r && tb > 0 {
            self.tb_dt[d].dec(t);
            self.tables.inc(0, t as usize, -1);
        } else if tb > ndt_after {
            // Local polytope repair: tables can't outnumber customers.
            self.tb_dt[d].dec_clamped(t);
            self.tables.inc(0, t as usize, -1);
        }
    }

    /// Document-side factor `g_r(d, t)` — eqs. (5)/(6) at a=0 — without φ.
    fn g(&self, d: usize, t: usize, r: bool) -> f64 {
        let ndt = self.state.n_dt[d].get(t as u32).max(0) as usize;
        let tb = self.tb_dt[d].get(t as u32).min(ndt as u32) as usize;
        if !r {
            if ndt == 0 || tb == 0 {
                return 0.0;
            }
            let frac = (ndt as f64 + 1.0 - tb as f64) / (ndt as f64 + 1.0);
            let sratio = (self.stir(ndt + 1, tb) - self.stir(ndt, tb)).exp();
            frac * sratio
        } else {
            let sratio = if ndt == 0 {
                1.0
            } else {
                (self.stir(ndt + 1, tb + 1) - self.stir(ndt, tb)).exp()
            };
            let frac = (tb as f64 + 1.0) / (ndt as f64 + 1.0);
            self.b1 * self.theta0(t) * frac * sratio
        }
    }

    #[inline]
    fn stir(&self, n: usize, m: usize) -> f64 {
        let n = n.min(self.stirling.max_n());
        let m = m.min(n);
        self.stirling.log_ro(n, m)
    }

    /// Grow Stirling coverage to the longest document (init does this; a
    /// reassigned shard may need it again).
    pub fn ensure_stirling_capacity(&mut self) {
        let maxn = self.docs.iter().map(|d| d.len()).max().unwrap_or(0);
        self.stirling.grow_to(maxn + 2);
    }

    /// Dense stale proposal for word `w`: slots `0..K` are (t, r=1); slot
    /// `K` is "open a new topic". Rebuilt in place over pooled buffers.
    fn rebuild_proposal(&mut self, w: u32) {
        let mut p = self.proposals[w as usize]
            .take()
            .unwrap_or_else(|| WordProposal::empty(self.k + 1));
        let mut qsum = 0.0;
        for t in 0..self.k {
            // Doc-independent upper envelope of the r=1 branch: the
            // doc-side fraction and Stirling ratio are ≤ 1 off-document.
            let v = self.b1 * self.theta0(t) * self.phi(w, t);
            p.qw[t] = v;
            qsum += v;
        }
        let v_new = self.b1 * self.theta0_new() / self.nwt.vocab() as f64;
        p.qw[self.k] = v_new;
        qsum += v_new;
        p.qsum = qsum;
        self.alias_builder.build_into(&mut p.table, &p.qw);
        p.budget = (self.k + 1) as u32;
        self.proposals[w as usize] = Some(p);
    }

    /// Mark the stale proposal for one word for rebuild (after a row
    /// sync); buffers are kept.
    pub fn invalidate_word(&mut self, w: u32) {
        if let Some(p) = self.proposals[w as usize].as_mut() {
            p.budget = 0;
        }
    }

    /// Mark all stale proposals for rebuild (bulk sync).
    pub fn invalidate_all(&mut self) {
        for p in self.proposals.iter_mut().flatten() {
            p.budget = 0;
        }
    }

    /// Observed MH acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.mh_proposed == 0 {
            1.0
        } else {
            self.mh_accepted as f64 / self.mh_proposed as f64
        }
    }

    /// Find a free slot for a brand-new topic (truncation permitting).
    fn free_topic(&self) -> Option<usize> {
        (0..self.k).find(|&t| self.tables.get(0, t) <= 0 && self.nwt.total(t) <= 0)
    }

    fn sample_token(&mut self, d: usize, i: usize, rng: &mut Rng) -> usize {
        let w = self.docs[d].tokens[i];
        let old_t = self.state.z[d][i];
        let old_r = self.state.r[d][i];
        self.remove_token(d, w, old_t, old_r);

        let need_rebuild = match &self.proposals[w as usize] {
            Some(p) => p.budget == 0,
            None => true,
        };
        if need_rebuild {
            self.rebuild_proposal(w);
        }

        // Outcome index space: 2t+r for existing topics, 2K for new topic.
        self.scratch_idx.clear();
        self.scratch_w.clear();
        let mut sparse_sum = 0.0;
        for (t, _c) in self.state.n_dt[d].iter() {
            for r in [false, true] {
                let wgt = self.phi(w, t as usize) * self.g(d, t as usize, r);
                if wgt > 0.0 {
                    self.scratch_idx.push(2 * t + r as u32);
                    self.scratch_w.push(wgt);
                    sparse_sum += wgt;
                }
            }
        }
        let qsum = self.proposals[w as usize].as_ref().unwrap().qsum;
        let total = sparse_sum + qsum;

        let this = &*self;
        let new_topic_idx = 2 * this.k;
        let sparse_idx = &this.scratch_idx;
        let sparse_w = &this.scratch_w;
        let proposals = &this.proposals;
        let p_of = |idx: usize| {
            if idx == new_topic_idx {
                this.b1 * this.theta0_new() / this.nwt.vocab() as f64
            } else {
                let (t, r) = (idx / 2, idx % 2 == 1);
                this.phi(w, t) * this.g(d, t, r)
            }
        };
        let q_of = |idx: usize| {
            let stale = proposals[w as usize].as_ref().map_or(0.0, |p| {
                if idx == new_topic_idx {
                    p.qw[this.k]
                } else if idx % 2 == 1 {
                    p.qw[idx / 2]
                } else {
                    0.0
                }
            });
            let sparse = if idx == new_topic_idx {
                0.0
            } else {
                let (t, r) = (idx / 2, idx % 2 == 1);
                if this.state.n_dt[d].get(t as u32) > 0 {
                    this.phi(w, t) * this.g(d, t, r)
                } else {
                    0.0
                }
            };
            sparse + stale
        };
        let mut draws = 0u32;
        let propose = |r: &mut Rng| {
            if total > 0.0 && r.f64() * total < sparse_sum {
                let mut u = r.f64() * sparse_sum;
                let mut j = sparse_idx.len().saturating_sub(1);
                for (jj, &wgt) in sparse_w.iter().enumerate() {
                    u -= wgt;
                    if u <= 0.0 {
                        j = jj;
                        break;
                    }
                }
                let idx = sparse_idx.get(j).copied().unwrap_or(1) as usize;
                (idx, q_of(idx))
            } else {
                let p = proposals[w as usize].as_ref().unwrap();
                let slot = p.table.sample(r);
                draws += 1;
                let idx = if slot == this.k { new_topic_idx } else { 2 * slot + 1 };
                (idx, q_of(idx))
            }
        };

        let init = Some(2 * old_t as usize + old_r as usize);
        let (new_idx, accepted) = mh_chain(init, self.mh_steps, propose, q_of, p_of, rng);
        self.mh_proposed += self.mh_steps as u64;
        self.mh_accepted += accepted as u64;

        if draws > 0 {
            if let Some(p) = self.proposals[w as usize].as_mut() {
                p.budget = p.budget.saturating_sub(draws);
            }
        }

        // Decode the outcome.
        let (mut new_t, mut new_r);
        if new_idx == new_topic_idx {
            match self.free_topic() {
                Some(t) => {
                    new_t = t as u32;
                    new_r = true;
                }
                None => {
                    // Truncation full: stay at the old topic.
                    new_t = old_t;
                    new_r = self.state.n_dt[d].get(old_t) == 0;
                }
            }
        } else {
            new_t = (new_idx / 2) as u32;
            new_r = new_idx % 2 == 1;
        }
        // First token of a topic in a doc must open a table.
        if !new_r && self.tb_dt[d].get(new_t) == 0 {
            new_r = true;
        }
        let _ = &mut new_t;
        self.state.z[d][i] = new_t;
        self.state.r[d][i] = new_r;
        self.add_token(d, w, new_t, new_r);
        accepted
    }
}

impl crate::eval::perplexity::TopicModelView for AliasHdp {
    fn k(&self) -> usize {
        self.k
    }
    fn phi(&self, w: u32, t: usize) -> f64 {
        AliasHdp::phi(self, w, t)
    }
    /// Fold-in prior: `b₁·θ₀(t)` — topics the root has never seen get
    /// (almost) no prior mass, matching the HDP document model.
    fn doc_prior(&self, t: usize) -> f64 {
        self.b1 * self.theta0(t) + 1e-9
    }
}

impl DocSampler for AliasHdp {
    fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize {
        let n = self.docs[d].tokens.len();
        let mut acc = 0usize;
        for i in 0..n {
            acc += self.sample_token(d, i, rng);
        }
        acc
    }

    fn num_topics(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "AliasHDP"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::CorpusConfig;

    fn make(n_docs: usize, k_max: usize, seed: u64) -> (AliasHdp, Rng) {
        let (c, _) = CorpusConfig {
            n_docs,
            vocab_size: 200,
            n_topics: 6,
            doc_len_mean: 20.0,
            seed,
            ..Default::default()
        }
        .generate();
        let mut rng = Rng::new(seed ^ 0xFACE);
        let s = AliasHdp::new(c.docs, 200, k_max, 1.0, 1.0, 0.01, &mut rng);
        (s, rng)
    }

    fn check_invariants(s: &AliasHdp) {
        // Word-topic counts match a recount; doc tables ≤ doc customers;
        // root tables = Σ_d doc tables.
        let mut recount = CountMatrix::new(s.nwt.vocab(), s.k);
        let mut root = vec![0i64; s.k];
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                recount.inc_local(w, s.state.z[d][i] as usize, 1);
            }
            for t in 0..s.k as u32 {
                let tb = s.tb_dt[d].get(t);
                let ndt = s.state.n_dt[d].get(t);
                assert!(tb <= ndt, "doc {d} topic {t}: tables {tb} > customers {ndt}");
                assert!(!(ndt > 0 && tb == 0), "doc {d} topic {t}: customers without table");
                root[t as usize] += tb as i64;
            }
        }
        for w in 0..s.nwt.vocab() as u32 {
            for t in 0..s.k {
                assert_eq!(s.nwt.get(w, t), recount.get(w, t), "nwt[{w},{t}]");
            }
        }
        for t in 0..s.k {
            assert_eq!(s.tables.get(0, t) as i64, root[t], "root tables for {t}");
        }
    }

    #[test]
    fn init_consistent() {
        let (s, _) = make(30, 24, 1);
        check_invariants(&s);
    }

    #[test]
    fn sweeps_preserve_invariants() {
        let (mut s, mut rng) = make(30, 24, 2);
        for _ in 0..4 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        check_invariants(&s);
    }

    #[test]
    fn topics_grow_beyond_seed() {
        // HDP must discover topics: active count should exceed the seeded
        // handful after training on a 6-topic corpus.
        let (mut s, mut rng) = make(150, 32, 3);
        for _ in 0..10 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let active = s.active_topics();
        assert!(active >= 4, "only {active} active topics");
        assert!(active <= 32);
    }

    #[test]
    fn training_improves_likelihood() {
        let (mut s, mut rng) = make(120, 24, 4);
        let ll0 = joint_ll(&s);
        for _ in 0..12 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let ll1 = joint_ll(&s);
        assert!(ll1 > ll0, "ll {ll0} -> {ll1}");
    }

    fn joint_ll(s: &AliasHdp) -> f64 {
        let mut ll = 0.0;
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                let t = s.state.z[d][i] as usize;
                ll += s.phi(w, t).max(1e-300).ln();
            }
        }
        ll
    }

    #[test]
    fn acceptance_rate_reasonable() {
        let (mut s, mut rng) = make(60, 24, 5);
        for _ in 0..3 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let rate = s.acceptance_rate();
        assert!(rate > 0.4, "HDP MH acceptance {rate}");
    }
}
