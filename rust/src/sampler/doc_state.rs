//! Per-document latent state: topic assignments and `k_d`-sparse counts.
//!
//! `n_td` — the number of tokens of document `d` in topic `t` — "remains
//! sparse, regardless of corpus size" (§2.1). The sparse term of eq. (4)
//! iterates exactly the non-zero entries, so this container optimizes for
//! iteration over a handful of `(topic, count)` pairs with `O(1)` inc/dec.

/// Sparse non-negative counts over topics, stored as unsorted
/// `(topic, count)` pairs (k_d is small, so linear probes beat hashing).
#[derive(Clone, Debug, Default)]
pub struct SparseCounts {
    entries: Vec<(u32, u32)>,
}

impl SparseCounts {
    /// Empty.
    pub fn new() -> Self {
        SparseCounts {
            entries: Vec::new(),
        }
    }

    /// Number of non-zero topics (`k_d`).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Count for a topic (0 when absent).
    #[inline]
    pub fn get(&self, topic: u32) -> u32 {
        self.entries
            .iter()
            .find(|&&(t, _)| t == topic)
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// Increment a topic's count.
    #[inline]
    pub fn inc(&mut self, topic: u32) {
        for e in self.entries.iter_mut() {
            if e.0 == topic {
                e.1 += 1;
                return;
            }
        }
        self.entries.push((topic, 1));
    }

    /// Decrement a topic's count; removes the entry when it reaches zero.
    /// Panics (debug) on decrementing an absent topic — that's a sampler
    /// bookkeeping bug, not a consistency artifact.
    #[inline]
    pub fn dec(&mut self, topic: u32) {
        for i in 0..self.entries.len() {
            if self.entries[i].0 == topic {
                self.entries[i].1 -= 1;
                if self.entries[i].1 == 0 {
                    self.entries.swap_remove(i);
                }
                return;
            }
        }
        debug_assert!(false, "dec of absent topic {topic}");
    }

    /// Iterate non-zero `(topic, count)` pairs (unsorted).
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.entries.iter().copied()
    }

    /// Total count (document length while fully assigned).
    pub fn total(&self) -> u64 {
        self.entries.iter().map(|&(_, c)| c as u64).sum()
    }

    /// Set a raw `(topic, count)` entry (mirror rebuilds). `count == 0`
    /// removes the entry.
    pub fn set_raw(&mut self, topic: u32, count: u32) {
        for i in 0..self.entries.len() {
            if self.entries[i].0 == topic {
                if count == 0 {
                    self.entries.swap_remove(i);
                } else {
                    self.entries[i].1 = count;
                }
                return;
            }
        }
        if count > 0 {
            self.entries.push((topic, count));
        }
    }

    /// Decrement that tolerates an absent entry (replica rows can lag a
    /// mirror under relaxed consistency).
    pub fn dec_clamped(&mut self, topic: u32) {
        if self.get(topic) > 0 {
            self.dec(topic);
        }
    }
}

/// Full latent state of one shard's documents.
#[derive(Clone, Debug)]
pub struct DocState {
    /// `z[d][i]` — topic of token `i` in document `d`.
    pub z: Vec<Vec<u32>>,
    /// `n_td` sparse counts per document.
    pub n_dt: Vec<SparseCounts>,
    /// PDP/HDP only: `r[d][i]` — "token opened a new table" indicator.
    pub r: Vec<Vec<bool>>,
}

impl DocState {
    /// Unassigned state for `n_docs` documents (topics are assigned by the
    /// sampler's init pass).
    pub fn new(n_docs: usize) -> Self {
        DocState {
            z: vec![Vec::new(); n_docs],
            n_dt: vec![SparseCounts::new(); n_docs],
            r: vec![Vec::new(); n_docs],
        }
    }

    /// Number of documents.
    pub fn len(&self) -> usize {
        self.z.len()
    }

    /// True iff no documents.
    pub fn is_empty(&self) -> bool {
        self.z.is_empty()
    }

    /// Mean `k_d` over documents — diagnostics for the sparsity claim.
    pub fn mean_kd(&self) -> f64 {
        if self.n_dt.is_empty() {
            return 0.0;
        }
        self.n_dt.iter().map(|s| s.nnz() as f64).sum::<f64>() / self.n_dt.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_dec_roundtrip() {
        let mut s = SparseCounts::new();
        s.inc(5);
        s.inc(5);
        s.inc(2);
        assert_eq!(s.get(5), 2);
        assert_eq!(s.get(2), 1);
        assert_eq!(s.get(7), 0);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.total(), 3);
        s.dec(5);
        assert_eq!(s.get(5), 1);
        s.dec(5);
        assert_eq!(s.get(5), 0);
        assert_eq!(s.nnz(), 1, "zero entries must be removed");
    }

    #[test]
    fn iter_covers_all_nonzero() {
        let mut s = SparseCounts::new();
        for t in [1u32, 3, 9, 3, 9, 9] {
            s.inc(t);
        }
        let mut got: Vec<(u32, u32)> = s.iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(1, 1), (3, 2), (9, 3)]);
    }

    #[test]
    fn mean_kd() {
        let mut d = DocState::new(2);
        d.n_dt[0].inc(1);
        d.n_dt[0].inc(2);
        d.n_dt[1].inc(1);
        assert!((d.mean_kd() - 1.5).abs() < 1e-12);
    }
}
