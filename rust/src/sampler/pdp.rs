//! AliasPDP: the Pitman-Yor topic model of §2.2 (PYTM + PDP language
//! model), sampled with the same sparse-exact + stale-dense-alias + MH
//! strategy, "albeit now using a twice as large space of state variables":
//! each outcome is a pair `(topic t, r ∈ {0,1})` where `r` says whether the
//! token opens a new table in restaurant `t`.
//!
//! Conditionals (token removed), from eqs. (5)/(6):
//!
//! ```text
//! p(z=t, r=0 | rest) ∝ (α + n_dt) · 1/(b+m_t) · (m_tw+1−s_tw)/(m_tw+1)
//!                      · S^{m_tw+1}_{s_tw,a} / S^{m_tw}_{s_tw,a}
//! p(z=t, r=1 | rest) ∝ (α + n_dt) · (b+a·s_t)/(b+m_t) · (s_tw+1)/(m_tw+1)
//!                      · (γ+s_tw)/(γ̄+s_t) · S^{m_tw+1}_{s_tw+1,a} / S^{m_tw}_{s_tw,a}
//! ```
//!
//! Splitting `(α + n_dt)` gives the `k_d`-sparse exact component (`n_dt`)
//! and the dense stale component (`α`) approximated per word by an alias
//! table over the `2K` pairs.
//!
//! Shared statistics: `m_tw` (customers), `s_tw` (tables) — the pair whose
//! polytope constraints (`0 ≤ s_tw ≤ m_tw`, `m_tw>0 ⇒ s_tw>0`) the
//! projection subsystem (§5.5) must maintain under relaxed consistency.

use super::alias::{AliasBuilder, AliasTable};
use super::counts::CountMatrix;
use super::doc_state::DocState;
use super::mh::mh_chain;
use super::stirling::StirlingTable;
use super::DocSampler;
use crate::corpus::doc::Document;
use crate::util::rng::Rng;

/// Stale per-word proposal over the `2K` pairs; pooled and rebuilt in
/// place like the LDA one (no steady-state allocation).
struct WordProposal {
    table: AliasTable,
    /// Stale dense weights over pairs, indexed `2t + r`.
    qw: Box<[f64]>,
    qsum: f64,
    budget: u32,
}

impl WordProposal {
    fn empty(len: usize) -> WordProposal {
        WordProposal {
            table: AliasTable::empty(),
            qw: vec![0.0; len].into_boxed_slice(),
            qsum: 0.0,
            budget: 0,
        }
    }
}

/// Pitman-Yor predictive word probability under fixed statistics:
///
/// ```text
/// p(w|t) = ((m_tw − a·s_tw)⁺ + (b + a·s_t)·base_w) / (b + m_t)
/// base_w = (γ + s_tw) / (γ̄ + s_t)
/// ```
///
/// The posterior term shared by the training-side
/// [`TopicModelView`](crate::eval::perplexity::TopicModelView) and the
/// frozen serving family ([`crate::serve::family::PdpFamily`]): callers
/// pass already-clamped counts.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn pyp_predictive(
    mtw: f64,
    stw: f64,
    mt: f64,
    st: f64,
    discount: f64,
    concentration: f64,
    gamma: f64,
    gamma_bar: f64,
) -> f64 {
    let base = (gamma + stw) / (gamma_bar + st);
    ((mtw - discount * stw).max(0.0) + (concentration + discount * st) * base)
        / (concentration + mt)
}

/// The AliasPDP sampler.
pub struct AliasPdp {
    k: usize,
    alpha: f64,
    /// PDP discount `a`.
    pub discount: f64,
    /// PDP concentration `b`.
    pub concentration: f64,
    /// Root Dirichlet smoothing γ (per word).
    pub gamma: f64,
    gamma_bar: f64,
    /// MH chain length per token.
    pub mh_steps: usize,
    /// Raw mode: disable the local defensive repairs and clamps — this is
    /// what "without projection" means in the paper (Fig 8): statistics
    /// that violate the polytope feed the sampler directly and "may
    /// easily produce NaN, infinite, or other unstable probabilities".
    /// Enabled by the trainer when `ProjectionMode::Off` is selected.
    pub raw_mode: bool,
    /// Shard documents.
    pub docs: Vec<Document>,
    /// Latent state (`z`, sparse `n_dt`, and the `r` indicators).
    pub state: DocState,
    /// Shared customer counts `m_tw` (synced via the parameter server).
    pub m: CountMatrix,
    /// Shared table counts `s_tw` (synced via the parameter server).
    pub s: CountMatrix,
    stirling: StirlingTable,
    proposals: Vec<Option<WordProposal>>,
    alias_builder: AliasBuilder,
    /// Diagnostics.
    pub mh_proposed: u64,
    /// Diagnostics.
    pub mh_accepted: u64,
    scratch_idx: Vec<u32>,
    scratch_w: Vec<f64>,
}

impl AliasPdp {
    /// Create with random topic initialization (every initial token opens
    /// a table with the CRP-correct probability).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        discount: f64,
        concentration: f64,
        gamma: f64,
        rng: &mut Rng,
    ) -> Self {
        Self::new_with_init(
            docs,
            vocab,
            k,
            alpha,
            discount,
            concentration,
            gamma,
            None,
            rng,
        )
    }

    /// Create, taking topic assignments from `init` where provided (table
    /// indicators are re-derived by the CRP rule — the shared table counts
    /// re-converge through projection, §5.5).
    #[allow(clippy::too_many_arguments)]
    pub fn new_with_init(
        docs: Vec<Document>,
        vocab: usize,
        k: usize,
        alpha: f64,
        discount: f64,
        concentration: f64,
        gamma: f64,
        init: Option<&[Vec<u32>]>,
        rng: &mut Rng,
    ) -> Self {
        let max_freq = {
            let mut f = vec![0u32; vocab];
            for d in &docs {
                for &w in &d.tokens {
                    f[w as usize] += 1;
                }
            }
            f.into_iter().max().unwrap_or(0) as usize
        };
        let mut s = AliasPdp {
            k,
            alpha,
            discount,
            concentration,
            gamma,
            gamma_bar: gamma * vocab as f64,
            mh_steps: 2,
            raw_mode: false,
            state: DocState::new(docs.len()),
            m: CountMatrix::new(vocab, k),
            s: CountMatrix::new(vocab, k),
            stirling: StirlingTable::new(discount, (max_freq + 2).min(4096)),
            proposals: (0..vocab).map(|_| None).collect(),
            alias_builder: AliasBuilder::new(),
            mh_proposed: 0,
            mh_accepted: 0,
            scratch_idx: Vec::with_capacity(64),
            scratch_w: Vec::with_capacity(64),
            docs,
        };
        // Normalizer caches: customers divide by b+m_t, tables by γ̄+s_t.
        s.m.set_smoothing(s.concentration);
        s.s.set_smoothing(s.gamma_bar);
        // Iterate the documents out-of-body so the init pass can mutate
        // the statistics without cloning every token vector.
        let docs_v = std::mem::take(&mut s.docs);
        for (d, doc) in docs_v.iter().enumerate() {
            let tokens = &doc.tokens;
            let mut zs = Vec::with_capacity(tokens.len());
            let mut rs = Vec::with_capacity(tokens.len());
            for (i, &w) in tokens.iter().enumerate() {
                let t = init
                    .and_then(|z| z.get(d).and_then(|zd| zd.get(i)).copied())
                    .filter(|&t| (t as usize) < k)
                    .unwrap_or_else(|| rng.below(k) as u32);
                // CRP: new table with prob (b + a·s_t)/(b + m_t).
                let mt = s.m.total(t as usize) as f64;
                let st = s.s.total(t as usize) as f64;
                let p_new = (s.concentration + s.discount * st) / (s.concentration + mt);
                let mtw = s.m.get(w, t as usize);
                let r = mtw == 0 || rng.coin(p_new);
                s.add_token(d, w, t, r);
                zs.push(t);
                rs.push(r);
            }
            s.state.z[d] = zs;
            s.state.r[d] = rs;
        }
        s.docs = docs_v;
        s
    }

    fn add_token(&mut self, d: usize, w: u32, t: u32, r: bool) {
        self.state.n_dt[d].inc(t);
        self.m.inc(w, t as usize, 1);
        if r {
            self.s.inc(w, t as usize, 1);
        }
    }

    /// Remove a token, locally repairing the `s ≤ m` polytope when the
    /// stored indicator disagrees with the (possibly synced) counts.
    /// Returns whether a table was actually closed.
    fn remove_token(&mut self, d: usize, w: u32, t: u32, r: bool) -> bool {
        self.state.n_dt[d].dec(t);
        self.m.inc(w, t as usize, -1);
        let m_after = self.m.get(w, t as usize).max(0);
        let s_now = self.s.get(w, t as usize).max(0);
        // Close the token's table if it opened one — but never the *last*
        // table while customers remain (the indicator scheme loses seating
        // detail; this is the standard repair), and always re-enter the
        // polytope 0 ≤ s ≤ m, (m>0 ⇒ s>0) that a sync may have broken.
        let mut s_new = s_now;
        if r && s_new > 0 {
            s_new -= 1;
        }
        if !self.raw_mode {
            s_new = s_new.min(m_after);
            if m_after > 0 && s_new == 0 {
                s_new = 1;
            }
        }
        if s_new != s_now {
            self.s.inc(w, t as usize, s_new - s_now);
        }
        s_new < s_now
    }

    /// Grow the Stirling table to cover current counts (call after syncs).
    pub fn ensure_stirling_capacity(&mut self) {
        let mut maxm = 0usize;
        for (_, row) in self.m.iter_rows() {
            maxm = maxm.max(row.max_value().max(0) as usize);
        }
        self.stirling.grow_to(maxm + 2);
    }

    /// Log-space Stirling lookup clamped to the grown range (the clamp can
    /// only trigger transiently after a sync; `ensure_stirling_capacity`
    /// restores exactness).
    #[inline]
    fn stir(&self, n: usize, m: usize) -> f64 {
        let n = n.min(self.stirling.max_n());
        let m = m.min(n);
        self.stirling.log_ro(n, m)
    }

    /// Unnormalized `f_r(t)` — everything in eqs. (5)/(6) except `(α+n_dt)`.
    fn f(&self, w: u32, t: usize, r: bool) -> f64 {
        let (mtw, stw);
        if self.raw_mode {
            // No clamps: violating statistics hit the Stirling ratios and
            // fractions raw (negative counts wrap to 0 only to avoid UB in
            // the table index; the *ratios* still go wrong — Fig 8).
            mtw = self.m.get(w, t).max(0) as usize;
            stw = self.s.get(w, t).max(0) as usize;
            if stw > mtw + 1 {
                // Impossible configuration: S ratios are 0/0 → poison.
                return if r { f64::NAN } else { 0.0 };
            }
        } else {
            mtw = self.m.get(w, t).max(0) as usize;
            stw = self.s.get(w, t).clamp(0, mtw as i32) as usize;
        }
        // Both denominators come from the incremental normalizer caches:
        // `inv_bm = 1/(b + max(m_t,0))`, `s.inv_denom = 1/(γ̄ + max(s_t,0))`.
        let st = (self.s.total(t) as f64).max(0.0);
        let inv_bm = self.m.inv_denom(t);
        let b = self.concentration;
        let a = self.discount;
        if !r {
            if mtw == 0 || stw == 0 {
                return 0.0; // no table to sit at
            }
            let frac = (mtw as f64 + 1.0 - stw as f64) / (mtw as f64 + 1.0);
            let sratio = (self.stir(mtw + 1, stw) - self.stir(mtw, stw)).exp();
            frac * sratio * inv_bm
        } else {
            let sratio = if mtw == 0 {
                1.0 // S^1_1 / S^0_0 = 1
            } else {
                (self.stir(mtw + 1, stw + 1) - self.stir(mtw, stw)).exp()
            };
            let frac = (stw as f64 + 1.0) / (mtw as f64 + 1.0);
            let root = (self.gamma + stw as f64) * self.s.inv_denom(t);
            (b + a * st) * inv_bm * frac * root * sratio
        }
    }

    /// Rebuild the stale proposal in place (pooled buffers; no
    /// steady-state allocation).
    fn rebuild_proposal(&mut self, w: u32) {
        let mut p = self.proposals[w as usize]
            .take()
            .unwrap_or_else(|| WordProposal::empty(2 * self.k));
        let mut qsum = 0.0;
        for t in 0..self.k {
            let v0 = self.alpha * self.f(w, t, false);
            let v1 = self.alpha * self.f(w, t, true);
            p.qw[2 * t] = v0;
            p.qw[2 * t + 1] = v1;
            qsum += v0 + v1;
        }
        p.qsum = qsum;
        self.alias_builder.build_into(&mut p.table, &p.qw);
        p.budget = 2 * self.k as u32;
        self.proposals[w as usize] = Some(p);
    }

    /// Mark the stale proposal for one word for rebuild (after a row
    /// sync); buffers are kept.
    pub fn invalidate_word(&mut self, w: u32) {
        if let Some(p) = self.proposals[w as usize].as_mut() {
            p.budget = 0;
        }
    }

    /// Mark all stale proposals for rebuild (bulk sync).
    pub fn invalidate_all(&mut self) {
        for p in self.proposals.iter_mut().flatten() {
            p.budget = 0;
        }
    }

    /// Observed MH acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.mh_proposed == 0 {
            1.0
        } else {
            self.mh_accepted as f64 / self.mh_proposed as f64
        }
    }

    fn sample_token(&mut self, d: usize, i: usize, rng: &mut Rng) -> usize {
        let w = self.docs[d].tokens[i];
        let old_t = self.state.z[d][i];
        let old_r = self.state.r[d][i];
        self.remove_token(d, w, old_t, old_r);

        // Keep Stirling coverage ahead of the biggest count for this word.
        let row_max = self.m.row(w).map_or(0, |r| r.max_value()).max(0) as usize;
        if row_max + 1 > self.stirling.max_n() {
            self.stirling.grow_to(row_max + 2);
        }

        let need_rebuild = match &self.proposals[w as usize] {
            Some(p) => p.budget == 0,
            None => true,
        };
        if need_rebuild {
            self.rebuild_proposal(w);
        }

        // Sparse exact component over pairs with n_dt > 0.
        self.scratch_idx.clear();
        self.scratch_w.clear();
        let mut sparse_sum = 0.0;
        for (t, c) in self.state.n_dt[d].iter() {
            for r in [false, true] {
                let wgt = c as f64 * self.f(w, t as usize, r);
                if wgt > 0.0 {
                    self.scratch_idx.push(2 * t + r as u32);
                    self.scratch_w.push(wgt);
                    sparse_sum += wgt;
                }
            }
        }
        let qsum = self.proposals[w as usize].as_ref().unwrap().qsum;
        let total = sparse_sum + qsum;

        let this = &*self;
        let sparse_idx = &this.scratch_idx;
        let sparse_w = &this.scratch_w;
        let proposals = &this.proposals;
        let q_of = |idx: usize| {
            let (t, r) = (idx / 2, idx % 2 == 1);
            let ndt = this.state.n_dt[d].get(t as u32) as f64;
            ndt * this.f(w, t, r) + proposals[w as usize].as_ref().map_or(0.0, |p| p.qw[idx])
        };
        let p_of = |idx: usize| {
            let (t, r) = (idx / 2, idx % 2 == 1);
            let ndt = this.state.n_dt[d].get(t as u32) as f64;
            (ndt + this.alpha) * this.f(w, t, r)
        };
        let mut draws = 0u32;
        let propose = |r: &mut Rng| {
            if total > 0.0 && r.f64() * total < sparse_sum {
                let mut u = r.f64() * sparse_sum;
                let mut idx = sparse_idx.len().saturating_sub(1);
                for (j, &wgt) in sparse_w.iter().enumerate() {
                    u -= wgt;
                    if u <= 0.0 {
                        idx = j;
                        break;
                    }
                }
                let pair = sparse_idx.get(idx).copied().unwrap_or(1) as usize;
                (pair, q_of(pair))
            } else {
                let p = proposals[w as usize].as_ref().unwrap();
                let pair = p.table.sample(r);
                draws += 1;
                (pair, q_of(pair))
            }
        };

        // Old state as a pair index; if the removal flipped its table
        // status the old index may now have zero mass — mh handles that.
        let init = Some(2 * old_t as usize + old_r as usize);
        let (new_idx, accepted) = mh_chain(init, self.mh_steps, propose, q_of, p_of, rng);
        self.mh_proposed += self.mh_steps as u64;
        self.mh_accepted += accepted as u64;

        if draws > 0 {
            if let Some(p) = self.proposals[w as usize].as_mut() {
                p.budget = p.budget.saturating_sub(draws);
            }
        }

        let new_t = (new_idx / 2) as u32;
        let mut new_r = new_idx % 2 == 1;
        // A token must open a table if the dish has none.
        if !new_r && self.m.get(w, new_t as usize) <= 0 {
            new_r = true;
        }
        self.state.z[d][i] = new_t;
        self.state.r[d][i] = new_r;
        self.add_token(d, w, new_t, new_r);
        accepted
    }
}

impl crate::eval::perplexity::TopicModelView for AliasPdp {
    fn k(&self) -> usize {
        self.k
    }
    /// PYP predictive word probability:
    /// `((m_tw − a·s_tw)⁺ + (b + a·s_t)·base_w) / (b + m_t)` with the
    /// root-smoothed base `base_w = (γ + s_tw)/(γ̄ + s_t)`.
    fn phi(&self, w: u32, t: usize) -> f64 {
        pyp_predictive(
            self.m.get(w, t).max(0) as f64,
            self.s.get(w, t).max(0) as f64,
            (self.m.total(t) as f64).max(0.0),
            (self.s.total(t) as f64).max(0.0),
            self.discount,
            self.concentration,
            self.gamma,
            self.gamma_bar,
        )
    }
    fn doc_prior(&self, _t: usize) -> f64 {
        self.alpha
    }
}

impl DocSampler for AliasPdp {
    fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize {
        let n = self.docs[d].tokens.len();
        let mut acc = 0usize;
        for i in 0..n {
            acc += self.sample_token(d, i, rng);
        }
        acc
    }

    fn num_topics(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "AliasPDP"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::{CorpusConfig, GenerativeModel};

    fn make(n_docs: usize, k: usize, seed: u64) -> (AliasPdp, Rng) {
        let (c, _) = CorpusConfig {
            n_docs,
            vocab_size: 200,
            n_topics: k,
            doc_len_mean: 20.0,
            model: GenerativeModel::Pyp,
            seed,
            ..Default::default()
        }
        .generate();
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let s = AliasPdp::new(c.docs, 200, k, 0.1, 0.1, 10.0, 0.5, &mut rng);
        (s, rng)
    }

    /// The PDP polytope invariants that projection exists to protect must
    /// hold *exactly* in single-machine operation.
    fn check_polytope(s: &AliasPdp) {
        for w in 0..s.m.vocab() as u32 {
            for t in 0..s.k {
                let m = s.m.get(w, t);
                let st = s.s.get(w, t);
                assert!(m >= 0, "m[{w},{t}] = {m} < 0");
                assert!(st >= 0, "s[{w},{t}] = {st} < 0");
                assert!(st <= m, "s[{w},{t}] = {st} > m = {m}");
                assert!(!(m > 0 && st == 0), "m[{w},{t}] = {m} but no tables");
            }
        }
    }

    fn check_counts(s: &AliasPdp) {
        let mut recount = CountMatrix::new(s.m.vocab(), s.k);
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                recount.inc_local(w, s.state.z[d][i] as usize, 1);
            }
            assert_eq!(s.state.n_dt[d].total() as usize, doc.tokens.len());
        }
        for w in 0..s.m.vocab() as u32 {
            for t in 0..s.k {
                assert_eq!(s.m.get(w, t), recount.get(w, t), "m[{w},{t}]");
            }
        }
    }

    #[test]
    fn init_satisfies_polytope() {
        let (s, _) = make(30, 6, 1);
        check_polytope(&s);
        check_counts(&s);
    }

    #[test]
    fn sweeps_preserve_invariants() {
        let (mut s, mut rng) = make(30, 6, 2);
        for _ in 0..4 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        check_polytope(&s);
        check_counts(&s);
    }

    #[test]
    fn acceptance_rate_reasonable() {
        let (mut s, mut rng) = make(60, 8, 3);
        for _ in 0..3 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let rate = s.acceptance_rate();
        assert!(rate > 0.5, "PDP MH acceptance {rate}");
    }

    #[test]
    fn training_improves_likelihood() {
        let (mut s, mut rng) = make(120, 8, 4);
        let ll0 = joint_ll(&s);
        for _ in 0..12 {
            for d in 0..s.docs.len() {
                s.sample_doc(d, &mut rng);
            }
        }
        let ll1 = joint_ll(&s);
        assert!(ll1 > ll0, "ll {ll0} -> {ll1}");
    }

    /// Predictive word probability under the PDP language model.
    fn joint_ll(s: &AliasPdp) -> f64 {
        let mut ll = 0.0;
        for (d, doc) in s.docs.iter().enumerate() {
            for (i, &w) in doc.tokens.iter().enumerate() {
                let t = s.state.z[d][i] as usize;
                let mtw = s.m.get(w, t).max(0) as f64;
                let stw = s.s.get(w, t).max(0) as f64;
                let mt = s.m.total(t).max(0) as f64;
                let st = s.s.total(t).max(0) as f64;
                let a = s.discount;
                let b = s.concentration;
                let base = (s.gamma + stw) / (s.gamma_bar + st);
                let p = ((mtw - a * stw).max(0.0) + (b + a * st) * base) / (b + mt);
                ll += p.max(1e-300).ln();
            }
        }
        ll
    }

    #[test]
    fn stirling_capacity_tracks_counts() {
        let (mut s, mut rng) = make(30, 6, 5);
        s.ensure_stirling_capacity();
        let cap = s.stirling.max_n();
        for d in 0..s.docs.len() {
            s.sample_doc(d, &mut rng);
        }
        // Sampling must auto-grow whenever counts outrun the table.
        assert!(s.stirling.max_n() >= cap);
    }
}
