//! Inference engines: the Metropolis-Hastings-Walker (alias) machinery and
//! the four samplers the paper evaluates.
//!
//! * [`alias`] — Walker/Vose alias tables: `O(l)` build, `O(1)` draw (§3.1).
//! * [`mh`] — Metropolis-Hastings correction for sampling from a *stale*
//!   proposal (§3.2–3.3).
//! * [`stirling`] — log-space generalized Stirling numbers for the PDP/HDP
//!   table arithmetic (§2.2).
//! * [`counts`] — the sufficient-statistics matrices clients replicate and
//!   the parameter server shards.
//! * [`doc_state`] — `k_d`-sparse per-document topic counts.
//! * [`sparse_lda`] — the YahooLDA baseline: Yao et al. s/r/q sparse
//!   sampler, re-implemented on the same parameter server (paper §6).
//! * [`alias_lda`] — AliasLDA: eq. (4) sparse-exact + stale-dense-alias
//!   + MH accept.
//! * [`pdp`] — AliasPDP: eqs. (5)/(6) over the doubled `(topic, new-table)`
//!   state space.
//! * [`hdp`] — AliasHDP: two-level DP on the document side.
//! * [`stash`] — the multi-thread producer/consumer alias pool (§5.1).

pub mod alias;
pub mod alias_lda;
pub mod counts;
pub mod doc_state;
pub mod hdp;
pub mod mh;
pub mod pdp;
pub mod sparse_lda;
pub mod stash;
pub mod stirling;

pub use alias::AliasTable;
pub use counts::CountMatrix;
pub use doc_state::DocState;

use crate::util::rng::Rng;

/// A model sampler that can resample one document in place against the
/// client's current replica of the shared statistics.
///
/// Implementations mutate (a) the document's topic assignments, (b) the
/// local doc-topic counts, and (c) the shared count matrices *through their
/// delta logs* so the parameter-server client can push the updates.
pub trait DocSampler {
    /// Resample every token of document `d`. Returns the number of
    /// Metropolis-Hastings proposals that were *accepted* (== tokens for
    /// exact samplers), for diagnostics.
    fn sample_doc(&mut self, d: usize, rng: &mut Rng) -> usize;

    /// Number of topics `K`.
    fn num_topics(&self) -> usize;

    /// Model name for logs/metrics.
    fn name(&self) -> &'static str;
}
