//! Walker's alias method (Vose's `O(l)` construction) — §3.1 of the paper.
//!
//! Preprocess an arbitrary discrete distribution over `l` outcomes into a
//! table of `(threshold, alias)` pairs; afterwards each draw costs two
//! uniforms and one comparison — `O(1)`. If the distribution is sampled at
//! least `l` times before it changes, the build cost amortizes away, which
//! is exactly the regime the stale-proposal Metropolis-Hastings scheme
//! (§3.3) engineers.

use crate::util::rng::Rng;

/// An immutable alias table over `0..len` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    /// Acceptance threshold per slot, already scaled to [0,1].
    prob: Vec<f64>,
    /// Alias outcome per slot.
    alias: Vec<u32>,
    /// Total (unnormalized) weight the table was built from.
    total: f64,
}

/// Reusable scratch for allocation-free [`AliasTable`] rebuilds: the
/// scaled-weight buffer and Vose's two work stacks. One builder serves
/// any number of tables (the samplers keep one per shard and rebuild
/// each word's proposal in place — §3.3's steady-state rebuilds then
/// allocate nothing).
#[derive(Clone, Debug, Default)]
pub struct AliasBuilder {
    scaled: Vec<f64>,
    small: Vec<u32>,
    large: Vec<u32>,
}

impl AliasBuilder {
    /// Empty builder (buffers grow to the first build's support size).
    pub fn new() -> AliasBuilder {
        AliasBuilder::default()
    }

    /// Rebuild `table` in place from (possibly unnormalized) non-negative
    /// weights. `O(l)`, reusing `table`'s and the builder's buffers.
    ///
    /// Zero-weight outcomes are representable and will never be drawn
    /// (unless *all* weights are zero, in which case the table degenerates
    /// to uniform — a deliberate choice so samplers never panic on an
    /// all-zero transient state caused by relaxed consistency).
    pub fn build_into(&mut self, table: &mut AliasTable, weights: &[f64]) {
        let n = weights.len();
        assert!(n > 0, "alias table over empty support");
        table.prob.clear();
        table.alias.clear();
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            table.prob.resize(n, 1.0);
            table.alias.extend(0..n as u32);
            table.total = 0.0;
            return;
        }
        let scale = n as f64 / total;
        self.scaled.clear();
        self.scaled.extend(weights.iter().map(|&w| w * scale));
        // Vose's two-stack partition.
        self.small.clear();
        self.large.clear();
        for (i, &p) in self.scaled.iter().enumerate() {
            if p < 1.0 {
                self.small.push(i as u32);
            } else {
                self.large.push(i as u32);
            }
        }
        table.prob.resize(n, 1.0);
        table.alias.extend(0..n as u32);
        let (prob, alias, scaled) = (&mut table.prob, &mut table.alias, &mut self.scaled);
        while let (Some(s), Some(l)) = (self.small.pop(), self.large.pop()) {
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                self.small.push(l);
            } else {
                self.large.push(l);
            }
        }
        // Numerical leftovers: both stacks drain to threshold 1.
        table.total = total;
    }
}

impl AliasTable {
    /// An empty table awaiting its first [`AliasBuilder::build_into`]
    /// (sampling it panics; build before use).
    pub fn empty() -> AliasTable {
        AliasTable {
            prob: Vec::new(),
            alias: Vec::new(),
            total: 0.0,
        }
    }

    /// Build from (possibly unnormalized) non-negative weights. `O(l)`.
    /// One-shot convenience over [`AliasBuilder::build_into`]; hot paths
    /// should hold a builder and rebuild in place instead.
    pub fn build(weights: &[f64]) -> AliasTable {
        let mut t = AliasTable::empty();
        AliasBuilder::new().build_into(&mut t, weights);
        t
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True iff the support is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Total weight at build time (0 for the degenerate all-zero table).
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Draw an outcome in `O(1)`.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chi2_ok(weights: &[f64], draws: usize, seed: u64) -> bool {
        let t = AliasTable::build(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0u64; weights.len()];
        for _ in 0..draws {
            counts[t.sample(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().sum();
        let mut chi2 = 0.0;
        let mut dof = 0usize;
        for (i, &w) in weights.iter().enumerate() {
            let e = w / total * draws as f64;
            if e < 5.0 {
                continue;
            }
            chi2 += (counts[i] as f64 - e).powi(2) / e;
            dof += 1;
        }
        // Very loose bound: χ² < dof + 6·sqrt(2·dof) (far beyond p=0.001).
        chi2 < dof as f64 + 6.0 * (2.0 * dof as f64).sqrt()
    }

    #[test]
    fn matches_distribution_uniform() {
        assert!(chi2_ok(&[1.0; 64], 200_000, 1));
    }

    #[test]
    fn matches_distribution_skewed() {
        let w: Vec<f64> = (0..100).map(|i| 1.0 / (i + 1) as f64).collect();
        assert!(chi2_ok(&w, 300_000, 2));
    }

    #[test]
    fn zero_weight_outcomes_never_drawn() {
        let w = [0.0, 5.0, 0.0, 1.0, 0.0];
        let t = AliasTable::build(&w);
        let mut rng = Rng::new(3);
        for _ in 0..50_000 {
            let s = t.sample(&mut rng);
            assert!(s == 1 || s == 3, "drew zero-weight outcome {s}");
        }
    }

    #[test]
    fn degenerate_all_zero_is_uniform_not_panic() {
        let t = AliasTable::build(&[0.0, 0.0, 0.0]);
        assert_eq!(t.total(), 0.0);
        let mut rng = Rng::new(4);
        let mut seen = [false; 3];
        for _ in 0..1000 {
            seen[t.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::build(&[3.5]);
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    fn build_into_reuse_matches_fresh_build() {
        let mut builder = AliasBuilder::new();
        let mut table = AliasTable::empty();
        // Rebuild the same table across different supports and sizes; each
        // rebuild must behave exactly like a fresh build.
        for (seed, n) in [(1u64, 16usize), (2, 64), (3, 8), (4, 64)] {
            let mut rng = Rng::new(seed);
            let w: Vec<f64> = (0..n).map(|_| rng.f64() + 1e-3).collect();
            builder.build_into(&mut table, &w);
            let fresh = AliasTable::build(&w);
            assert_eq!(table.len(), n);
            assert_eq!(table.prob, fresh.prob);
            assert_eq!(table.alias, fresh.alias);
            assert_eq!(table.total(), fresh.total());
        }
        // Degenerate all-zero rebuild resets cleanly too.
        builder.build_into(&mut table, &[0.0; 5]);
        assert_eq!(table.total(), 0.0);
        assert_eq!(table.len(), 5);
    }

    #[test]
    fn build_is_linear_probe() {
        // Structural sanity: thresholds in [0,1], aliases in range.
        let w: Vec<f64> = (0..1000).map(|i| ((i * 37) % 97) as f64 + 0.1).collect();
        let t = AliasTable::build(&w);
        assert!(t.prob.iter().all(|&p| (0.0..=1.0 + 1e-12).contains(&p)));
        assert!(t.alias.iter().all(|&a| (a as usize) < t.len()));
    }
}
