//! Training configuration: model family, sampler, cluster topology,
//! consistency and failure-injection knobs — plus JSON round-tripping so
//! experiment presets live in files and CLI flags override them.

use crate::corpus::generator::{CorpusConfig, GenerativeModel};
use crate::ps::network::NetConfig;
use crate::util::json::Json;
use std::time::Duration;

/// Which latent variable model to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// LDA with the YahooLDA sparse sampler (the baseline).
    YahooLda,
    /// LDA with the Metropolis-Hastings-Walker sampler.
    AliasLda,
    /// Pitman-Yor topic model (PDP language model).
    AliasPdp,
    /// Hierarchical Dirichlet Process topic model.
    AliasHdp,
}

impl ModelKind {
    /// Every model kind, in declaration order (exhaustive sweeps).
    pub const ALL: [ModelKind; 4] = [
        ModelKind::YahooLda,
        ModelKind::AliasLda,
        ModelKind::AliasPdp,
        ModelKind::AliasHdp,
    ];

    /// Parse from a CLI/JSON string.
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "yahoolda" | "yahoo" | "sparse" | "sparselda" => Some(ModelKind::YahooLda),
            "aliaslda" | "alias" | "lda" => Some(ModelKind::AliasLda),
            "aliaspdp" | "pdp" => Some(ModelKind::AliasPdp),
            "aliashdp" | "hdp" => Some(ModelKind::AliasHdp),
            _ => None,
        }
    }

    /// Display name matching the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::YahooLda => "YahooLDA",
            ModelKind::AliasLda => "AliasLDA",
            ModelKind::AliasPdp => "AliasPDP",
            ModelKind::AliasHdp => "AliasHDP",
        }
    }

    /// Canonical string form — guaranteed to round-trip through
    /// [`ModelKind::parse`] (the contract snapshots rely on to record
    /// their family).
    pub fn as_str(&self) -> &'static str {
        self.name()
    }

    /// The serving family this kind's frozen statistics belong to:
    /// `"lda"` (both LDA samplers share one statistic), `"pdp"`, or
    /// `"hdp"`. The `serve --model` contradiction check compares at this
    /// granularity.
    pub fn family_name(&self) -> &'static str {
        match self {
            ModelKind::YahooLda | ModelKind::AliasLda => "lda",
            ModelKind::AliasPdp => "pdp",
            ModelKind::AliasHdp => "hdp",
        }
    }

    /// Does this model carry the table polytope (needs projection)?
    pub fn has_table_constraints(&self) -> bool {
        matches!(self, ModelKind::AliasPdp | ModelKind::AliasHdp)
    }
}

/// Where constraint projection runs (§5.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionMode {
    /// No projection (Fig 8's diverging ablation).
    Off,
    /// Algorithm 1: single designated client.
    SingleMachine,
    /// Algorithm 2: partitioned across clients (paper's reported choice).
    Distributed,
    /// Algorithm 3: server-side on-demand.
    OnDemandServer,
}

impl ProjectionMode {
    /// Parse from a CLI/JSON string.
    pub fn parse(s: &str) -> Option<ProjectionMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(ProjectionMode::Off),
            "single" | "alg1" => Some(ProjectionMode::SingleMachine),
            "distributed" | "alg2" => Some(ProjectionMode::Distributed),
            "ondemand" | "server" | "alg3" => Some(ProjectionMode::OnDemandServer),
            _ => None,
        }
    }
}

/// Model hyper-parameters.
#[derive(Clone, Debug)]
pub struct ModelParams {
    /// Number of topics (truncation `K_max` for HDP).
    pub topics: usize,
    /// Document-topic Dirichlet α (LDA/PDP).
    pub alpha: f64,
    /// Topic-word Dirichlet β (LDA/HDP).
    pub beta: f64,
    /// PDP discount `a`.
    pub pdp_discount: f64,
    /// PDP concentration `b`.
    pub pdp_concentration: f64,
    /// PDP root smoothing γ.
    pub pdp_gamma: f64,
    /// HDP root concentration b₀.
    pub hdp_b0: f64,
    /// HDP document concentration b₁.
    pub hdp_b1: f64,
    /// MH chain length per token.
    pub mh_steps: usize,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams {
            topics: 100,
            alpha: 0.1,
            beta: 0.01,
            pdp_discount: 0.1,
            pdp_concentration: 10.0,
            pdp_gamma: 0.5,
            hdp_b0: 1.0,
            hdp_b1: 1.0,
            mh_steps: 2,
        }
    }
}

/// Cluster topology + consistency knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Client (worker) nodes — one shard each, like the paper.
    pub clients: usize,
    /// Server nodes as a fraction of clients (paper: 40%).
    pub server_fraction: f64,
    /// Virtual ring points per server slot.
    pub vnodes: usize,
    /// Transport behaviour.
    pub net: NetConfig,
    /// Pull cadence: pull every `sync_every` documents sampled.
    pub sync_every_docs: usize,
    /// Snapshot cadence (None disables).
    pub snapshot_every: Option<Duration>,
    /// Snapshot directory (defaults under the target dir).
    pub snapshot_dir: Option<std::path::PathBuf>,
    /// Communication filter applied to every push (§5.3): magnitude
    /// priority + uniform-sampling rescue. Default = send everything.
    pub filter: crate::ps::filter::Filter,
    /// Artificial per-document delay for *initially spawned* workers —
    /// simulates slow/preemptable machines (replacement nodes run at full
    /// speed, like the paper's reassignment to fresh machines).
    pub worker_slowdown: Duration,
    /// Clients (by index) that get an extra 10× slowdown — deterministic
    /// straggler injection.
    pub slow_clients: Vec<usize>,
    /// How long a worker may go without a sync-point heartbeat before
    /// the session declares it lost and fails it over. Generous by
    /// default: a worker is legitimately silent for whole sampling
    /// stretches between sync points, and oversubscribed hosts stall
    /// threads for seconds. Explicit kills are detected immediately
    /// regardless of this value.
    pub worker_liveness: Duration,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            clients: 4,
            server_fraction: 0.4,
            vnodes: 64,
            net: NetConfig::default(),
            sync_every_docs: 64,
            snapshot_every: None,
            snapshot_dir: None,
            filter: crate::ps::filter::Filter::default(),
            worker_slowdown: Duration::ZERO,
            slow_clients: Vec::new(),
            worker_liveness: Duration::from_secs(10),
        }
    }
}

impl ClusterConfig {
    /// Server count: `max(1, round(clients × server_fraction))` (§6:
    /// "the number of [server] nodes is 40% of the total client nodes").
    pub fn n_servers(&self) -> usize {
        ((self.clients as f64 * self.server_fraction).round() as usize).max(1)
    }
}

/// Failure-injection schedule (reproduces the shared-cluster preemption
/// environment of §6).
#[derive(Clone, Debug, Default)]
pub struct FailurePlan {
    /// `(iteration, client_index)` kills.
    pub kill_clients: Vec<(u64, usize)>,
    /// `(iteration, server_slot)` kills.
    pub kill_servers: Vec<(u64, usize)>,
}

/// The complete training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model family + sampler.
    pub model: ModelKind,
    /// Hyper-parameters.
    pub params: ModelParams,
    /// Corpus synthesis.
    pub corpus: CorpusConfig,
    /// Cluster topology.
    pub cluster: ClusterConfig,
    /// Projection placement.
    pub projection: ProjectionMode,
    /// Training iterations (full Gibbs sweeps).
    pub iterations: u64,
    /// Evaluate test perplexity every `eval_every` iterations (paper: 5).
    pub eval_every: u64,
    /// Held-out test documents (paper: 2000).
    pub test_docs: usize,
    /// Failure injection.
    pub failures: FailurePlan,
    /// Global seed.
    pub seed: u64,
    /// Use the PJRT evaluation artifacts when available.
    pub use_pjrt_eval: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: ModelKind::AliasLda,
            params: ModelParams::default(),
            corpus: CorpusConfig::default(),
            cluster: ClusterConfig::default(),
            projection: ProjectionMode::Distributed,
            iterations: 50,
            eval_every: 5,
            test_docs: 200,
            failures: FailurePlan::default(),
            seed: 42,
            use_pjrt_eval: false,
        }
    }
}

impl TrainConfig {
    /// Reject configurations that would divide by zero or deadlock deep
    /// inside the training loop, with errors that name the knob to fix.
    /// Called by [`TrainSession::start`](crate::coordinator::TrainSession)
    /// (and therefore by `Trainer::run`) before any topology is built.
    pub fn validate(&self) -> Result<(), String> {
        if self.eval_every == 0 {
            return Err(
                "eval_every must be ≥ 1 (the worker's metrics cadence computes \
                 `iteration % eval_every`); use a large value to evaluate rarely"
                    .into(),
            );
        }
        if self.cluster.sync_every_docs == 0 {
            return Err(
                "cluster.sync_every_docs must be ≥ 1 (the token loop syncs every \
                 `sync_every_docs` documents); use a large value to sync rarely"
                    .into(),
            );
        }
        if self.cluster.clients == 0 {
            return Err(
                "cluster.clients must be ≥ 1 — there is no one to train the model \
                 with zero client workers"
                    .into(),
            );
        }
        if self.params.topics < 2 {
            return Err(format!(
                "params.topics is {} but a topic model needs at least 2 topics \
                 (HDP: the truncation K_max)",
                self.params.topics
            ));
        }
        Ok(())
    }

    /// A fast LDA preset for tests/examples.
    pub fn small_lda() -> Self {
        let mut cfg = TrainConfig::default();
        cfg.params.topics = 20;
        cfg.corpus.n_docs = 800;
        cfg.corpus.vocab_size = 2_000;
        cfg.corpus.n_topics = 20;
        cfg.corpus.doc_len_mean = 40.0;
        cfg.iterations = 20;
        cfg.cluster.clients = 4;
        cfg
    }

    /// A PDP preset on a power-law corpus.
    pub fn small_pdp() -> Self {
        let mut cfg = TrainConfig::small_lda();
        cfg.model = ModelKind::AliasPdp;
        cfg.corpus.model = GenerativeModel::Pyp;
        cfg
    }

    /// An HDP preset.
    pub fn small_hdp() -> Self {
        let mut cfg = TrainConfig::small_lda();
        cfg.model = ModelKind::AliasHdp;
        cfg.params.topics = 40; // truncation
        cfg
    }

    /// Serialize (subset used by presets; see `from_json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.name().into())),
            ("topics", Json::Num(self.params.topics as f64)),
            ("alpha", Json::Num(self.params.alpha)),
            ("beta", Json::Num(self.params.beta)),
            ("mh_steps", Json::Num(self.params.mh_steps as f64)),
            ("pdp_discount", Json::Num(self.params.pdp_discount)),
            (
                "pdp_concentration",
                Json::Num(self.params.pdp_concentration),
            ),
            ("pdp_gamma", Json::Num(self.params.pdp_gamma)),
            ("hdp_b0", Json::Num(self.params.hdp_b0)),
            ("hdp_b1", Json::Num(self.params.hdp_b1)),
            ("n_docs", Json::Num(self.corpus.n_docs as f64)),
            ("vocab_size", Json::Num(self.corpus.vocab_size as f64)),
            ("doc_len_mean", Json::Num(self.corpus.doc_len_mean)),
            ("true_topics", Json::Num(self.corpus.n_topics as f64)),
            // Corpus *generator* identity: a resumed session must be able
            // to regenerate the identical synthetic corpus from this JSON
            // (the checkpoint's client snapshots index into its documents)
            // — which takes every generator knob, not just the seed.
            ("corpus_seed", Json::Num(self.corpus.seed as f64)),
            ("corpus_alpha", Json::Num(self.corpus.alpha)),
            ("corpus_beta", Json::Num(self.corpus.beta)),
            ("zipf_s", Json::Num(self.corpus.zipf_s)),
            ("corpus_pyp_discount", Json::Num(self.corpus.pyp_discount)),
            (
                "corpus_pyp_concentration",
                Json::Num(self.corpus.pyp_concentration),
            ),
            (
                "corpus_model",
                Json::Str(
                    match self.corpus.model {
                        GenerativeModel::Lda => "lda",
                        GenerativeModel::Pyp => "pyp",
                    }
                    .into(),
                ),
            ),
            (
                "sync_every_docs",
                Json::Num(self.cluster.sync_every_docs as f64),
            ),
            ("clients", Json::Num(self.cluster.clients as f64)),
            (
                "server_fraction",
                Json::Num(self.cluster.server_fraction),
            ),
            // Ring geometry: checkpointed slot stores were sharded under
            // it, so a resumed session must rebuild the identical ring.
            ("vnodes", Json::Num(self.cluster.vnodes as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("test_docs", Json::Num(self.test_docs as f64)),
            (
                "projection",
                Json::Str(
                    match self.projection {
                        ProjectionMode::Off => "off",
                        ProjectionMode::SingleMachine => "single",
                        ProjectionMode::Distributed => "distributed",
                        ProjectionMode::OnDemandServer => "ondemand",
                    }
                    .into(),
                ),
            ),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    /// Overlay JSON fields onto `self` (missing fields keep defaults).
    pub fn apply_json(&mut self, j: &Json) -> Result<(), String> {
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            self.model = ModelKind::parse(v).ok_or_else(|| format!("bad model {v:?}"))?;
        }
        if let Some(v) = j.get("projection").and_then(Json::as_str) {
            self.projection =
                ProjectionMode::parse(v).ok_or_else(|| format!("bad projection {v:?}"))?;
        }
        if let Some(v) = j.get("corpus_model").and_then(Json::as_str) {
            self.corpus.model = match v.to_ascii_lowercase().as_str() {
                "lda" => GenerativeModel::Lda,
                "pyp" => GenerativeModel::Pyp,
                _ => return Err(format!("bad corpus_model {v:?}")),
            };
        }
        macro_rules! num {
            ($key:literal, $field:expr, $ty:ty) => {
                if let Some(v) = j.get($key).and_then(Json::as_f64) {
                    $field = v as $ty;
                }
            };
        }
        num!("topics", self.params.topics, usize);
        num!("alpha", self.params.alpha, f64);
        num!("beta", self.params.beta, f64);
        num!("mh_steps", self.params.mh_steps, usize);
        num!("pdp_discount", self.params.pdp_discount, f64);
        num!("pdp_concentration", self.params.pdp_concentration, f64);
        num!("pdp_gamma", self.params.pdp_gamma, f64);
        num!("hdp_b0", self.params.hdp_b0, f64);
        num!("hdp_b1", self.params.hdp_b1, f64);
        num!("n_docs", self.corpus.n_docs, usize);
        num!("vocab_size", self.corpus.vocab_size, usize);
        num!("doc_len_mean", self.corpus.doc_len_mean, f64);
        num!("clients", self.cluster.clients, usize);
        num!("server_fraction", self.cluster.server_fraction, f64);
        num!("vnodes", self.cluster.vnodes, usize);
        num!("iterations", self.iterations, u64);
        num!("eval_every", self.eval_every, u64);
        num!("test_docs", self.test_docs, usize);
        num!("seed", self.seed, u64);
        num!("corpus_seed", self.corpus.seed, u64);
        num!("corpus_alpha", self.corpus.alpha, f64);
        num!("corpus_beta", self.corpus.beta, f64);
        num!("zipf_s", self.corpus.zipf_s, f64);
        num!("corpus_pyp_discount", self.corpus.pyp_discount, f64);
        num!("corpus_pyp_concentration", self.corpus.pyp_concentration, f64);
        num!("sync_every_docs", self.cluster.sync_every_docs, usize);
        // Keep the corpus ground truth aligned with the model topics by
        // default (explicit "true_topics" overrides).
        num!("true_topics", self.corpus.n_topics, usize);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_kind_parsing() {
        assert_eq!(ModelKind::parse("yahoolda"), Some(ModelKind::YahooLda));
        assert_eq!(ModelKind::parse("AliasLDA"), Some(ModelKind::AliasLda));
        assert_eq!(ModelKind::parse("PDP"), Some(ModelKind::AliasPdp));
        assert_eq!(ModelKind::parse("hdp"), Some(ModelKind::AliasHdp));
        assert_eq!(ModelKind::parse("gpt"), None);
    }

    /// Satellite: `as_str` → `parse` is the identity for every kind (and
    /// case-insensitively so) — the contract that lets snapshots record
    /// their family as a string.
    #[test]
    fn model_kind_as_str_parse_roundtrip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::parse(kind.as_str()), Some(kind), "{kind:?}");
            assert_eq!(
                ModelKind::parse(&kind.as_str().to_ascii_uppercase()),
                Some(kind)
            );
            assert_eq!(
                ModelKind::parse(&kind.as_str().to_ascii_lowercase()),
                Some(kind)
            );
            assert!(!kind.family_name().is_empty());
        }
        // Family granularity: both LDA samplers serve the same statistic.
        assert_eq!(
            ModelKind::YahooLda.family_name(),
            ModelKind::AliasLda.family_name()
        );
        assert_ne!(
            ModelKind::AliasPdp.family_name(),
            ModelKind::AliasHdp.family_name()
        );
    }

    #[test]
    fn server_fraction_rule() {
        let mut c = ClusterConfig::default();
        c.clients = 10;
        c.server_fraction = 0.4;
        assert_eq!(c.n_servers(), 4);
        c.clients = 1;
        assert_eq!(c.n_servers(), 1, "at least one server");
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut cfg = TrainConfig::small_pdp();
        cfg.iterations = 77;
        cfg.seed = 123;
        cfg.corpus.seed = 99;
        cfg.corpus.zipf_s = 2.0;
        cfg.corpus.alpha = 0.33;
        cfg.corpus.pyp_discount = 0.25;
        cfg.cluster.sync_every_docs = 17;
        let j = cfg.to_json();
        let mut back = TrainConfig::default();
        back.apply_json(&j).unwrap();
        assert_eq!(back.model, ModelKind::AliasPdp);
        assert_eq!(back.iterations, 77);
        assert_eq!(back.seed, 123);
        assert_eq!(back.params.topics, cfg.params.topics);
        // Corpus-generator identity survives: the resumed session must be
        // able to regenerate the exact same synthetic corpus.
        assert_eq!(back.corpus.model, GenerativeModel::Pyp);
        assert_eq!(back.corpus.seed, 99);
        assert_eq!(back.corpus.n_topics, cfg.corpus.n_topics);
        assert_eq!(back.corpus.zipf_s.to_bits(), 2.0f64.to_bits());
        assert_eq!(back.corpus.alpha.to_bits(), 0.33f64.to_bits());
        assert_eq!(back.corpus.pyp_discount.to_bits(), 0.25f64.to_bits());
        assert_eq!(
            back.corpus.pyp_concentration.to_bits(),
            cfg.corpus.pyp_concentration.to_bits()
        );
        assert_eq!(back.corpus.beta.to_bits(), cfg.corpus.beta.to_bits());
        assert_eq!(back.cluster.sync_every_docs, 17);
        // The regenerated corpora must be identical token-for-token.
        let (a, _) = cfg.corpus.generate();
        let (b, _) = back.corpus.generate();
        assert_eq!(a.docs.len(), b.docs.len());
        for (da, db) in a.docs.iter().zip(&b.docs) {
            assert_eq!(da.tokens, db.tokens);
        }
    }

    /// Satellite: `validate()` refuses the div-by-zero/deadlock knobs with
    /// errors that name the offending field.
    #[test]
    fn validate_refuses_degenerate_configs() {
        assert!(TrainConfig::default().validate().is_ok());
        assert!(TrainConfig::small_lda().validate().is_ok());

        let mut cfg = TrainConfig::default();
        cfg.eval_every = 0;
        let e = cfg.validate().unwrap_err();
        assert!(e.contains("eval_every"), "{e}");

        let mut cfg = TrainConfig::default();
        cfg.cluster.sync_every_docs = 0;
        let e = cfg.validate().unwrap_err();
        assert!(e.contains("sync_every_docs"), "{e}");

        let mut cfg = TrainConfig::default();
        cfg.cluster.clients = 0;
        let e = cfg.validate().unwrap_err();
        assert!(e.contains("clients"), "{e}");

        let mut cfg = TrainConfig::default();
        cfg.params.topics = 1;
        let e = cfg.validate().unwrap_err();
        assert!(e.contains("topics") && e.contains('1'), "{e}");
    }

    #[test]
    fn apply_json_rejects_bad_model() {
        let mut cfg = TrainConfig::default();
        let j = Json::parse(r#"{"model":"nonsense"}"#).unwrap();
        assert!(cfg.apply_json(&j).is_err());
    }
}
