//! Wall-clock timing helpers for the metrics pipeline and the bench harness.

use std::time::{Duration, Instant};

/// A stopwatch that accumulates across start/stop cycles — used to separate
/// "sampling time" from "synchronization time" inside a worker iteration.
#[derive(Debug)]
pub struct Stopwatch {
    acc: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// New, stopped, zeroed stopwatch.
    pub fn new() -> Self {
        Stopwatch {
            acc: Duration::ZERO,
            started: None,
        }
    }

    /// Start (no-op if running).
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop (no-op if stopped) and fold the lap into the accumulator.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.acc += t0.elapsed();
        }
    }

    /// Total accumulated time (including a running lap).
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.acc + t0.elapsed(),
            None => self.acc,
        }
    }

    /// Reset to zero and stop.
    pub fn reset(&mut self) {
        self.acc = Duration::ZERO;
        self.started = None;
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_laps() {
        let mut s = Stopwatch::new();
        s.start();
        std::thread::sleep(Duration::from_millis(5));
        s.stop();
        let first = s.elapsed();
        assert!(first >= Duration::from_millis(4));
        s.start();
        std::thread::sleep(Duration::from_millis(5));
        s.stop();
        assert!(s.elapsed() > first);
        s.reset();
        assert_eq!(s.elapsed(), Duration::ZERO);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
