//! Running statistics used by the per-iteration metrics aggregation.
//!
//! The paper's figures report, per iteration and across all clients:
//! max, min, mean, ±1 std-dev error bars, and the **number of data points**
//! (clients shrink over time because the scheduler terminates a job once
//! 90% of workers reach the target iteration — §6 "curse of the last
//! reducer"). [`RunningStats`] computes exactly that set with Welford's
//! online algorithm and supports merging partial aggregates from different
//! clients (Chan et al. parallel variance).

/// Online mean/variance/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        RunningStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Merge another accumulator into this one (parallel combine).
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations ("data points" column of the paper's figures).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator; 0 if fewer than 2 points).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation (NaN if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum observation (NaN if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Format as the paper's error-bar row: `mean ±std [min, max] (n)`.
    pub fn row(&self) -> String {
        format!(
            "{:12.4} ±{:10.4} [{:12.4}, {:12.4}] (n={})",
            self.mean(),
            self.std(),
            self.min(),
            self.max(),
            self.n
        )
    }
}

/// Simple fixed-bin histogram used by perf diagnostics (e.g. MH acceptance
/// rates, per-token sampling latencies).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `nbins` equal bins.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            under: 0,
            over: 0,
        }
    }

    /// Record an observation.
    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    /// Total observations including out-of-range.
    pub fn count(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// Approximate quantile from bin midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64) as u64;
        let mut acc = self.under;
        if acc >= target && self.under > 0 {
            return self.lo;
        }
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RunningStats::new();
        xs.iter().for_each(|&x| s.push(x));
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // naive sample variance = 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = RunningStats::new();
        xs.iter().for_each(|&x| all.push(x));
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        xs[..37].iter().for_each(|&x| a.push(x));
        xs[37..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = RunningStats::new();
        a.push(1.0);
        a.push(3.0);
        let before = a.row();
        a.merge(&RunningStats::new());
        assert_eq!(a.row(), before);

        let mut e = RunningStats::new();
        e.merge(&a);
        assert_eq!(e.row(), a.row());
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.push((i % 100) as f64);
        }
        assert_eq!(h.count(), 1000);
        let med = h.quantile(0.5);
        assert!((med - 50.0).abs() < 2.0, "median {med}");
    }
}
