//! A tiny leveled logger with monotonic timestamps.
//!
//! Every simulated node logs through this; verbosity is set once by the CLI
//! (`-v`/`-q`). Output goes to stderr so benchmark tables on stdout stay
//! machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global log threshold.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log threshold.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

/// Would a record at `l` be emitted?
pub fn enabled(l: Level) -> bool {
    l >= level()
}

/// Emit a log record (used via the macros below).
pub fn emit(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t0 = *START.get_or_init(Instant::now);
    let elapsed = t0.elapsed();
    let tag = match l {
        Level::Trace => "TRACE",
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!(
        "[{:>9.3}s {} {}] {}",
        elapsed.as_secs_f64(),
        tag,
        target,
        msg
    );
}

/// `info!(target, "fmt {}", args...)`
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

/// `warn!(target, "fmt {}", args...)`
#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

/// `debug!(target, "fmt {}", args...)`
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

/// `error!(target, "fmt {}", args...)`
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
