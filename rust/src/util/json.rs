//! A minimal JSON value, emitter and recursive-descent parser.
//!
//! Used for: the artifact manifest written by `python/compile/aot.py`,
//! metrics logs consumed by plotting, node snapshots' metadata, and config
//! files. (The offline build environment has no `serde`; this ~300-line
//! substitute covers the subset of JSON those files use — which is all of
//! JSON except exotic number formats.)

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Convenience: object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Lookup in an object; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (rounds exactly-integral floats).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while self.pos < self.bytes.len()
                        && self.bytes[self.pos] != b'"'
                        && self.bytes[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "invalid utf-8 in string")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {:?})", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {:?})", other)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":null,"d":true},"e":"x\ny\"z\"","f":1e3}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1000.0));
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\ny\"z\""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,[2]],[]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[1].as_arr().unwrap().len(), 0);
    }

    #[test]
    fn integral_floats_emit_as_ints() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }
}
