//! Deterministic pseudo-random number generation and the distributions the
//! samplers and the synthetic-corpus generator need.
//!
//! The whole system is seeded: every node derives its stream from
//! `(global_seed, node_id)` via SplitMix64, so cluster runs are bit-for-bit
//! reproducible regardless of thread interleaving in the simulated network.

/// SplitMix64 — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
///
/// Period 2^256−1; passes BigCrush. Chosen over PCG for its trivially
/// branch-free hot path (the samplers draw tens of millions of variates
/// per second per thread).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Two different seeds give
    /// statistically independent streams (seeded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start at the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream for a sub-component (e.g. a node or a
    /// sampling thread) without correlating with the parent stream.
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `(0, 1)` — never exactly zero (safe for `ln`).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via the polar (Marsaglia) method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Gamma(shape, 1.0) via Marsaglia–Tsang; boosted for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64_open();
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric/asymmetric Dirichlet draw; `alpha` per-component
    /// concentrations. Returns a probability vector.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // Degenerate (all-tiny shapes underflowed): fall back to uniform.
            let u = 1.0 / g.len() as f64;
            g.iter_mut().for_each(|x| *x = u);
        } else {
            g.iter_mut().for_each(|x| *x /= sum);
        }
        g
    }

    /// Draw from an unnormalized discrete distribution by linear scan.
    /// `O(len)` — the thing the alias method beats.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Poisson draw (Knuth for small mean, normal approximation for large).
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = mean + mean.sqrt() * self.normal();
            x.max(0.0).round() as usize
        }
    }
}

/// A Zipf(s) distribution over ranks `0..n` sampled in O(1) through a
/// precomputed alias table (dog-fooding [`crate::sampler::alias`] would be a
/// circular dependency, so a tiny standalone table lives here).
pub struct Zipf {
    /// P(rank = i) — exposed for corpus diagnostics.
    pub probs: Vec<f64>,
    alias: Vec<(f64, u32)>,
}

impl Zipf {
    /// Build a Zipf law with exponent `s` over `n` ranks.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut probs: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
        let z: f64 = probs.iter().sum();
        probs.iter_mut().for_each(|p| *p /= z);
        let alias = build_alias(&probs);
        Zipf { probs, alias }
    }

    /// Draw a rank in `[0, n)`.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.alias.len());
        let (thresh, alt) = self.alias[i];
        if rng.f64() < thresh {
            i
        } else {
            alt as usize
        }
    }
}

/// Vose alias-table construction over a normalized probability vector.
/// (The production alias table with its extra bookkeeping lives in
/// `sampler::alias`; this minimal one keeps `util` dependency-free.)
pub(crate) fn build_alias(probs: &[f64]) -> Vec<(f64, u32)> {
    let n = probs.len();
    let mut scaled: Vec<f64> = probs.iter().map(|p| p * n as f64).collect();
    let mut small: Vec<u32> = Vec::new();
    let mut large: Vec<u32> = Vec::new();
    for (i, &p) in scaled.iter().enumerate() {
        if p < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
    }
    let mut table = vec![(1.0f64, 0u32); n];
    while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
        table[s as usize] = (scaled[s as usize], l);
        scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
        if scaled[l as usize] < 1.0 {
            small.push(l);
        } else {
            large.push(l);
        }
    }
    for l in large {
        table[l as usize] = (1.0, l);
    }
    for s in small {
        table[s as usize] = (1.0, s);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derived_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.derive(1);
        let mut b = root.derive(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "derived streams must be independent");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y < 1.0);
        }
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::new(11);
        let n = 10;
        let mut counts = vec![0usize; n];
        let draws = 100_000;
        for _ in 0..draws {
            counts[r.below(n)] += 1;
        }
        let expect = draws as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(5);
        for &shape in &[0.3, 1.0, 4.5, 20.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "gamma({shape}) mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        let alpha = vec![0.1; 50];
        let p = r.dirichlet(&alpha);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut c = [0usize; 3];
        for _ in 0..40_000 {
            c[r.categorical(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        let ratio = c[2] as f64 / c[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn zipf_is_power_law() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(23);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Head rank must dominate the tail rank by roughly the power law.
        assert!(counts[0] > counts[99] * 5);
        // All mass accounted.
        assert_eq!(counts.iter().sum::<usize>(), 200_000);
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(29);
        for &m in &[3.0, 50.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(m) as f64).sum::<f64>() / n as f64;
            assert!((mean - m).abs() < 0.1 * m, "poisson({m}) mean {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
