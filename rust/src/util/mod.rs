//! Small self-contained substrates: deterministic RNG, running statistics,
//! a minimal JSON emitter/parser, a leveled logger and wall-clock timers.
//!
//! These exist because the build environment is fully offline: only the
//! `xla` crate's dependency closure is vendored, so `rand`, `serde`, `log`
//! facades are re-implemented here at the small scale this crate needs.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::RunningStats;
