//! The pipeline driver: stream → live session → serving tier.
//!
//! [`Pipeline::run`] owns the long-lived loop:
//!
//! 1. **Bootstrap** — pull the first chunk off the [`CorpusStream`],
//!    start a [`TrainSession`] over it (park mode on), and run the
//!    warm-up sweeps. The stream's header vocabulary sizes the model, so
//!    later chunks can carry words the bootstrap chunk never showed.
//! 2. **Serve** — checkpoint the cluster and load a [`ReplicaSet`] over
//!    the checkpoint directory. A query thread fires fold-in queries on
//!    a fixed cadence ([`Pacer`]) against the set for the whole run —
//!    reloads must never drop or block a query.
//! 3. **Stream** — for each subsequent chunk: ingest it into the live
//!    session ([`TrainSession::ingest`]), run the sweeps the
//!    [`OnlinePolicy`] assigns the batch, and on the checkpoint cadence
//!    write a fresh cluster checkpoint and [`ReplicaSet::reload`] the
//!    serving tier in place — each reload is a new model generation
//!    answering queries.
//!
//! Only one chunk of the corpus is ever resident in the driver
//! (`peak_chunk_docs` proves it); the session's shards grow, but the
//! stream-side buffer stays bounded. Each batch appends a
//! [`PipelineSample`] to the report: ingest rate, the serving
//! **freshness lag** (documents ingested but not yet inside the served
//! generation — the distance between the train and serve tiers), the
//! live generation number, and the segment's held-out perplexity.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::policy::OnlinePolicy;
use crate::config::TrainConfig;
use crate::coordinator::TrainSession;
use crate::corpus::doc::Corpus;
use crate::corpus::source::CorpusSource;
use crate::corpus::stream::CorpusStream;
use crate::net::Pacer;
use crate::serve::{InferConfig, ReplicaSet};
use crate::util::rng::Rng;
use crate::Result;

/// A [`CorpusSource`] over the already-pulled bootstrap chunk — the
/// adapter that lets [`TrainSession::start`] (which wants a whole
/// corpus) begin from the first chunk of a stream.
struct BootstrapSource {
    corpus: Corpus,
}

impl CorpusSource for BootstrapSource {
    fn load(&self) -> Result<Corpus> {
        Ok(self.corpus.clone())
    }

    fn describe(&self) -> String {
        format!(
            "streaming bootstrap chunk ({} docs, vocab {})",
            self.corpus.docs.len(),
            self.corpus.vocab_size
        )
    }
}

/// Everything [`Pipeline::run`] needs beyond the stream itself.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Cluster + model configuration for the live session. A snapshot
    /// cadence is forced on (park mode requires disk snapshots) when the
    /// config doesn't set one.
    pub train: TrainConfig,
    /// Sweeps-per-batch schedule.
    pub policy: OnlinePolicy,
    /// Directory cluster checkpoints go to — also the directory the
    /// serving tier loads and reloads from.
    pub checkpoint_dir: PathBuf,
    /// Checkpoint + serving reload every this many streamed batches.
    pub checkpoint_every_batches: u64,
    /// Serving replicas in the [`ReplicaSet`].
    pub replicas: usize,
    /// Cadence of the background query load.
    pub query_interval: Duration,
    /// Tokens per synthetic query document.
    pub query_doc_len: usize,
    /// Gibbs sweeps over the bootstrap chunk before serving starts.
    pub warmup_sweeps: u64,
}

impl PipelineConfig {
    /// Defaults sized for the in-process loop: checkpoint every 2
    /// batches, 2 serving replicas, a query every 2 ms.
    pub fn new(train: TrainConfig, checkpoint_dir: PathBuf) -> PipelineConfig {
        PipelineConfig {
            train,
            policy: OnlinePolicy::default(),
            checkpoint_dir,
            checkpoint_every_batches: 2,
            replicas: 2,
            query_interval: Duration::from_millis(2),
            query_doc_len: 16,
            warmup_sweeps: 4,
        }
    }
}

/// One row of the pipeline's time series — emitted per mini-batch.
#[derive(Clone, Debug)]
pub struct PipelineSample {
    /// 1-based mini-batch index (1 = the bootstrap chunk).
    pub batch: u64,
    /// Documents given to the session so far (bootstrap + ingested).
    pub docs_ingested: u64,
    /// Documents inside the generation the serving tier currently
    /// answers with (the session's absorbed count at the last reload).
    pub docs_servable: u64,
    /// `docs_ingested − docs_servable`: the model-generation freshness
    /// lag in documents.
    pub freshness_lag: u64,
    /// Serving generation live when the sample was taken.
    pub generation: u64,
    /// This batch's ingest throughput (chunk docs / batch wall time,
    /// sampling included).
    pub ingest_docs_per_sec: f64,
    /// Held-out perplexity at the end of the batch's segment.
    pub perplexity: f64,
    /// Sweeps the policy assigned this batch.
    pub sweeps: u64,
}

/// What a whole [`Pipeline::run`] produced.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Per-batch time series, bootstrap first, final catch-up row last.
    pub samples: Vec<PipelineSample>,
    /// Mini-batches processed (bootstrap included).
    pub batches: u64,
    /// Documents pulled off the stream (bootstrap included).
    pub docs_streamed: u64,
    /// Largest single chunk the driver ever held — the resident-memory
    /// bound the streaming claim rests on.
    pub peak_chunk_docs: usize,
    /// Queries the background load fired.
    pub queries_sent: u64,
    /// Queries that came back with a mixture (must equal
    /// `queries_sent`: reloads drop nothing).
    pub queries_answered: u64,
    /// Distinct serving generations the query thread observed, ascending.
    pub generations_observed: Vec<u64>,
    /// Serving reloads performed (initial load included).
    pub reloads: u64,
    /// End-to-end wall time.
    pub wall_secs: f64,
    /// Held-out perplexity after the final catch-up checkpoint.
    pub final_perplexity: f64,
}

impl PipelineReport {
    /// Freshness lag of the last sample (0 after the final catch-up).
    pub fn final_lag(&self) -> u64 {
        self.samples.last().map(|s| s.freshness_lag).unwrap_or(0)
    }

    /// Largest freshness lag any sample saw.
    pub fn peak_lag(&self) -> u64 {
        self.samples.iter().map(|s| s.freshness_lag).max().unwrap_or(0)
    }

    /// Mean ingest throughput over the streamed batches.
    pub fn ingest_docs_per_sec(&self) -> f64 {
        self.docs_streamed as f64 / self.wall_secs.max(1e-9)
    }

    /// Human summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline: {} batches, {} docs streamed (peak chunk {} docs)\n",
            self.batches, self.docs_streamed, self.peak_chunk_docs
        ));
        out.push_str(&format!(
            "ingest {:.0} docs/s | {} reloads, generations {:?}\n",
            self.ingest_docs_per_sec(),
            self.reloads,
            self.generations_observed
        ));
        out.push_str(&format!(
            "queries {}/{} answered | lag peak {} docs, final {} docs\n",
            self.queries_answered,
            self.queries_sent,
            self.peak_lag(),
            self.final_lag()
        ));
        out.push_str(&format!(
            "final held-out perplexity {:.1} ({:.1}s wall)\n",
            self.final_perplexity, self.wall_secs
        ));
        out.push_str("batch  docs_in  servable  lag  gen  sweeps  docs/s  perplexity\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{:>5}  {:>7}  {:>8}  {:>3}  {:>3}  {:>6}  {:>6.0}  {:>10.1}\n",
                s.batch,
                s.docs_ingested,
                s.docs_servable,
                s.freshness_lag,
                s.generation,
                s.sweeps,
                s.ingest_docs_per_sec,
                s.perplexity
            ));
        }
        out
    }
}

/// The train-while-serve pipeline. See the module docs for the loop.
pub struct Pipeline;

impl Pipeline {
    /// Stream `stream` end-to-end through a live session with a serving
    /// tier attached, returning the full time series. The stream is
    /// consumed; the session and serving set are torn down before
    /// returning.
    pub fn run(cfg: PipelineConfig, stream: &mut dyn CorpusStream) -> Result<PipelineReport> {
        anyhow::ensure!(cfg.replicas >= 1, "need at least one serving replica");
        anyhow::ensure!(
            cfg.checkpoint_every_batches >= 1,
            "checkpoint_every_batches must be ≥ 1"
        );
        anyhow::ensure!(cfg.query_doc_len >= 1, "query_doc_len must be ≥ 1");
        let t0 = Instant::now();

        // 1. Bootstrap: first chunk → session.
        let first = stream.next_chunk()?.ok_or_else(|| {
            anyhow::anyhow!("stream {} carries no documents", stream.describe())
        })?;
        let mut peak_chunk_docs = first.len();
        let mut docs_streamed = first.len() as u64;
        anyhow::ensure!(
            first.len() > cfg.train.test_docs,
            "bootstrap chunk ({} docs) must exceed the held-out split \
             ({} docs) — raise chunk_docs or lower test_docs",
            first.len(),
            cfg.train.test_docs
        );
        let mut train_cfg = cfg.train.clone();
        if train_cfg.cluster.snapshot_every.is_none() {
            // Park mode hands segment state back via disk snapshots.
            train_cfg.cluster.snapshot_every = Some(Duration::from_millis(100));
        }
        let boot = BootstrapSource {
            corpus: Corpus {
                docs: first,
                vocab_size: stream.vocab_size(),
                true_topics: 0,
            },
        };
        let mut session = TrainSession::start(train_cfg, &boot)?;
        session.set_park_workers(true)?;
        let mut batch: u64 = 1;
        let warmup = cfg.warmup_sweeps.max(1);
        let boot_start = Instant::now();
        let boot_seg = session.run_online(warmup)?;

        // 2. Serve: checkpoint and attach the replica set + query load.
        session.checkpoint(&cfg.checkpoint_dir)?;
        let set = ReplicaSet::load_dir(&cfg.checkpoint_dir, cfg.replicas)?;
        let mut reloads: u64 = 1;
        let mut docs_servable = session.docs_absorbed();

        let stop = Arc::new(AtomicBool::new(false));
        let q_sent = Arc::new(AtomicU64::new(0));
        let q_answered = Arc::new(AtomicU64::new(0));
        let gens_seen = Arc::new(Mutex::new(BTreeSet::new()));
        let query_thread = {
            let set = set.clone();
            let stop = stop.clone();
            let q_sent = q_sent.clone();
            let q_answered = q_answered.clone();
            let gens_seen = gens_seen.clone();
            let vocab = session.vocab();
            let doc_len = cfg.query_doc_len;
            let interval = cfg.query_interval;
            let seed = cfg.train.seed ^ 0x5E12_FE;
            std::thread::Builder::new()
                .name("pipeline-query".into())
                .spawn(move || {
                    let mut rng = Rng::new(seed);
                    let icfg = InferConfig {
                        burnin: 2,
                        samples: 1,
                        mh_steps: 2,
                    };
                    let mut pacer = Pacer::new(Instant::now(), interval);
                    while !stop.load(Ordering::Relaxed) {
                        pacer.wait();
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let tokens: Vec<u32> =
                            (0..doc_len).map(|_| rng.below(vocab) as u32).collect();
                        q_sent.fetch_add(1, Ordering::Relaxed);
                        let res = set.infer(&tokens, &icfg, &mut rng);
                        if !res.theta.is_empty() {
                            q_answered.fetch_add(1, Ordering::Relaxed);
                        }
                        gens_seen.lock().unwrap().insert(set.generation());
                    }
                })
                .expect("spawn query thread")
        };

        let mut samples = vec![PipelineSample {
            batch,
            docs_ingested: session.docs_ingested(),
            docs_servable,
            freshness_lag: session.docs_ingested().saturating_sub(docs_servable),
            generation: set.generation(),
            ingest_docs_per_sec: docs_streamed as f64
                / boot_start.elapsed().as_secs_f64().max(1e-9),
            perplexity: boot_seg.report.final_perplexity(),
            sweeps: warmup,
        }];

        // 3. Stream: ingest → online sweeps → cadence checkpoint/reload.
        let mut streamed_batches: u64 = 0;
        let mut final_perplexity = boot_seg.report.final_perplexity();
        while let Some(chunk) = stream.next_chunk()? {
            batch += 1;
            streamed_batches += 1;
            peak_chunk_docs = peak_chunk_docs.max(chunk.len());
            docs_streamed += chunk.len() as u64;
            let batch_start = Instant::now();
            session.ingest(&chunk)?;
            let sweeps = cfg.policy.sweeps_for(batch);
            let seg = session.run_online(sweeps)?;
            final_perplexity = seg.report.final_perplexity();
            if streamed_batches % cfg.checkpoint_every_batches == 0 {
                session.checkpoint(&cfg.checkpoint_dir)?;
                set.reload(&cfg.checkpoint_dir)?;
                reloads += 1;
                docs_servable = session.docs_absorbed();
            }
            let ingested = session.docs_ingested();
            samples.push(PipelineSample {
                batch,
                docs_ingested: ingested,
                docs_servable,
                freshness_lag: ingested.saturating_sub(docs_servable),
                generation: set.generation(),
                ingest_docs_per_sec: chunk.len() as f64
                    / batch_start.elapsed().as_secs_f64().max(1e-9),
                perplexity: final_perplexity,
                sweeps,
            });
        }

        // Final catch-up: everything ingested becomes servable.
        session.checkpoint(&cfg.checkpoint_dir)?;
        set.reload(&cfg.checkpoint_dir)?;
        reloads += 1;
        docs_servable = session.docs_absorbed();
        let ingested = session.docs_ingested();
        samples.push(PipelineSample {
            batch,
            docs_ingested: ingested,
            docs_servable,
            freshness_lag: ingested.saturating_sub(docs_servable),
            generation: set.generation(),
            ingest_docs_per_sec: 0.0,
            perplexity: final_perplexity,
            sweeps: 0,
        });

        // Tear down: stop the query load, then the cluster.
        stop.store(true, Ordering::Relaxed);
        let _ = query_thread.join();
        let _ = session.finish()?;

        let generations_observed: Vec<u64> =
            gens_seen.lock().unwrap().iter().copied().collect();
        Ok(PipelineReport {
            samples,
            batches: batch,
            docs_streamed,
            peak_chunk_docs,
            queries_sent: q_sent.load(Ordering::Relaxed),
            queries_answered: q_answered.load(Ordering::Relaxed),
            generations_observed,
            reloads,
            wall_secs: t0.elapsed().as_secs_f64(),
            final_perplexity,
        })
    }
}
