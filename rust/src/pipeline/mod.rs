//! Streaming ingest + online train-while-serve (Layer 8).
//!
//! The paper's production setting is not "load a corpus, train, stop":
//! documents arrive continuously, the model trains forever, and the
//! serving tier answers queries against snapshots that trail training by
//! a bounded, shrinking lag. This tier composes the subsystems below it
//! into that long-lived loop:
//!
//! * the **streaming corpus** layer ([`crate::corpus::stream`]) reads
//!   docword files in bounded chunks — constant stream-side resident
//!   memory no matter the corpus size;
//! * the **online session** ([`crate::coordinator::TrainSession`] in
//!   park mode) ingests each chunk into live workers via lazy sharding
//!   ([`crate::coordinator::DocFeed`]) and raises parked workers'
//!   targets instead of respawning threads per mini-batch;
//! * the **update policy** ([`OnlinePolicy`]) maps the online-learning
//!   literature's decaying step weights `ρ_t = (τ+t)^{−κ}` onto the
//!   collapsed-Gibbs knob we actually have — sweeps per mini-batch;
//! * the **checkpoint store** ([`crate::ps::snapshot`], incremental v4
//!   segments) turns the live cluster into an on-disk generation on a
//!   cadence;
//! * the **serving tier** ([`crate::serve::ReplicaSet`]) hot-reloads
//!   each generation under continuous query load — zero dropped
//!   queries across reloads.
//!
//! [`Pipeline::run`] drives the loop and emits a [`PipelineReport`]
//! time series: ingest rate, serving generation, model-generation
//! **freshness lag** (documents ingested but not yet servable), and
//! held-out perplexity per mini-batch.

pub mod driver;
pub mod policy;

pub use driver::{Pipeline, PipelineConfig, PipelineReport, PipelineSample};
pub use policy::OnlinePolicy;
